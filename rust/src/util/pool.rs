//! Fixed-size thread pool and a scoped parallel map.
//!
//! Tokio is not available offline, and the coordinator's concurrency needs
//! are simple: fan a batch of independent comparisons / simulations over the
//! cores and join. `par_map` uses `std::thread::scope`, so closures can
//! borrow from the caller without `'static` bounds.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default (logical cores, capped at 16 —
/// the batcher saturates PJRT well before that).
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(16)
}

/// Apply `f` to every element of `items` using up to `workers` threads,
/// preserving input order in the output. Panics in `f` propagate.
///
/// Work is claimed in contiguous chunks through one atomic counter and
/// each chunk's results are written through its own disjoint `&mut` output
/// slice — the element hot path performs no locking at all (the seed
/// version paid a `Mutex` lock/unlock per element). Chunks are small
/// (`~8 ×` the worker count) so uneven per-element costs still balance.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(&f).collect();
    }
    // One claimable task per chunk: the input chunk zipped with the
    // matching disjoint window of the output. The Mutex is touched once
    // per *chunk* (take on claim), never per element.
    type ChunkTask<'s, T, R> = Mutex<Option<(&'s [T], &'s mut [Option<R>])>>;
    let chunk = n.div_ceil(workers * 8).max(1);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let tasks: Vec<ChunkTask<'_, T, R>> = items
        .chunks(chunk)
        .zip(out.chunks_mut(chunk))
        .map(|pair| Mutex::new(Some(pair)))
        .collect();
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let ci = next.fetch_add(1, Ordering::Relaxed);
                if ci >= tasks.len() {
                    break;
                }
                let (xs, slots) = tasks[ci]
                    .lock()
                    .expect("chunk slot")
                    .take()
                    .expect("chunk claimed once");
                for (x, slot) in xs.iter().zip(slots.iter_mut()) {
                    *slot = Some(f(x));
                }
            });
        }
    });
    drop(tasks);
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Long-lived FIFO thread pool for the serve loop: jobs are boxed closures.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    /// Spawn a pool with `workers` threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mrtuner-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().expect("pool rx lock").recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool is live")
            .send(Box::new(f))
            .expect("pool worker alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..500).collect();
        let ys = par_map(&xs, 8, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_allows_borrows() {
        let base = vec![10u64, 20, 30];
        let xs = vec![0usize, 1, 2];
        let ys = par_map(&xs, 2, |&i| base[i] + 1);
        assert_eq!(ys, vec![11, 21, 31]);
    }

    #[test]
    fn par_map_chunking_covers_uneven_sizes() {
        // Sizes around the chunking boundaries: n < workers, n == workers,
        // n not divisible by the chunk count, n >> chunks.
        for n in [1usize, 3, 7, 8, 9, 63, 64, 65, 1000] {
            for workers in [2usize, 5, 16] {
                let xs: Vec<u64> = (0..n as u64).collect();
                let ys = par_map(&xs, workers, |&x| x + 1);
                assert_eq!(
                    ys,
                    xs.iter().map(|x| x + 1).collect::<Vec<_>>(),
                    "n={n} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(par_map(&none, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins: all jobs must have completed.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }
}
