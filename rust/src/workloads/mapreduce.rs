//! In-process MapReduce execution engine.
//!
//! Really runs a workload's map → combine → partition → shuffle → sort →
//! reduce chain over concrete bytes. Used for (a) workload correctness
//! tests, (b) cost-model calibration, and (c) deriving shuffle partition
//! statistics that the discrete-event simulator scales up to full job size.

use super::traits::Workload;

/// FNV-1a 64-bit — the default key partitioner.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Byte/record counters collected during a real run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Counters {
    pub map_input_bytes: u64,
    pub map_output_records: u64,
    pub map_output_bytes: u64,
    pub combine_output_records: u64,
    pub combine_output_bytes: u64,
    pub reduce_groups: u64,
    pub output_bytes: u64,
}

/// Result of a real in-process job execution.
#[derive(Debug, Clone)]
pub struct JobOutput {
    /// Final output of each reducer, in reducer order.
    pub reducer_outputs: Vec<Vec<u8>>,
    /// Shuffle bytes received per reducer.
    pub partition_bytes: Vec<u64>,
    pub counters: Counters,
}

/// Execute the full job: `num_splits` map tasks, `num_reducers` reduce tasks.
pub fn run_job(
    w: &dyn Workload,
    input: &[u8],
    num_splits: usize,
    num_reducers: usize,
) -> JobOutput {
    assert!(num_reducers > 0, "need at least one reducer");
    let splits = w.split(input, num_splits.max(1));
    let mut counters = Counters {
        map_input_bytes: input.len() as u64,
        ..Counters::default()
    };

    // Map side: map → sort → group → combine → partition.
    let mut buckets: Vec<Vec<(Vec<u8>, Vec<u8>)>> = vec![Vec::new(); num_reducers];
    for split in &splits {
        let mut pairs: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
        w.map(split, &mut |k, v| {
            counters.map_output_records += 1;
            counters.map_output_bytes += (k.len() + v.len()) as u64;
            pairs.push((k.to_vec(), v.to_vec()));
        });
        pairs.sort();
        let mut i = 0;
        while i < pairs.len() {
            let mut j = i + 1;
            while j < pairs.len() && pairs[j].0 == pairs[i].0 {
                j += 1;
            }
            let key = pairs[i].0.clone();
            let values: Vec<Vec<u8>> = pairs[i..j].iter().map(|(_, v)| v.clone()).collect();
            let combined = w.combine(&key, values);
            let p = w.partition(&key, num_reducers);
            debug_assert!(p < num_reducers);
            for v in combined {
                counters.combine_output_records += 1;
                counters.combine_output_bytes += (key.len() + v.len()) as u64;
                buckets[p].push((key.clone(), v));
            }
            i = j;
        }
    }

    // Reduce side: per-reducer sort → group → reduce.
    let mut reducer_outputs = Vec::with_capacity(num_reducers);
    let mut partition_bytes = Vec::with_capacity(num_reducers);
    for bucket in &mut buckets {
        partition_bytes
            .push(bucket.iter().map(|(k, v)| (k.len() + v.len()) as u64).sum::<u64>());
        bucket.sort();
        let mut out = Vec::new();
        let mut i = 0;
        while i < bucket.len() {
            let mut j = i + 1;
            while j < bucket.len() && bucket[j].0 == bucket[i].0 {
                j += 1;
            }
            let values: Vec<Vec<u8>> = bucket[i..j].iter().map(|(_, v)| v.clone()).collect();
            counters.reduce_groups += 1;
            w.reduce(&bucket[i].0, &values, &mut out);
            i = j;
        }
        counters.output_bytes += out.len() as u64;
        reducer_outputs.push(out);
    }

    JobOutput {
        reducer_outputs,
        partition_bytes,
        counters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::{workload_for, AppId};

    #[test]
    fn fnv_known_values() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn deterministic_across_runs() {
        let w = workload_for(AppId::WordCount);
        let mut rng = Rng::new(5);
        let input = w.generate(64 * 1024, &mut rng);
        let a = run_job(w.as_ref(), &input, 3, 2);
        let b = run_job(w.as_ref(), &input, 3, 2);
        assert_eq!(a.reducer_outputs, b.reducer_outputs);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn split_count_does_not_change_result() {
        // MapReduce determinism: the reduce output must be independent of
        // how the input was split (combiner associativity).
        let w = workload_for(AppId::WordCount);
        let mut rng = Rng::new(6);
        let input = w.generate(48 * 1024, &mut rng);
        let a = run_job(w.as_ref(), &input, 1, 3);
        let b = run_job(w.as_ref(), &input, 7, 3);
        assert_eq!(a.reducer_outputs, b.reducer_outputs);
        assert_eq!(a.counters.output_bytes, b.counters.output_bytes);
    }

    #[test]
    fn partition_bytes_sum_to_combine_output() {
        let w = workload_for(AppId::EximParse);
        let mut rng = Rng::new(7);
        let input = w.generate(32 * 1024, &mut rng);
        let out = run_job(w.as_ref(), &input, 4, 5);
        let total: u64 = out.partition_bytes.iter().sum();
        assert_eq!(total, out.counters.combine_output_bytes);
    }
}
