"""L2 model entry points and the AOT lowering pipeline."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_match_one_composes_preprocess_and_dtw():
    L, B = 64, 8
    rng = np.random.default_rng(1)
    raw = np.zeros(L, np.float32)
    raw[:50] = rng.random(50)
    ys = np.zeros((B, L), np.float32)
    nys = np.full(B, 40, np.int32)
    ys[:, :40] = rng.random((B, 40))

    q, dists, choices = model.match_one(
        jnp.array(raw), jnp.array(ys), jnp.array([50], jnp.int32), jnp.array(nys)
    )
    q2 = model.preprocess(jnp.array(raw), jnp.array([50], jnp.int32))
    np.testing.assert_allclose(np.asarray(q), np.asarray(q2), atol=1e-6)
    d2, ch2 = model.dtw_batch(q2, jnp.array(ys), jnp.array([50], jnp.int32), jnp.array(nys))
    np.testing.assert_allclose(np.asarray(dists), np.asarray(d2), rtol=1e-5)
    assert choices.shape == (B, L, L)
    assert choices.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(ch2), np.asarray(choices))


def test_entries_cover_every_bucket():
    names = [name for name, *_ in aot.entries()]
    for L in aot.BUCKETS:
        assert f"preprocess_{L}" in names
        assert f"dtw_pair_{L}" in names
        assert f"dtw_batch_{aot.BATCH}x{L}" in names
        assert f"match_one_{aot.BATCH}x{L}" in names


def test_lowering_produces_valid_hlo_text():
    # Lower the smallest preprocess entry and sanity-check the HLO text.
    name, fn, args, _ = next(iter(aot.entries()))
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_written(tmp_path):
    # Full AOT run into a temp dir (slow-ish but the real build-time path).
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["batch"] == aot.BATCH
    assert sorted(manifest["buckets"]) == sorted(aot.BUCKETS)
    assert len(manifest["entries"]) == 4 * len(aot.BUCKETS)
    for e in manifest["entries"]:
        assert os.path.exists(tmp_path / e["file"])
        assert e["kind"] in {"preprocess", "dtw_pair", "dtw_batch", "match_one"}
