//! End-to-end observability (PR 7): drive a routed `knn_batch` through a
//! [`RouterServer`] with an [`InMemoryTracker`] on *both* sides of the
//! wire and assert the full distributed span tree — router-side
//! `request → handle → knn_batch → shard×N`, each shard's own
//! `request → handle → knn_batch → cascade → {lb_kim, lb_paa, lb_keogh,
//! dp}` tree stitched underneath via the envelope's `trace` field — with
//! strictly positive durations under a [`VirtualClock`] (no sleeps, fully
//! deterministic, CI-runnable).
//!
//! With `MRTUNER_EMIT_TRACE` set, a second test repeats the round trip
//! with a [`ChromeTracker`] and writes a `chrome://tracing` /
//! [Perfetto](https://ui.perfetto.dev)-loadable `trace.json` (CI uploads
//! it as an artifact).

use mrtuner::client::MrtunerClient;
use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::router::{RouterServer, ShardRouter};
use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::database::profile::ProfileEntry;
use mrtuner::index::IndexedDb;
use mrtuner::protocol::Request;
use mrtuner::simulator::job::JobConfig;
use mrtuner::streaming::SessionManager;
use mrtuner::trace::{
    ChromeTracker, InMemoryTracker, SpanRecord, TraceHandle, Tracker, VirtualClock,
};
use mrtuner::util::json::Json;
use mrtuner::workloads::AppId;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn raw_wave(freq: f64, len: usize) -> Vec<f64> {
    (0..len)
        .map(|i| (0.5 + 0.4 * ((i as f64) * freq).sin()).clamp(0.0, 1.0))
        .collect()
}

fn entry(app: AppId, cfg: JobConfig, freq: f64, len: usize) -> ProfileEntry {
    ProfileEntry {
        app,
        config: cfg,
        series: mrtuner::signal::preprocess(&raw_wave(freq, len)),
        raw_len: len,
        completion_secs: 100.0,
    }
}

/// Two shards, one config set and two apps each — small enough that the
/// span tree is fully enumerable, big enough that every cascade stage
/// sees candidates.
fn two_shard_dbs() -> Vec<IndexedDb> {
    let configs = [JobConfig::new(4, 2, 10.0, 20.0), JobConfig::new(8, 4, 20.0, 40.0)];
    configs
        .iter()
        .enumerate()
        .map(|(ci, cfg)| {
            let mut db = IndexedDb::new();
            for (ai, app) in [AppId::WordCount, AppId::TeraSort].into_iter().enumerate() {
                let freq = 0.15 + 0.11 * (ci * 2 + ai) as f64;
                db.insert(entry(app, *cfg, freq, 48 + 16 * ci));
            }
            db
        })
        .collect()
}

/// A live [`TraceHandle`] over `tracker` with a deterministic virtual
/// clock: every read ticks, so no recorded span can have zero duration.
fn traced_handle(tracker: Arc<dyn Tracker>) -> TraceHandle {
    TraceHandle::with_clock(tracker, Arc::new(VirtualClock::new(10)))
}

fn traced_state(db: IndexedDb, tracker: Arc<dyn Tracker>) -> ServerState {
    ServerState {
        db,
        runtime: None,
        metrics: Metrics::new(),
        sessions: SessionManager::new(),
        tracer: traced_handle(tracker),
        recorder: None,
        predictors: Default::default(),
    }
}

struct Fleet {
    addrs: Vec<String>,
    trackers: Vec<Arc<InMemoryTracker>>,
    stops: Vec<Arc<AtomicBool>>,
    joins: Vec<std::thread::JoinHandle<anyhow::Result<()>>>,
}

fn spawn_traced_fleet(shards: Vec<IndexedDb>) -> Fleet {
    let mut fleet = Fleet {
        addrs: Vec::new(),
        trackers: Vec::new(),
        stops: Vec::new(),
        joins: Vec::new(),
    };
    for db in shards {
        let tracker = Arc::new(InMemoryTracker::new());
        let handle: Arc<dyn Tracker> = Arc::clone(&tracker);
        let server = MatchServer::bind("127.0.0.1:0", traced_state(db, handle)).unwrap();
        fleet.addrs.push(server.local_addr().unwrap().to_string());
        fleet.trackers.push(tracker);
        fleet.stops.push(server.stop_flag());
        fleet
            .joins
            .push(std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50))));
    }
    fleet
}

impl Fleet {
    fn shutdown(self) {
        for (stop, addr) in self.stops.iter().zip(&self.addrs) {
            stop.store(true, Ordering::SeqCst);
            let _ = std::net::TcpStream::connect(addr);
        }
        for j in self.joins {
            j.join().unwrap().unwrap();
        }
    }
}

/// The single child of `parent` named `name`, asserting it exists, is
/// unique, and closed with a strictly positive duration.
fn only_child(tr: &InMemoryTracker, parent: u64, name: &str) -> SpanRecord {
    let hits: Vec<SpanRecord> =
        tr.children_of(parent).into_iter().filter(|s| s.name == name).collect();
    assert_eq!(hits.len(), 1, "want one `{name}` under span {parent}: {hits:?}");
    let s = hits.into_iter().next().unwrap();
    assert!(s.end_ns > s.start_ns, "`{name}` span not closed or zero-length: {s:?}");
    s
}

#[test]
fn routed_knn_batch_builds_a_stitched_distributed_span_tree() {
    let fleet = spawn_traced_fleet(two_shard_dbs());
    let router_tracker = Arc::new(InMemoryTracker::new());
    let metrics = Arc::new(Metrics::new());
    let router = ShardRouter::connect(&fleet.addrs, Arc::clone(&metrics))
        .unwrap()
        .with_tracer(traced_handle(Arc::clone(&router_tracker)));
    let front = RouterServer::bind("127.0.0.1:0", router).unwrap();
    let addr = front.local_addr().unwrap();
    let stop = front.stop_flag();
    let join = std::thread::spawn(move || front.serve_with(2, Duration::from_millis(50)));

    // One routed batch (config None → fans to both shards), then the
    // metrics snapshot over the same wire.
    let mut client = MrtunerClient::connect(&addr.to_string()).unwrap();
    let queries = vec![raw_wave(0.15, 48), raw_wave(0.3, 64)];
    let body = client.knn_batch(&queries, 2, None).unwrap();
    assert_eq!(body.results.len(), 2);
    assert!(body.results.iter().all(|r| r.neighbors.len() == 2));

    let m = client.metrics().unwrap();
    assert!(m.get("requests").and_then(Json::as_u64).is_some(), "{m}");
    let fanout = m.get("fanout").and_then(Json::as_arr).unwrap();
    assert_eq!(fanout.len(), 2, "both shards timed: {m}");

    drop(client);
    stop.store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
    join.join().unwrap().unwrap();
    let addrs = fleet.addrs.clone();
    let trackers: Vec<Arc<InMemoryTracker>> = fleet.trackers.iter().map(Arc::clone).collect();
    fleet.shutdown();

    // ---- router side: request → {decode, handle → knn_batch → shard×2,
    // encode}, all closed, all strictly positive under the virtual clock.
    let roots = router_tracker.roots();
    assert_eq!(roots.len(), 2, "knn_batch + metrics requests: {roots:?}");
    assert!(roots.iter().all(|r| r.name == "request" && r.remote_parent == 0));
    let root = roots
        .iter()
        .find(|r| {
            router_tracker
                .children_of(r.id)
                .iter()
                .any(|h| h.notes.contains(&("type", "knn_batch".to_string())))
        })
        .expect("a root whose handle is typed knn_batch")
        .clone();
    assert!(root.end_ns > root.start_ns);
    let decode = only_child(&router_tracker, root.id, "decode");
    let handle = only_child(&router_tracker, root.id, "handle");
    let encode = only_child(&router_tracker, root.id, "encode");
    // Decode is timed before the root opens (its window is re-attached
    // post hoc), so only the phase order is pinned, plus containment of
    // the phases that genuinely nest.
    assert!(decode.end_ns <= handle.start_ns && handle.end_ns <= encode.start_ns);
    assert!(handle.start_ns >= root.start_ns && encode.end_ns <= root.end_ns);

    let batch = only_child(&router_tracker, handle.id, "knn_batch");
    assert_eq!(batch.events, vec![("queries", 2)]);
    let shard_spans = router_tracker.children_of(batch.id);
    assert_eq!(shard_spans.len(), 2, "one fan-out span per shard: {shard_spans:?}");
    for (si, s) in shard_spans.iter().enumerate() {
        assert_eq!(s.name, "shard");
        assert_eq!(s.events, vec![("shard", si as u64)]);
        assert_eq!(s.notes, vec![("addr", addrs[si].clone())]);
        assert!(s.end_ns > s.start_ns, "shard span zero-length: {s:?}");
    }

    // The metrics request traced too (its handle is typed, no children).
    let metrics_root = roots.iter().find(|r| r.id != root.id).unwrap();
    let mh = only_child(&router_tracker, metrics_root.id, "handle");
    assert!(mh.notes.contains(&("type", "metrics".to_string())));

    // ---- shard side: each shard's own tree nests under the router's
    // per-shard span via the envelope's `trace` field (remote_parent),
    // and carries the full cascade stage breakdown.
    for (si, tracker) in trackers.iter().enumerate() {
        // ShardRouter::connect's untraced shard_info probe is also
        // recorded (remote_parent 0); the routed batch is the linked one.
        let linked: Vec<SpanRecord> =
            tracker.roots().into_iter().filter(|r| r.remote_parent != 0).collect();
        assert_eq!(linked.len(), 1, "shard {si}: one traced request: {linked:?}");
        let sroot = &linked[0];
        assert_eq!(sroot.name, "request");
        assert_eq!(
            sroot.remote_parent, shard_spans[si].id,
            "shard {si}'s tree must hang off the router's fan-out span"
        );
        assert!(sroot.end_ns > sroot.start_ns);

        let shandle = only_child(tracker, sroot.id, "handle");
        assert!(shandle.notes.contains(&("type", "knn_batch".to_string())));
        let sbatch = only_child(tracker, shandle.id, "knn_batch");
        assert_eq!(sbatch.events, vec![("queries", 2)]);
        let cascade = only_child(tracker, sbatch.id, "cascade");
        assert_eq!(cascade.events, vec![("candidates", 4)], "2 queries × 2 entries");
        let stage_names: Vec<&str> =
            tracker.children_of(cascade.id).iter().map(|s| s.name).collect();
        assert_eq!(stage_names, vec!["lb_kim", "lb_paa", "lb_keogh", "dp"]);
        for stage in tracker.children_of(cascade.id) {
            assert!(stage.end_ns > stage.start_ns, "stage zero-length: {stage:?}");
            assert!(!stage.events.is_empty(), "stage without counters: {stage:?}");
        }
        let dp = only_child(tracker, cascade.id, "dp");
        let evals = dp
            .events
            .iter()
            .find(|(n, _)| *n == "evals")
            .map(|(_, v)| *v)
            .unwrap_or(0);
        assert!(evals >= 1, "dp stage ran no DTW: {dp:?}");

        // Conservation (the SearchStats invariant, now visible per stage
        // span): candidates = pruned_* + abandoned + dtw_evals.
        let abandoned = dp
            .events
            .iter()
            .find(|(n, _)| *n == "abandoned")
            .map(|(_, v)| *v)
            .unwrap_or(0);
        let pruned: u64 = tracker
            .children_of(cascade.id)
            .iter()
            .flat_map(|s| s.events.clone())
            .filter(|(n, _)| *n == "pruned")
            .map(|(_, v)| v)
            .sum();
        assert_eq!(pruned + abandoned + evals, 4, "cascade accounting leak");
    }
}

/// Like [`spawn_traced_fleet`], but each shard's [`InMemoryTracker`] sits
/// behind a [`SamplingTracker`] sharing `(n, seed)` with the router —
/// the production topology for head-based sampling.
fn spawn_sampled_fleet(shards: Vec<IndexedDb>, n: u64, seed: u64) -> Fleet {
    use mrtuner::trace::SamplingTracker;
    let mut fleet = Fleet {
        addrs: Vec::new(),
        trackers: Vec::new(),
        stops: Vec::new(),
        joins: Vec::new(),
    };
    for db in shards {
        let tracker = Arc::new(InMemoryTracker::new());
        let sampler: Arc<dyn Tracker> = Arc::new(SamplingTracker::with_seed(
            Arc::clone(&tracker) as Arc<dyn Tracker>,
            n,
            seed,
        ));
        let server = MatchServer::bind("127.0.0.1:0", traced_state(db, sampler)).unwrap();
        fleet.addrs.push(server.local_addr().unwrap().to_string());
        fleet.trackers.push(tracker);
        fleet.stops.push(server.stop_flag());
        fleet
            .joins
            .push(std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50))));
    }
    fleet
}

/// Head-based 1-in-N sampling agrees across processes: the router decides
/// per request (seeded, from the v2 request id) and the decision rides
/// every fan-out envelope, so the router and *both* shards record span
/// trees for exactly the same request ids — and nothing else. Runs
/// entirely under virtual clocks; the kept set is computed from
/// [`mrtuner::trace::sampler::decide`], not observed, so a drift in either
/// direction (over- or under-recording) fails loudly.
#[test]
fn sampling_decisions_agree_across_router_and_shards() {
    use mrtuner::trace::sampler::decide;
    use mrtuner::trace::SamplingTracker;

    const RATE: u64 = 4;
    const REQUESTS: u64 = 16;
    // The shards' only locally-decided root is the shard_info handshake
    // probe (their connection's request id 1; everything routed carries an
    // explicit fate on the wire). Pick a seed that samples key 1 out and
    // keeps a nontrivial, strict subset of ids 2..=REQUESTS.
    let seed = (0..10_000u64)
        .find(|&s| {
            let kept = (2..=REQUESTS).filter(|&k| decide(s, RATE, k)).count();
            !decide(s, RATE, 1) && kept >= 2 && kept < (REQUESTS - 1) as usize
        })
        .expect("a suitable seed exists");
    let kept: Vec<u64> = (1..=REQUESTS).filter(|&k| decide(seed, RATE, k)).collect();

    let fleet = spawn_sampled_fleet(two_shard_dbs(), RATE, seed);
    let router_tracker = Arc::new(InMemoryTracker::new());
    let router_sampler: Arc<dyn Tracker> = Arc::new(SamplingTracker::with_seed(
        Arc::clone(&router_tracker) as Arc<dyn Tracker>,
        RATE,
        seed,
    ));
    let metrics = Arc::new(Metrics::new());
    let router = ShardRouter::connect(&fleet.addrs, Arc::clone(&metrics))
        .unwrap()
        .with_tracer(traced_handle(router_sampler));
    let front = RouterServer::bind("127.0.0.1:0", router).unwrap();
    let addr = front.local_addr().unwrap();
    let stop = front.stop_flag();
    let join = std::thread::spawn(move || front.serve_with(2, Duration::from_millis(50)));

    // Request id i carries i queries, so every recorded tree states which
    // request it belongs to in its own `queries` event.
    let mut client = MrtunerClient::connect(&addr.to_string()).unwrap();
    for i in 1..=REQUESTS {
        let queries: Vec<Vec<f64>> = (0..i).map(|_| raw_wave(0.15, 48)).collect();
        let body = client.knn_batch(&queries, 1, None).unwrap();
        assert_eq!(body.results.len(), i as usize, "sampling must not affect answers");
    }

    drop(client);
    stop.store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
    join.join().unwrap().unwrap();
    let trackers: Vec<Arc<InMemoryTracker>> = fleet.trackers.iter().map(Arc::clone).collect();
    fleet.shutdown();

    // Router side: one root per kept id, in request order, each naming its
    // request through the batch span's `queries` event.
    let roots = router_tracker.roots();
    assert_eq!(roots.len(), kept.len(), "router recorded exactly the kept ids");
    for (root, &key) in roots.iter().zip(&kept) {
        let handle = only_child(&router_tracker, root.id, "handle");
        let batch = only_child(&router_tracker, handle.id, "knn_batch");
        assert_eq!(batch.events, vec![("queries", key)], "roots arrive in request order");
        let shard_spans = router_tracker.children_of(batch.id);
        assert_eq!(shard_spans.len(), 2, "kept requests fan to both shards");
        // Each shard recorded the same request, stitched under the
        // router's per-shard span.
        for (si, tracker) in trackers.iter().enumerate() {
            let sroot = tracker
                .roots()
                .into_iter()
                .find(|r| r.remote_parent == shard_spans[si].id)
                .unwrap_or_else(|| panic!("shard {si} missing tree for request {key}"));
            let sh = only_child(tracker, sroot.id, "handle");
            let sb = only_child(tracker, sh.id, "knn_batch");
            assert_eq!(sb.events, vec![("queries", key)], "same request, same tree");
        }
    }

    // ... and nothing else: no shard recorded a sampled-out request, an
    // orphan decode, or the handshake probe.
    for (si, tracker) in trackers.iter().enumerate() {
        let sroots = tracker.roots();
        assert_eq!(sroots.len(), kept.len(), "shard {si} over- or under-recorded");
        assert!(
            sroots.iter().all(|r| r.name == "request" && r.remote_parent != 0),
            "shard {si} recorded a locally-decided root: {sroots:?}"
        );
    }

    // The router's metrics counters agree with the decision function.
    let (recorded, sampled_out, _, _) = metrics.trace_summary();
    assert_eq!(recorded, kept.len() as u64);
    assert_eq!(sampled_out, REQUESTS - kept.len() as u64);
}

/// With `MRTUNER_EMIT_TRACE` set (CI does), repeat the routed round trip
/// against a [`ChromeTracker`] and write the artifact. The env var's
/// value is the output path (`1` means `trace.json` in the CWD).
#[test]
fn emit_chrome_trace_artifact_when_asked() {
    let dest = match std::env::var("MRTUNER_EMIT_TRACE") {
        Ok(v) if v == "1" => "trace.json".to_string(),
        Ok(v) if !v.is_empty() => v,
        _ => return, // opt-in only; a no-op pass otherwise
    };
    let fleet = spawn_traced_fleet(two_shard_dbs());
    let chrome = Arc::new(ChromeTracker::new());
    let mut router = ShardRouter::connect(&fleet.addrs, Arc::new(Metrics::new()))
        .unwrap()
        .with_tracer(traced_handle(Arc::clone(&chrome)));

    // Drive both routed shapes under explicit request roots so the
    // artifact shows a batch fan-out and a match fan-out side by side.
    let tracer = router.tracer().clone();
    {
        let root = tracer.root("request");
        let handle = root.child("handle");
        let batch = handle.child("knn_batch");
        let req = Request::KnnBatch {
            queries: vec![raw_wave(0.15, 48), raw_wave(0.3, 64)],
            k: 2,
            config: None,
            allow_partial: false,
        };
        router.route_knn_batch(&req, &batch).unwrap();
    }
    {
        let root = tracer.root("request");
        let handle = root.child("handle");
        let m = handle.child("match");
        let req = Request::Match {
            series: raw_wave(0.15, 48),
            config: JobConfig::new(4, 2, 10.0, 20.0),
        };
        router.route_match(&req, &m).unwrap();
    }
    fleet.shutdown();

    assert!(!chrome.is_empty(), "no events recorded");
    let doc = chrome.to_json();
    assert_eq!(
        doc.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms")
    );
    chrome.write_to(std::path::Path::new(&dest)).unwrap();
    eprintln!("wrote {} trace events to {dest}", chrome.len());
}
