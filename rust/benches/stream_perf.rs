//! Perf bench (streaming layer): anytime classification of live CPU
//! streams vs the full-series indexed matcher.
//!
//! For each reference-DB size (50 and 500 entries: 5 apps × 10/100 config
//! sets) a fleet of simulator-generated sessions is streamed into the
//! online classifier. Per session we record whether the early-exit policy
//! declared the same application the full-series indexed search declares,
//! how much of the series it observed before deciding, and the wall-clock
//! feed cost. The acceptance bar at DB=500: >= 95% agreement while
//! observing <= 60% of the series on average.
//!
//! Results go to stdout and `BENCH_stream.json` (the perf trajectory
//! file). `MRTUNER_BENCH_SMOKE=1` shrinks the sweep for CI.
//!
//! Run with: `cargo bench --bench stream_perf`

use mrtuner::coordinator::batcher::prepare_query;
use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::index::IndexedDb;
use mrtuner::simulator::engine::simulate;
use mrtuner::simulator::job::JobConfig;
use mrtuner::streaming::{DecisionPolicy, FinalLen, StreamSession, StreamStats};
use mrtuner::util::json::Json;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::{workload_for, AppId};
use std::time::Instant;

/// SysStat upload period, in 1 Hz samples per feed batch.
const FEED_BATCH: usize = 10;

/// Short-job config ranges so streams stay inside the incremental regime
/// (the paper's full ranges produce multi-thousand-second runs that the
/// pipeline resamples; streaming those defers every answer to finalize).
fn stream_grid(n: usize, seed: u64) -> ConfigGrid {
    let mut rng = Rng::new(seed ^ 0x57ea_4042);
    let configs = (0..n)
        .map(|_| {
            JobConfig::new(
                rng.range_u64(2, 13) as usize,
                rng.range_u64(1, 7) as usize,
                rng.range_u64(5, 21) as f64,
                rng.range_u64(30, 101) as f64,
            )
        })
        .collect();
    ConfigGrid { configs }
}

struct SizeResult {
    db: usize,
    sessions: usize,
    agreement: f64,
    early_rate: f64,
    mean_fraction: f64,
    mean_decision_sample: f64,
    mean_session_ms: f64,
    culled_per_session: f64,
    stream: StreamStats,
}

/// `session_configs` picks how many of the grid's config sets are driven
/// as live sessions (one session per config set per app).
fn run_size(db_configs: usize, session_configs: usize, sc: &SystemConfig) -> SizeResult {
    let grid = stream_grid(db_configs, 1);
    let profiler = Profiler::new(sc, None);
    let mut idx = IndexedDb::new();
    for &app in AppId::all() {
        for entry in profiler.profile(app, &grid) {
            idx.insert(entry);
        }
    }
    println!(
        "  reference DB: {} entries ({} apps x {} config sets)",
        idx.len(),
        AppId::all().len(),
        grid.len()
    );

    let policy = DecisionPolicy::default();
    let mut sessions = 0usize;
    let mut agree = 0usize;
    let mut early = 0usize;
    let mut fraction_sum = 0.0;
    let mut decision_sample_sum = 0.0;
    let mut wall_sum = 0.0;
    let mut stream = StreamStats::default();

    for (si, cfg) in grid.configs.iter().take(session_configs.min(grid.len())).enumerate() {
        for (ai, &app) in AppId::all().iter().enumerate() {
            // Fresh capture of a known app under a profiled config set —
            // different noise seed than the stored reference.
            let w = workload_for(app);
            let r = simulate(
                w.as_ref(),
                cfg,
                &sc.cluster,
                &sc.noise,
                &mut Rng::new(0xbeef ^ ((si as u64) << 8) ^ (ai as u64)),
            );

            // Offline truth: full-series indexed top-1 in this bucket.
            let q = prepare_query(&r.cpu_noisy);
            let (offline, _) = idx.knn_in_config(&q, &cfg.label(), 1);
            let offline_app = idx.entries()[offline[0].index].app;

            let mut session = StreamSession::open(
                &idx,
                Some(cfg),
                FinalLen::Known(r.cpu_noisy.len()),
                policy,
            );
            let mut source = r.live_stream();
            let t0 = Instant::now();
            while let Some(chunk) = source.next_batch(FEED_BATCH) {
                if session.push(&idx, chunk).is_some() {
                    break;
                }
            }
            wall_sum += t0.elapsed().as_secs_f64();

            sessions += 1;
            stream.merge(&session.stats());
            match session.decision() {
                Some(d) => {
                    early += 1;
                    fraction_sum += d.fraction;
                    decision_sample_sum += d.at_sample as f64;
                    if d.app == offline_app {
                        agree += 1;
                    }
                }
                None => {
                    // Ran to completion: the exact finalize IS the offline
                    // answer, at fraction 1.0.
                    fraction_sum += 1.0;
                    decision_sample_sum += r.cpu_noisy.len() as f64;
                    agree += 1;
                }
            }
        }
    }

    SizeResult {
        db: idx.len(),
        sessions,
        agreement: agree as f64 / sessions as f64,
        early_rate: early as f64 / sessions as f64,
        mean_fraction: fraction_sum / sessions as f64,
        mean_decision_sample: decision_sample_sum / sessions as f64,
        mean_session_ms: wall_sum / sessions as f64 * 1e3,
        culled_per_session: stream.culled as f64 / sessions as f64,
        stream,
    }
}

fn main() {
    mrtuner::util::logging::init();
    let smoke = std::env::var("MRTUNER_BENCH_SMOKE").is_ok();
    let sc = SystemConfig {
        use_runtime: false,
        ..SystemConfig::default()
    };

    // (db config sets, session config sets): DB entries = configs x 5
    // apps, sessions = session configs x 5 apps.
    let plan: &[(usize, usize)] = if smoke {
        &[(10, 4)] // DB=50, 20 sessions
    } else {
        &[(10, 10), (100, 20)] // DB=50 (50 sessions), DB=500 (100 sessions)
    };

    let mut size_rows = Vec::new();
    for &(db_configs, session_configs) in plan {
        println!("== streaming classification, DB = {} entries ==", db_configs * AppId::all().len());
        let r = run_size(db_configs, session_configs, &sc);
        println!(
            "  sessions={} agreement={:.1}% early={:.1}% mean_fraction={:.2} mean_decision_sample={:.0} mean_session={:.2}ms culled/session={:.1}",
            r.sessions,
            r.agreement * 100.0,
            r.early_rate * 100.0,
            r.mean_fraction,
            r.mean_decision_sample,
            r.mean_session_ms,
            r.culled_per_session,
        );
        println!("  work: {}", r.stream);
        if r.db >= 500 {
            let pass = r.agreement >= 0.95 && r.mean_fraction <= 0.60;
            println!(
                "  acceptance (DB=500): agreement >= 95% and mean_fraction <= 0.60: {}",
                if pass { "PASS" } else { "FAIL" }
            );
        }
        size_rows.push(Json::obj(vec![
            ("db", Json::Num(r.db as f64)),
            ("sessions", Json::Num(r.sessions as f64)),
            ("agreement", Json::Num(r.agreement)),
            ("early_rate", Json::Num(r.early_rate)),
            ("mean_fraction", Json::Num(r.mean_fraction)),
            ("mean_decision_sample", Json::Num(r.mean_decision_sample)),
            ("mean_session_ms", Json::Num(r.mean_session_ms)),
            ("culled_per_session", Json::Num(r.culled_per_session)),
            ("lb_evals", Json::Num(r.stream.lb_evals as f64)),
            ("dp_evals", Json::Num(r.stream.dp_evals as f64)),
            ("dp_abandoned", Json::Num(r.stream.dp_abandoned as f64)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("stream_perf".into())),
        ("smoke", Json::Bool(smoke)),
        ("feed_batch", Json::Num(FEED_BATCH as f64)),
        ("sizes", Json::arr(size_rows)),
    ]);
    std::fs::write("BENCH_stream.json", report.to_pretty()).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
}
