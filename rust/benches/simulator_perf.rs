//! Perf bench (substrate): discrete-event simulator throughput — events/s
//! and wall time per simulated job across job sizes and cluster scales,
//! plus an ablation of speculative execution (DESIGN.md design choice).
//!
//! Run with: `cargo bench --bench simulator_perf`

#[path = "harness.rs"]
mod harness;

use harness::bench;
use mrtuner::signal::noise::NoiseModel;
use mrtuner::simulator::cluster::ClusterConfig;
use mrtuner::simulator::engine::simulate;
use mrtuner::simulator::job::JobConfig;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::{workload_for, AppId};

fn main() {
    mrtuner::util::logging::init();
    println!("== simulator throughput ==");
    for (label, cfg) in [
        ("small  (M=8,  I=50MB) ", JobConfig::new(8, 4, 10.0, 50.0)),
        ("medium (M=21, I=80MB) ", JobConfig::new(21, 30, 10.0, 80.0)),
        ("large  (M=42, I=500MB)", JobConfig::new(42, 33, 20.0, 500.0)),
    ] {
        for app in [AppId::WordCount, AppId::TeraSort] {
            let w = workload_for(app);
            let cluster = ClusterConfig::pseudo_distributed();
            let mut events = 0u64;
            let stats = bench(&format!("{label} {:10}", app.name()), 2, 10, || {
                let r = simulate(w.as_ref(), &cfg, &cluster, &NoiseModel::default(), &mut Rng::new(7));
                events = r.counters.events;
                r.completion_secs
            });
            println!(
                "    -> {events} events, {:.0} events/ms, sim/wall ratio {:.0}x",
                events as f64 / (stats.mean_s * 1e3),
                {
                    let r = simulate(w.as_ref(), &cfg, &cluster, &NoiseModel::default(), &mut Rng::new(7));
                    r.completion_secs / stats.mean_s
                }
            );
        }
    }

    println!("\n== cluster scaling (WordCount, M=64, I=1GB) ==");
    let cfg = JobConfig::new(64, 16, 32.0, 1024.0);
    let w = workload_for(AppId::WordCount);
    for nodes in [1usize, 4, 16] {
        let cluster = ClusterConfig::cluster(nodes);
        bench(&format!("nodes={nodes:2}"), 1, 5, || {
            simulate(w.as_ref(), &cfg, &cluster, &NoiseModel::none(), &mut Rng::new(1)).completion_secs
        });
    }

    println!("\n== ablation: speculative execution under stragglers ==");
    let cfg = JobConfig::new(12, 4, 10.0, 60.0);
    for (label, speculative) in [("speculation off", false), ("speculation on ", true)] {
        let mut cluster = ClusterConfig::pseudo_distributed();
        cluster.speculative = speculative;
        cluster.task_jitter = 0.5;
        let mut mean_completion = 0.0;
        for seed in 0..20u64 {
            let r = simulate(w.as_ref(), &cfg, &cluster, &NoiseModel::none(), &mut Rng::new(seed));
            mean_completion += r.completion_secs / 20.0;
        }
        println!("  {label}: mean completion {mean_completion:.1}s over 20 seeds");
    }
}
