//! Property sweeps for the zero-allocation parallel DTW query engine:
//!
//! * scratch-arena kernels are bit-identical whether the arena is fresh
//!   per call (the seed's allocation behaviour) or reused forever;
//! * the cutoff-sharing parallel k-NN returns exactly the serial top-k
//!   (indices, order, bit-identical distances) with valid counters;
//! * the batched multi-query search equals the per-query search exactly,
//!   counters included, for any mix of query lengths;
//! * the batched matcher equals the per-app indexed matcher.

use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::database::store::ReferenceDb;
use mrtuner::dtw::banded::{
    dtw_banded, dtw_banded_distance_cutoff, dtw_banded_distance_cutoff_with, dtw_banded_with,
};
use mrtuner::dtw::fastdtw::{fastdtw, fastdtw_with};
use mrtuner::dtw::full::{dtw, dtw_distance_with, dtw_with};
use mrtuner::dtw::{band_radius, DtwScratch};
use mrtuner::index::{knn, knn_parallel, Envelope, IndexedDb, DEFAULT_BLOCK};
use mrtuner::prelude::*;
use mrtuner::streaming::anytime::{prefix_dtw, prefix_dtw_with};
use mrtuner::util::rng::Pcg32;
use mrtuner::workloads::AppId;

fn series(g: &mut Pcg32, len: usize) -> Vec<f64> {
    let mut v = 0.5;
    (0..len)
        .map(|_| {
            v = (v + (g.f64() - 0.5) * 0.2).clamp(0.0, 1.0);
            v
        })
        .collect()
}

#[test]
fn scratch_kernels_bit_identical_fresh_vs_reused() {
    // One arena reused across all rounds vs a fresh arena per call (the
    // seed's allocation pattern) vs the seed-signature wrappers: every
    // kernel must agree to the bit, paths included.
    let mut g = Pcg32::new(700, 1);
    let mut warm = DtwScratch::new();
    for round in 0..25 {
        let n = 2 + g.below(120) as usize;
        let m = 2 + g.below(120) as usize;
        let x = series(&mut g, n);
        let y = series(&mut g, m);
        let r = band_radius(n, m);

        let a = dtw_banded_with(&mut warm, &x, &y, r);
        let b = dtw_banded_with(&mut DtwScratch::new(), &x, &y, r);
        let c = dtw_banded(&x, &y, r);
        assert_eq!(a.distance.to_bits(), b.distance.to_bits(), "round {round}");
        assert_eq!(a.distance.to_bits(), c.distance.to_bits(), "round {round}");
        assert_eq!(a.path, b.path);
        assert_eq!(a.path, c.path);

        for cutoff in [f64::INFINITY, a.distance, a.distance * 0.6] {
            let ca = dtw_banded_distance_cutoff_with(&mut warm, &x, &y, r, cutoff);
            let cb = dtw_banded_distance_cutoff_with(&mut DtwScratch::new(), &x, &y, r, cutoff);
            let cc = dtw_banded_distance_cutoff(&x, &y, r, cutoff);
            assert_eq!(ca.map(f64::to_bits), cb.map(f64::to_bits), "round {round}");
            assert_eq!(ca.map(f64::to_bits), cc.map(f64::to_bits), "round {round}");
        }

        let fa = dtw_with(&mut warm, &x, &y);
        let fb = dtw(&x, &y);
        assert_eq!(fa.distance.to_bits(), fb.distance.to_bits());
        assert_eq!(fa.path, fb.path);
        let da = dtw_distance_with(&mut warm, &x, &y);
        let db = dtw_distance_with(&mut DtwScratch::new(), &x, &y);
        assert_eq!(da.to_bits(), db.to_bits());

        let ga = fastdtw_with(&mut warm, &x, &y, 4);
        let gb = fastdtw(&x, &y, 4);
        assert_eq!(ga.distance.to_bits(), gb.distance.to_bits());
        assert_eq!(ga.path, gb.path);

        let p = 1 + g.below(n as u32) as usize;
        let pa = prefix_dtw_with(&mut warm, &x[..p], &y, n, f64::INFINITY);
        let pb = prefix_dtw(&x[..p], &y, n, f64::INFINITY);
        match (pa, pb) {
            (Some(a), Some(b)) => {
                assert_eq!(a.row_min.to_bits(), b.row_min.to_bits(), "round {round}");
                assert_eq!(a.exact.map(f64::to_bits), b.exact.map(f64::to_bits));
            }
            (None, None) => {}
            other => panic!("round {round}: prefix DP disagreed: {other:?}"),
        }
    }
}

#[test]
fn parallel_knn_equals_serial_knn_across_seeds() {
    // For any database, query, k and worker count, the parallel engine
    // returns exactly the serial top-k: same candidates seen, same
    // neighbours in the same order, bit-identical distances, and counters
    // that still partition the candidate set.
    for seed in 1..=3u64 {
        let mut g = Pcg32::new(710 + seed, seed);
        let refs: Vec<Vec<f64>> = (0..120)
            .map(|_| series(&mut g, 30 + g.below(220) as usize))
            .collect();
        let envs: Vec<Envelope> = refs.iter().map(|s| Envelope::build(s, DEFAULT_BLOCK)).collect();
        let cands: Vec<(usize, &[f64], &Envelope)> = refs
            .iter()
            .zip(&envs)
            .enumerate()
            .map(|(i, (s, e))| (i, s.as_slice(), e))
            .collect();
        for qi in 0..4 {
            let q = series(&mut g, 40 + g.below(220) as usize);
            for k in [1usize, 3, 10] {
                let (serial, sstats) = knn(&q, cands.iter().copied(), k);
                for workers in [2usize, 3, 8] {
                    let (par, pstats) = knn_parallel(&q, &cands, k, workers);
                    assert_eq!(
                        par.len(),
                        serial.len(),
                        "seed {seed} q{qi} k={k} w={workers}"
                    );
                    for (a, b) in par.iter().zip(&serial) {
                        assert_eq!(a.index, b.index, "seed {seed} q{qi} k={k} w={workers}");
                        assert_eq!(
                            a.distance.to_bits(),
                            b.distance.to_bits(),
                            "seed {seed} q{qi} k={k} w={workers}: {} vs {}",
                            a.distance,
                            b.distance
                        );
                    }
                    assert_eq!(pstats.candidates, sstats.candidates);
                    assert_eq!(
                        pstats.pruned() + pstats.dtw_started(),
                        pstats.candidates,
                        "seed {seed}: counters do not partition"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_knn_equals_per_query_knn_across_seeds() {
    // Entry-major batching with shared envelope passes must be invisible:
    // per query, neighbours AND counters equal the standalone search.
    for seed in 1..=3u64 {
        let mut g = Pcg32::new(720 + seed, seed);
        let mut idx = IndexedDb::new();
        for i in 0..35usize {
            let len = 30 + g.below(250) as usize;
            idx.insert(ProfileEntry {
                app: AppId::all()[i % AppId::all().len()],
                config: JobConfig::new(1 + i, 2, 10.0, 20.0),
                series: series(&mut g, len),
                raw_len: len,
                completion_secs: 1.0,
            });
        }
        // Length profile with heavy duplication (the sharing case) plus
        // unique lengths and one PAA-skipping short query.
        let lens = [128usize, 128, 128, 64, 200, 64, 40, 128, 96];
        let queries: Vec<Vec<f64>> = lens.iter().map(|&l| series(&mut g, l)).collect();
        let qrefs: Vec<&[f64]> = queries.iter().map(Vec::as_slice).collect();
        for k in [1usize, 4] {
            let batch = idx.knn_batch(&qrefs, k);
            assert_eq!(batch.len(), qrefs.len());
            for (qi, q) in qrefs.iter().enumerate() {
                let (want, wstats) = idx.knn(q, k);
                let (got, gstats) = &batch[qi];
                assert_eq!(got.len(), want.len(), "seed {seed} query {qi} k={k}");
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.index, b.index, "seed {seed} query {qi} k={k}");
                    assert_eq!(a.distance.to_bits(), b.distance.to_bits());
                }
                assert_eq!(*gstats, wstats, "seed {seed} query {qi} k={k}");
            }
        }
        // Config-scoped batches agree with the scoped per-query search.
        let label = idx.entries()[0].config_key();
        let scoped = idx.knn_batch_in_config(&qrefs, &label, 2);
        for (qi, q) in qrefs.iter().enumerate() {
            let (want, wstats) = idx.knn_in_config(q, &label, 2);
            assert_eq!(scoped[qi].0.len(), want.len());
            for (a, b) in scoped[qi].0.iter().zip(&want) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.distance.to_bits(), b.distance.to_bits());
            }
            assert_eq!(scoped[qi].1, wstats);
        }
    }
}

#[test]
fn db_parallel_knn_equals_serial_through_the_wrapper() {
    let mut g = Pcg32::new(730, 1);
    let mut idx = IndexedDb::new();
    for i in 0..60usize {
        let len = 40 + g.below(200) as usize;
        idx.insert(ProfileEntry {
            app: AppId::WordCount,
            config: JobConfig::new(1 + i, 2, 10.0, 20.0),
            series: series(&mut g, len),
            raw_len: len,
            completion_secs: 1.0,
        });
    }
    for _ in 0..5 {
        let q = series(&mut g, 60 + g.below(200) as usize);
        let (serial, _) = idx.knn(&q, 3);
        let (par, pstats) = idx.knn_parallel(&q, 3, 8);
        assert_eq!(par.len(), serial.len());
        for (a, b) in par.iter().zip(&serial) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
        }
        assert_eq!(pstats.candidates, 60);
    }
}

#[test]
fn batched_matcher_equals_per_app_matcher_end_to_end() {
    // Full-pipeline equivalence: profiling + batched per-config search +
    // correlation re-rank must reproduce the per-app indexed matcher.
    let sc = SystemConfig {
        workers: 2,
        use_runtime: false,
        ..SystemConfig::default()
    };
    let grid = ConfigGrid::small(11);
    let profiler = Profiler::new(&sc, None);
    let mut db = ReferenceDb::new();
    for app in [AppId::WordCount, AppId::TeraSort] {
        for e in profiler.profile(app, &grid) {
            db.insert(e);
        }
    }
    let idx = IndexedDb::from_db(db);
    let m = Matcher::new(&sc, None);
    let apps = [AppId::EximParse, AppId::TeraSort];
    let batch = m.match_apps_indexed(&apps, &grid, &idx, 2);
    assert_eq!(batch.len(), apps.len());
    for (i, &app) in apps.iter().enumerate() {
        let (want, wstats) = m.match_app_indexed(app, &grid, &idx, 2);
        assert_eq!(batch[i].0.winner, want.winner, "app {}", app.name());
        assert_eq!(batch[i].0.tally, want.tally, "app {}", app.name());
        assert_eq!(batch[i].1, wstats, "app {}", app.name());
        assert_eq!(batch[i].0.cells.len(), want.cells.len());
        for (a, b) in batch[i].0.votes.iter().zip(&want.votes) {
            assert_eq!(a.best_app, b.best_app, "config {}", a.config.label());
            assert_eq!(
                a.best_similarity.to_bits(),
                b.best_similarity.to_bits(),
                "config {}",
                a.config.label()
            );
        }
    }
}
