//! FIFO JobTracker: pending queues, reduce slow-start, wave accounting.

use std::collections::VecDeque;

/// Scheduling state for one job (Hadoop 0.20 FIFO semantics).
#[derive(Debug)]
pub struct JobTracker {
    pending_maps: VecDeque<usize>,
    pending_reduces: VecDeque<usize>,
    pub total_maps: usize,
    pub total_reduces: usize,
    pub completed_maps: usize,
    pub completed_reduces: usize,
    slowstart: f64,
}

impl JobTracker {
    pub fn new(num_maps: usize, num_reduces: usize, slowstart: f64) -> JobTracker {
        JobTracker {
            pending_maps: (0..num_maps).collect(),
            pending_reduces: (0..num_reduces).collect(),
            total_maps: num_maps,
            total_reduces: num_reduces,
            completed_maps: 0,
            completed_reduces: 0,
            slowstart: slowstart.clamp(0.0, 1.0),
        }
    }

    /// Maps needed before reducers may launch.
    fn slowstart_threshold(&self) -> usize {
        ((self.slowstart * self.total_maps as f64).ceil() as usize).min(self.total_maps)
    }

    /// True once reduce tasks are allowed to start.
    pub fn reducers_eligible(&self) -> bool {
        self.completed_maps >= self.slowstart_threshold()
    }

    /// Pop the next pending map task.
    pub fn next_map(&mut self) -> Option<usize> {
        self.pending_maps.pop_front()
    }

    /// Pop the next pending reduce task, honouring slow-start.
    pub fn next_reduce(&mut self) -> Option<usize> {
        if self.reducers_eligible() {
            self.pending_reduces.pop_front()
        } else {
            None
        }
    }

    pub fn has_pending_maps(&self) -> bool {
        !self.pending_maps.is_empty()
    }

    pub fn has_pending_reduces(&self) -> bool {
        !self.pending_reduces.is_empty()
    }

    pub fn on_map_complete(&mut self) {
        self.completed_maps += 1;
        debug_assert!(self.completed_maps <= self.total_maps);
    }

    pub fn on_reduce_complete(&mut self) {
        self.completed_reduces += 1;
        debug_assert!(self.completed_reduces <= self.total_reduces);
    }

    pub fn all_done(&self) -> bool {
        self.completed_maps == self.total_maps && self.completed_reduces == self.total_reduces
    }

    /// Number of map waves on a cluster with `slots` map slots.
    pub fn map_waves(&self, slots: usize) -> usize {
        self.total_maps.div_ceil(slots.max(1))
    }

    /// Drain every not-yet-scheduled map (mid-run reconfiguration): the
    /// drained logical ids leave the job entirely, so `total_maps` shrinks
    /// by the drained count. Running and completed maps are untouched.
    pub fn take_pending_maps(&mut self) -> Vec<usize> {
        let drained: Vec<usize> = self.pending_maps.drain(..).collect();
        self.total_maps -= drained.len();
        drained
    }

    /// Enqueue replacement map tasks (by logical id) planned under a new
    /// configuration; they join the back of the FIFO queue.
    pub fn add_pending_maps(&mut self, ids: impl IntoIterator<Item = usize>) {
        let before = self.pending_maps.len();
        self.pending_maps.extend(ids);
        self.total_maps += self.pending_maps.len() - before;
    }

    /// Replace the reduce side wholesale with `num_reduces` fresh slots
    /// (only valid while no reduce has completed — the engine gates this
    /// on all running reducers still being in their startup phase).
    pub fn reset_reduces(&mut self, num_reduces: usize) {
        debug_assert_eq!(self.completed_reduces, 0);
        self.pending_reduces = (0..num_reduces).collect();
        self.total_reduces = num_reduces;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut jt = JobTracker::new(3, 2, 0.0);
        assert_eq!(jt.next_map(), Some(0));
        assert_eq!(jt.next_map(), Some(1));
        assert_eq!(jt.next_map(), Some(2));
        assert_eq!(jt.next_map(), None);
    }

    #[test]
    fn slowstart_gates_reducers() {
        let mut jt = JobTracker::new(20, 2, 0.05);
        assert!(!jt.reducers_eligible());
        assert_eq!(jt.next_reduce(), None);
        jt.on_map_complete();
        assert!(jt.reducers_eligible()); // ceil(0.05*20)=1
        assert_eq!(jt.next_reduce(), Some(0));
    }

    #[test]
    fn slowstart_zero_starts_immediately() {
        let mut jt = JobTracker::new(5, 1, 0.0);
        assert!(jt.reducers_eligible());
        assert_eq!(jt.next_reduce(), Some(0));
    }

    #[test]
    fn all_done_tracking() {
        let mut jt = JobTracker::new(2, 1, 0.0);
        assert!(!jt.all_done());
        jt.on_map_complete();
        jt.on_map_complete();
        jt.on_reduce_complete();
        assert!(jt.all_done());
    }

    #[test]
    fn reconfigure_queues() {
        let mut jt = JobTracker::new(6, 3, 0.0);
        jt.next_map(); // 0 running
        jt.on_map_complete();
        let drained = jt.take_pending_maps();
        assert_eq!(drained, vec![1, 2, 3, 4, 5]);
        assert_eq!(jt.total_maps, 1);
        assert_eq!(jt.completed_maps, 1); // map side momentarily complete
        jt.add_pending_maps([10, 11, 12]);
        assert_eq!(jt.total_maps, 4);
        assert_eq!(jt.next_map(), Some(10)); // FIFO over the new ids
        jt.reset_reduces(5);
        assert_eq!(jt.total_reduces, 5);
        assert_eq!(jt.next_reduce(), Some(0));
        assert!(jt.has_pending_reduces());
    }

    #[test]
    fn wave_math() {
        let jt = JobTracker::new(11, 1, 0.05);
        assert_eq!(jt.map_waves(2), 6);
        assert_eq!(jt.map_waves(4), 3);
        assert_eq!(jt.map_waves(16), 1);
    }
}
