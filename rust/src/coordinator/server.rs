//! Match-as-a-service: a line-delimited JSON protocol over TCP.
//!
//! Requests (one JSON object per line):
//!   {"cmd": "ping"}
//!   {"cmd": "stats"}
//!   {"cmd": "apps"}
//!   {"cmd": "match", "series": [..], "config": {"mappers": M, "reducers": R,
//!    "split_mb": FS, "input_mb": I}}
//!   {"cmd": "knn", "series": [..], "k": K[, "config": {..}]}
//!   {"cmd": "knn_batch", "queries": [[..], ..], "k": K[, "config": {..}]}
//!   {"cmd": "stream_open"[, "config": {..}][, "final_len": N][, "max_len": N]
//!    [, "min_fraction": F][, "margin": M][, "min_samples": S]}
//!   {"cmd": "stream_feed", "session": ID, "samples": [..]}
//!   {"cmd": "stream_poll", "session": ID[, "k": K]}
//!   {"cmd": "stream_poll_all"[, "k": K]}
//!   {"cmd": "stream_close", "session": ID}
//!
//! The `match` request carries a *raw* captured CPU series (what a real
//! deployment's SysStat agent would send); the server preprocesses it,
//! compares against every stored reference under the same configuration
//! set, and answers with the per-app similarities and the best match.
//!
//! The `knn` request runs the lower-bound-cascade index instead: the k
//! nearest references under the banded-DTW distance — over the whole
//! database, or one configuration set when `config` is given — plus each
//! neighbour's correlation similarity and the pruning counters for this
//! search. Whole-database searches are scored across the worker cores
//! with a shared early-abandoning cutoff (`IndexedDb::knn_parallel`,
//! result identical to the serial scan). `knn_batch` carries many queries
//! in one request and answers them in one entry-major pass that shares
//! envelope work across same-length queries (`IndexedDb::knn_batch`); the
//! per-batch size and latency land in the metrics report. The state holds
//! an [`IndexedDb`], so concurrent connections share one immutable
//! envelope cache.
//!
//! The `stream_*` commands expose the online classifier
//! (`crate::streaming`): `stream_open` registers a live session (scoped to
//! one configuration set, or the whole database), `stream_feed` ingests
//! raw CPU sample batches and reports the anytime state (including the
//! early decision the moment the session's exit policy declares one),
//! `stream_poll` returns the current top-k without feeding, and
//! `stream_close` finalizes with the exact indexed search over the full
//! capture. Because live streams hold their connection open for the whole
//! job, the read loop tolerates idle timeouts instead of dropping the
//! peer: each timeout tick re-checks the server stop flag (so shutdown is
//! never wedged by a blocked read) and sweeps sessions abandoned by dead
//! clients.

use super::batcher::{prepare_query, similarities_auto};
use super::metrics::Metrics;
use crate::dtw::corr::MATCH_THRESHOLD;
use crate::index::{IndexedDb, SearchStats};
use crate::runtime::RuntimeHandle;
use crate::simulator::job::JobConfig;
use crate::streaming::{
    DecisionPolicy, FinalLen, SessionManager, StreamDecision, StreamSession, TopEntry,
    MAX_STREAM_LEN,
};
use crate::util::json::Json;
use crate::util::pool::{default_workers, ThreadPool};
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Per-connection read timeout: the cadence at which blocked readers
/// re-check the stop flag and sweep idle sessions. A single timeout does
/// NOT close the connection — live streams legitimately sit idle between
/// feeds — but a connection idle past [`CONN_IDLE`] is dropped, so a pool
/// worker can never be pinned for long by a dead client.
pub const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Connections idle this long are dropped. Harmless to live streams:
/// sessions are addressed by id and survive reconnects, and a SysStat
/// feeder sends every few seconds anyway.
pub const CONN_IDLE: Duration = Duration::from_secs(60);

/// Sessions untouched for this long belong to dead clients and are
/// reaped (checked on every idle tick and on every `stream_open`, so
/// abandoned sessions die even when no connection is idling).
pub const SESSION_IDLE: Duration = Duration::from_secs(600);

/// Shared server state.
pub struct ServerState {
    pub db: IndexedDb,
    pub runtime: Option<RuntimeHandle>,
    pub metrics: Metrics,
    pub sessions: SessionManager,
}

/// The TCP server.
pub struct MatchServer {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
}

impl MatchServer {
    /// Bind to `addr` (use port 0 for an ephemeral port).
    pub fn bind(addr: &str, state: ServerState) -> Result<MatchServer> {
        let listener = TcpListener::bind(addr)?;
        Ok(MatchServer {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Local address (for tests with ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Stop handle: set true and connect once to unblock accept(). Workers
    /// blocked on idle connections notice within one [`READ_TIMEOUT`].
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until the stop flag is raised (default read timeout).
    pub fn serve(&self, workers: usize) -> Result<()> {
        self.serve_with(workers, READ_TIMEOUT)
    }

    /// Serve until the stop flag is raised. Each connection is handled on
    /// the pool; one line per request, one line per response.
    pub fn serve_with(&self, workers: usize, read_timeout: Duration) -> Result<()> {
        let pool = ThreadPool::new(workers.max(1));
        log::info!("serving on {}", self.listener.local_addr()?);
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    let stop = Arc::clone(&self.stop);
                    pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &state, &stop, read_timeout) {
                            log::debug!("connection ended: {e:#}");
                        }
                    });
                }
                Err(e) => log::warn!("accept failed: {e}"),
            }
        }
        Ok(())
    }
}

fn handle_connection(
    stream: TcpStream,
    state: &ServerState,
    stop: &AtomicBool,
    read_timeout: Duration,
) -> Result<()> {
    stream.set_read_timeout(Some(read_timeout))?;
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut last_activity = std::time::Instant::now();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => last_activity = std::time::Instant::now(),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle tick: keep the connection (a live stream may simply
                // have nothing to feed yet), sweep abandoned sessions, and
                // loop back to the stop-flag check so shutdown can never be
                // wedged by a blocked read. Partially read bytes stay in
                // `line` for the next pass. Connections idle past
                // [`CONN_IDLE`] are dropped so idle clients cannot pin
                // pool workers; their sessions live on until reaped.
                reap_sessions(state);
                if last_activity.elapsed() > CONN_IDLE {
                    log::debug!("dropping connection idle for {:?}", last_activity.elapsed());
                    break;
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        state.metrics.inc_requests();
        let response = state.metrics.time(|| match handle_request(line.trim(), state) {
            Ok(v) => v,
            Err(e) => {
                state.metrics.inc_errors();
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(format!("{e:#}"))),
                ])
            }
        });
        line.clear();
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    log::debug!("peer {peer} disconnected");
    Ok(())
}

/// Dispatch one request line.
pub fn handle_request(line: &str, state: &ServerState) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("pong", Json::Bool(true)),
        ])),
        Some("stats") => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("report", Json::Str(state.metrics.report())),
            ("db_entries", Json::Num(state.db.len() as f64)),
            ("live_sessions", Json::Num(state.sessions.len() as f64)),
        ])),
        Some("apps") => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "apps",
                Json::arr(
                    state
                        .db
                        .apps()
                        .iter()
                        .map(|a| Json::Str(a.name().to_string()))
                        .collect(),
                ),
            ),
        ])),
        Some("match") => handle_match(&req, state),
        Some("knn") => handle_knn(&req, state),
        Some("knn_batch") => handle_knn_batch(&req, state),
        Some("stream_open") => handle_stream_open(&req, state),
        Some("stream_feed") => handle_stream_feed(&req, state),
        Some("stream_poll") => handle_stream_poll(&req, state),
        Some("stream_poll_all") => handle_stream_poll_all(&req, state),
        Some("stream_close") => handle_stream_close(&req, state),
        _ => Err(anyhow!("unknown cmd")),
    }
}

/// Parse the optional/required pieces shared by `match` and `knn`.
fn parse_series(req: &Json) -> Result<Vec<f64>> {
    let series = req
        .get("series")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing series"))?
        .iter()
        .filter_map(Json::as_f64)
        .collect::<Vec<f64>>();
    if series.len() < 4 {
        return Err(anyhow!("series too short"));
    }
    Ok(series)
}

fn parse_config(v: &Json) -> Result<JobConfig> {
    let num = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("config missing {k}"))
    };
    Ok(JobConfig::new(
        num("mappers")? as usize,
        num("reducers")? as usize,
        num("split_mb")?,
        num("input_mb")?,
    ))
}

/// Sweep sessions abandoned by dead clients into the metrics counters.
fn reap_sessions(state: &ServerState) {
    let reaped = state.sessions.reap_idle(SESSION_IDLE);
    if reaped > 0 {
        state.metrics.add_stream_reaped(reaped as u64);
        log::debug!("reaped {reaped} idle stream sessions");
    }
}

fn parse_session_id(req: &Json) -> Result<u64> {
    req.get("session")
        .and_then(Json::as_usize)
        .map(|id| id as u64)
        .ok_or_else(|| anyhow!("missing session id"))
}

fn decision_json(d: &StreamDecision) -> Json {
    Json::obj(vec![
        ("app", Json::Str(d.app.name().to_string())),
        ("config", Json::Str(d.config.label())),
        ("entry", Json::Num(d.entry as f64)),
        ("distance", Json::Num(d.distance)),
        ("similarity", Json::Num(d.similarity)),
        ("at_sample", Json::Num(d.at_sample as f64)),
        ("fraction", Json::Num(d.fraction)),
    ])
}

/// Open a live classification session.
fn handle_stream_open(req: &Json, state: &ServerState) -> Result<Json> {
    // Every open sweeps stale sessions, so open-and-abandon clients cannot
    // grow the registry even when no connection ever sits idle.
    reap_sessions(state);
    let config = match req.get("config") {
        Some(c) => Some(parse_config(c)?),
        None => None,
    };
    // A Known hint beyond the incremental cap only wastes DP width and
    // disables the fraction gate; clamp it like max_len.
    let final_len = match req.get("final_len").and_then(Json::as_usize) {
        Some(n) if n > 0 => FinalLen::Known(n.min(MAX_STREAM_LEN)),
        _ => FinalLen::AtMost(
            req.get("max_len")
                .and_then(Json::as_usize)
                .unwrap_or(MAX_STREAM_LEN)
                .clamp(1, MAX_STREAM_LEN),
        ),
    };
    let mut policy = DecisionPolicy::default();
    if let Some(f) = req.get("min_fraction").and_then(Json::as_f64) {
        policy.min_fraction = f.clamp(0.0, 2.0);
    }
    if let Some(m) = req.get("margin").and_then(Json::as_f64) {
        policy.margin = m.max(1.0);
    }
    if let Some(s) = req.get("min_samples").and_then(Json::as_usize) {
        policy.min_samples = s;
    }
    let session = StreamSession::open(&state.db, config.as_ref(), final_len, policy);
    let candidates = session.candidates();
    let id = state.sessions.open(session);
    state.metrics.inc_stream_opened();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("session", Json::Num(id as f64)),
        ("candidates", Json::Num(candidates as f64)),
    ]))
}

/// Feed one batch of raw CPU samples into a live session.
fn handle_stream_feed(req: &Json, state: &ServerState) -> Result<Json> {
    let id = parse_session_id(req)?;
    let samples: Vec<f64> = req
        .get("samples")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing samples"))?
        .iter()
        .filter_map(Json::as_f64)
        .collect();
    if samples.is_empty() {
        return Err(anyhow!("empty samples"));
    }
    let (decided_now, decision, observed, live) = state.sessions.with(id, |s| {
        let had = s.decision().is_some();
        s.push(&state.db, &samples);
        let d = s.decision().cloned();
        (d.is_some() && !had, d, s.observed(), s.live_candidates())
    })?;
    if decided_now {
        if let Some(d) = &decision {
            state.metrics.record_stream_decision(d.at_sample, d.fraction);
        }
    }
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("observed", Json::Num(observed as f64)),
        ("live_candidates", Json::Num(live as f64)),
        (
            "decision",
            decision.as_ref().map(decision_json).unwrap_or(Json::Null),
        ),
    ]))
}

/// Anytime top rows shared by `stream_poll` and `stream_poll_all`.
fn top_json(top: &[TopEntry]) -> Json {
    Json::arr(
        top.iter()
            .map(|t| {
                Json::obj(vec![
                    ("app", Json::Str(t.app.name().to_string())),
                    ("config", Json::Str(t.config.label())),
                    ("entry", Json::Num(t.entry as f64)),
                    (
                        "distance",
                        t.distance.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("lower_bound", Json::Num(t.lower_bound)),
                ])
            })
            .collect(),
    )
}

/// Report a live session's anytime top-k without feeding it.
fn handle_stream_poll(req: &Json, state: &ServerState) -> Result<Json> {
    let id = parse_session_id(req)?;
    let k = req.get("k").and_then(Json::as_usize).unwrap_or(3).clamp(1, 20);
    let (top, decision, observed, live, culled) = state.sessions.with(id, |s| {
        (
            s.top(&state.db, k),
            s.decision().cloned(),
            s.observed(),
            s.live_candidates(),
            s.stats().culled,
        )
    })?;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("observed", Json::Num(observed as f64)),
        ("live_candidates", Json::Num(live as f64)),
        ("culled", Json::Num(culled as f64)),
        ("top", top_json(&top)),
        (
            "decision",
            decision.as_ref().map(decision_json).unwrap_or(Json::Null),
        ),
    ]))
}

/// Snapshot every live session in one request — the fleet dashboard's
/// poll, backed by `SessionManager::poll_all`.
fn handle_stream_poll_all(req: &Json, state: &ServerState) -> Result<Json> {
    let k = req.get("k").and_then(Json::as_usize).unwrap_or(3).clamp(1, 20);
    let polls = state.sessions.poll_all(&state.db, k);
    let rows = polls
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("session", Json::Num(p.id as f64)),
                ("observed", Json::Num(p.observed as f64)),
                ("live_candidates", Json::Num(p.live_candidates as f64)),
                ("culled", Json::Num(p.culled as f64)),
                ("top", top_json(&p.top)),
                (
                    "decision",
                    p.decision.as_ref().map(decision_json).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("sessions", Json::arr(rows)),
    ]))
}

/// Close a session: exact final search over the whole capture.
fn handle_stream_close(req: &Json, state: &ServerState) -> Result<Json> {
    let id = parse_session_id(req)?;
    let session = state.sessions.close(id)?;
    state.metrics.inc_stream_closed();
    state.metrics.record_stream_session(&session.stats());
    let (neighbors, stats) = session.finalize(&state.db, 1);
    state.metrics.record_search(&stats);
    let entries = state.db.entries();
    let final_json = match neighbors.first() {
        Some(nb) => {
            let e = &entries[nb.index];
            let q = prepare_query(session.raw());
            let sim = crate::dtw::corr::similarity_percent_banded(&q, &e.series);
            Json::obj(vec![
                ("app", Json::Str(e.app.name().to_string())),
                ("config", Json::Str(e.config_key())),
                ("entry", Json::Num(nb.index as f64)),
                ("distance", Json::Num(nb.distance)),
                ("similarity", Json::Num(sim)),
                ("matched", Json::Bool(sim >= MATCH_THRESHOLD)),
            ])
        }
        None => Json::Null,
    };
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("observed", Json::Num(session.observed() as f64)),
        ("final", final_json),
        (
            "decision",
            session.decision().map(decision_json).unwrap_or(Json::Null),
        ),
    ]))
}

/// Pruning counters as a response object.
fn stats_json(stats: &SearchStats) -> Json {
    Json::obj(vec![
        ("candidates", Json::Num(stats.candidates as f64)),
        ("pruned_lb_kim", Json::Num(stats.pruned_lb_kim as f64)),
        ("pruned_lb_paa", Json::Num(stats.pruned_lb_paa as f64)),
        ("pruned_lb_keogh", Json::Num(stats.pruned_lb_keogh as f64)),
        ("abandoned", Json::Num(stats.abandoned as f64)),
        ("dtw_evals", Json::Num(stats.dtw_evals as f64)),
    ])
}

/// One neighbour as a response row (with its correlation similarity).
fn neighbor_json(state: &ServerState, q: &[f64], nb: &crate::index::Neighbor) -> Json {
    let e = &state.db.entries()[nb.index];
    Json::obj(vec![
        ("app", Json::Str(e.app.name().to_string())),
        ("config", Json::Str(e.config_key())),
        ("distance", Json::Num(nb.distance)),
        (
            "similarity",
            Json::Num(crate::dtw::corr::similarity_percent_banded(q, &e.series)),
        ),
    ])
}

/// Whole-DB k-NN searches currently fanning out (process-wide). The
/// physical cores are one shared budget: a lone request gets them all,
/// concurrent requests split them, so CPU-bound scan threads never
/// oversubscribe the machine however many pool workers are serving.
static KNN_IN_FLIGHT: AtomicUsize = AtomicUsize::new(0);

/// RAII share of the core budget for one whole-DB search.
struct KnnFanout;

impl KnnFanout {
    fn enter() -> KnnFanout {
        KNN_IN_FLIGHT.fetch_add(1, Ordering::Relaxed);
        KnnFanout
    }
    /// Cores this search may use: total divided by searches in flight
    /// (including this one), floored at 1 (= serial scan).
    fn workers(&self) -> usize {
        (default_workers() / KNN_IN_FLIGHT.load(Ordering::Relaxed).max(1)).max(1)
    }
}

impl Drop for KnnFanout {
    fn drop(&mut self) {
        KNN_IN_FLIGHT.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Index-backed k-NN: exact nearest references under the banded-DTW
/// distance via the lower-bound cascade. Whole-database searches fan the
/// candidate scan over the cores with a shared cutoff
/// (`IndexedDb::knn_parallel`, result identical to the serial scan),
/// splitting the core budget across concurrent requests; config-scoped
/// buckets are small and stay serial.
fn handle_knn(req: &Json, state: &ServerState) -> Result<Json> {
    let series = parse_series(req)?;
    let k = req
        .get("k")
        .and_then(Json::as_usize)
        .unwrap_or(1)
        .clamp(1, 100);
    let q = prepare_query(&series);
    let (neighbors, stats) = match req.get("config") {
        Some(cfg) => state.db.knn_in_config(&q, &parse_config(cfg)?.label(), k),
        None => {
            let fanout = KnnFanout::enter();
            state.db.knn_parallel(&q, k, fanout.workers())
        }
    };
    state.metrics.record_search(&stats);
    state.metrics.inc_comparisons(stats.dtw_evals);

    let results = neighbors.iter().map(|nb| neighbor_json(state, &q, nb)).collect();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("neighbors", Json::arr(results)),
        ("stats", stats_json(&stats)),
    ]))
}

/// Largest accepted `knn_batch` request — bounds per-request work the
/// same way `k` is clamped.
const MAX_KNN_BATCH: usize = 256;

/// Batched k-NN: many queries answered in one entry-major pass that
/// shares envelope work across same-length queries. Response carries one
/// result row per query (input order) plus the merged pruning counters;
/// the batch size and wall-clock land in the metrics registry.
fn handle_knn_batch(req: &Json, state: &ServerState) -> Result<Json> {
    let queries_json = req
        .get("queries")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing queries"))?;
    if queries_json.is_empty() {
        return Err(anyhow!("empty queries"));
    }
    if queries_json.len() > MAX_KNN_BATCH {
        return Err(anyhow!(
            "batch too large ({} queries, max {MAX_KNN_BATCH})",
            queries_json.len()
        ));
    }
    let k = req
        .get("k")
        .and_then(Json::as_usize)
        .unwrap_or(1)
        .clamp(1, 100);
    let mut prepared: Vec<Vec<f64>> = Vec::with_capacity(queries_json.len());
    for (qi, qj) in queries_json.iter().enumerate() {
        let series: Vec<f64> = qj
            .as_arr()
            .ok_or_else(|| anyhow!("query {qi}: not an array"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        if series.len() < 4 {
            return Err(anyhow!("query {qi}: series too short"));
        }
        prepared.push(prepare_query(&series));
    }
    let qrefs: Vec<&[f64]> = prepared.iter().map(Vec::as_slice).collect();
    let t0 = std::time::Instant::now();
    let results = match req.get("config") {
        Some(cfg) => state
            .db
            .knn_batch_in_config(&qrefs, &parse_config(cfg)?.label(), k),
        None => state.db.knn_batch(&qrefs, k),
    };
    state
        .metrics
        .record_knn_batch(qrefs.len() as u64, t0.elapsed().as_secs_f64());

    let mut merged = SearchStats::default();
    let rows = results
        .iter()
        .zip(&prepared)
        .map(|((neighbors, stats), q)| {
            merged.merge(stats);
            Json::obj(vec![
                (
                    "neighbors",
                    Json::arr(neighbors.iter().map(|nb| neighbor_json(state, q, nb)).collect()),
                ),
                ("stats", stats_json(stats)),
            ])
        })
        .collect();
    state.metrics.record_search(&merged);
    state.metrics.inc_comparisons(merged.dtw_evals);
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("results", Json::arr(rows)),
        ("stats", stats_json(&merged)),
    ]))
}

fn handle_match(req: &Json, state: &ServerState) -> Result<Json> {
    let series = parse_series(req)?;
    let config = parse_config(
        req.get("config")
            .ok_or_else(|| anyhow!("match: missing config"))?,
    )?;

    let refs = state.db.by_config(&config.label());
    let ref_series: Vec<Vec<f64>> = refs.iter().map(|e| e.series.clone()).collect();
    let sims = similarities_auto(state.runtime.as_ref(), &series, &ref_series);
    state.metrics.inc_comparisons(sims.len() as u64);

    let mut results = Vec::new();
    let mut best: Option<(&str, f64)> = None;
    for (e, s) in refs.iter().zip(&sims) {
        results.push(Json::obj(vec![
            ("app", Json::Str(e.app.name().to_string())),
            ("similarity", Json::Num(*s)),
        ]));
        if best.map_or(true, |(_, bs)| *s > bs) {
            best = Some((e.app.name(), *s));
        }
    }
    let (match_app, match_sim) = match best {
        Some((a, s)) if s >= MATCH_THRESHOLD => (Json::Str(a.to_string()), Json::Num(s)),
        Some((_, s)) => (Json::Null, Json::Num(s)),
        None => (Json::Null, Json::Num(0.0)),
    };
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("results", Json::arr(results)),
        ("match", match_app),
        ("best_similarity", match_sim),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::database::profile::ProfileEntry;
    use crate::workloads::AppId;

    fn raw_wave(freq: f64) -> Vec<f64> {
        (0..64)
            .map(|i| (0.5 + 0.4 * ((i as f64) * freq).sin()).clamp(0.0, 1.0))
            .collect()
    }

    fn state_with_db() -> ServerState {
        let mut db = IndexedDb::new();
        let series = raw_wave(0.2);
        db.insert(ProfileEntry {
            app: AppId::WordCount,
            config: JobConfig::new(4, 2, 10.0, 20.0),
            series: crate::signal::preprocess(&series),
            raw_len: 64,
            completion_secs: 100.0,
        });
        let shifted = raw_wave(0.55);
        db.insert(ProfileEntry {
            app: AppId::TeraSort,
            config: JobConfig::new(4, 2, 10.0, 20.0),
            series: crate::signal::preprocess(&shifted),
            raw_len: 64,
            completion_secs: 80.0,
        });
        ServerState {
            db,
            runtime: None,
            metrics: Metrics::new(),
            sessions: SessionManager::new(),
        }
    }

    fn config_json() -> Json {
        Json::obj(vec![
            ("mappers", Json::Num(4.0)),
            ("reducers", Json::Num(2.0)),
            ("split_mb", Json::Num(10.0)),
            ("input_mb", Json::Num(20.0)),
        ])
    }

    #[test]
    fn ping_roundtrip() {
        let state = state_with_db();
        let resp = handle_request(r#"{"cmd":"ping"}"#, &state).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn match_request_finds_similar_series() {
        let state = state_with_db();
        let series: Vec<f64> = raw_wave(0.2);
        let req = Json::obj(vec![
            ("cmd", Json::Str("match".into())),
            ("series", Json::nums(&series)),
            ("config", config_json()),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let best = resp.get("best_similarity").and_then(Json::as_f64).unwrap();
        assert!(best > 90.0, "best={best}");
        assert_eq!(resp.get("match").and_then(Json::as_str), Some("wordcount"));
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        let state = state_with_db();
        assert!(handle_request("not json", &state).is_err());
        assert!(handle_request(r#"{"cmd":"nope"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"match"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn","series":[1,2]}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_feed","samples":[1]}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_feed","session":99,"samples":[0.5]}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_poll","session":99}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_close","session":99}"#, &state).is_err());
    }

    #[test]
    fn knn_request_returns_neighbors_and_stats() {
        let state = state_with_db();
        let series: Vec<f64> = raw_wave(0.2);
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(2.0)),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let neighbors = resp.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(neighbors.len(), 2);
        // The untouched sine is the query itself: distance 0, first.
        assert_eq!(
            neighbors[0].get("app").and_then(Json::as_str),
            Some("wordcount")
        );
        assert_eq!(neighbors[0].get("distance").and_then(Json::as_f64), Some(0.0));
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("candidates").and_then(Json::as_f64), Some(2.0));
        // The search was folded into the shared metrics registry.
        assert_eq!(state.metrics.search_stats().candidates, 2);

        // Config-scoped search sees only that bucket.
        let scoped = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(5.0)),
            ("config", config_json()),
        ]);
        let resp = handle_request(&scoped.to_string(), &state).unwrap();
        let neighbors = resp.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(neighbors.len(), 2, "both entries share the config set");
    }

    #[test]
    fn knn_batch_request_answers_every_query() {
        let state = state_with_db();
        let q1 = raw_wave(0.2); // wordcount-shaped
        let q2 = raw_wave(0.55); // terasort-shaped
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn_batch".into())),
            ("queries", Json::arr(vec![Json::nums(&q1), Json::nums(&q2)])),
            ("k", Json::Num(1.0)),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let results = resp.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        let top_app = |i: usize| {
            results[i]
                .get("neighbors")
                .and_then(Json::as_arr)
                .unwrap()[0]
                .get("app")
                .and_then(Json::as_str)
                .unwrap()
                .to_string()
        };
        assert_eq!(top_app(0), "wordcount");
        assert_eq!(top_app(1), "terasort");
        // Merged counters: 2 queries x 2 candidates.
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("candidates").and_then(Json::as_f64), Some(4.0));
        let (batches, queries, _) = state.metrics.knn_batch_summary();
        assert_eq!((batches, queries), (1, 2));
        assert_eq!(state.metrics.search_stats().candidates, 4);

        // Malformed batches error cleanly.
        assert!(handle_request(r#"{"cmd":"knn_batch"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn_batch","queries":[]}"#, &state).is_err());
        assert!(
            handle_request(r#"{"cmd":"knn_batch","queries":[[1,2]]}"#, &state).is_err(),
            "short series accepted"
        );
    }

    #[test]
    fn stream_poll_all_snapshots_sessions() {
        let state = state_with_db();
        for _ in 0..2 {
            let open = Json::obj(vec![
                ("cmd", Json::Str("stream_open".into())),
                ("config", config_json()),
                ("final_len", Json::Num(64.0)),
            ]);
            handle_request(&open.to_string(), &state).unwrap();
        }
        // Feed only the first session.
        let feed = Json::obj(vec![
            ("cmd", Json::Str("stream_feed".into())),
            ("session", Json::Num(1.0)),
            ("samples", Json::nums(&raw_wave(0.2)[..16])),
        ]);
        handle_request(&feed.to_string(), &state).unwrap();
        let resp =
            handle_request(r#"{"cmd":"stream_poll_all","k":2}"#, &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let sessions = resp.get("sessions").and_then(Json::as_arr).unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].get("session").and_then(Json::as_f64), Some(1.0));
        assert_eq!(sessions[0].get("observed").and_then(Json::as_f64), Some(16.0));
        assert_eq!(sessions[1].get("observed").and_then(Json::as_f64), Some(0.0));
        assert!(sessions[0].get("top").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn stream_lifecycle_end_to_end() {
        let state = state_with_db();
        // Open a session scoped to the stored config set.
        let open = Json::obj(vec![
            ("cmd", Json::Str("stream_open".into())),
            ("config", config_json()),
            ("final_len", Json::Num(64.0)),
        ]);
        let resp = handle_request(&open.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("candidates").and_then(Json::as_f64), Some(2.0));
        let id = resp.get("session").and_then(Json::as_f64).unwrap();
        assert_eq!(state.sessions.len(), 1);

        // Feed the wordcount-shaped capture in batches.
        let series = raw_wave(0.2);
        let mut decided = false;
        for chunk in series.chunks(16) {
            let feed = Json::obj(vec![
                ("cmd", Json::Str("stream_feed".into())),
                ("session", Json::Num(id)),
                ("samples", Json::nums(chunk)),
            ]);
            let resp = handle_request(&feed.to_string(), &state).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            decided |= resp.get("decision") != Some(&Json::Null);
        }

        // Poll: the anytime top-1 must be the wordcount reference.
        let poll = Json::obj(vec![
            ("cmd", Json::Str("stream_poll".into())),
            ("session", Json::Num(id)),
            ("k", Json::Num(2.0)),
        ]);
        let resp = handle_request(&poll.to_string(), &state).unwrap();
        let top = resp.get("top").and_then(Json::as_arr).unwrap();
        assert!(!top.is_empty());
        assert_eq!(top[0].get("app").and_then(Json::as_str), Some("wordcount"));
        assert_eq!(resp.get("observed").and_then(Json::as_f64), Some(64.0));

        // Close: exact final answer.
        let close = Json::obj(vec![
            ("cmd", Json::Str("stream_close".into())),
            ("session", Json::Num(id)),
        ]);
        let resp = handle_request(&close.to_string(), &state).unwrap();
        let final_obj = resp.get("final").expect("final result");
        assert_eq!(final_obj.get("app").and_then(Json::as_str), Some("wordcount"));
        assert_eq!(state.sessions.len(), 0);
        if decided {
            assert_eq!(state.metrics.stream_decisions.load(std::sync::atomic::Ordering::Relaxed), 1);
        }
        assert_eq!(state.metrics.stream_opened.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(state.metrics.stream_closed.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_knn_requests_share_the_index() {
        let state = std::sync::Arc::new(state_with_db());
        let series: Vec<f64> = raw_wave(0.2);
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(1.0)),
        ])
        .to_string();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let state = std::sync::Arc::clone(&state);
                let req = req.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let resp = handle_request(&req, &state).unwrap();
                        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
                    }
                });
            }
        });
        assert_eq!(state.metrics.search_stats().candidates, 8 * 20 * 2);
    }

    #[test]
    fn tcp_end_to_end() {
        let server = MatchServer::bind("127.0.0.1:0", state_with_db()).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_flag();
        let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "line={line}");

        stream.write_all(b"{\"cmd\":\"apps\"}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("wordcount"));

        drop(reader);
        drop(stream);
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // unblock accept
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn idle_connections_survive_timeouts_and_do_not_wedge_shutdown() {
        let server = MatchServer::bind("127.0.0.1:0", state_with_db()).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_flag();
        let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // Idle well past several read timeouts: the connection must still
        // be served (pre-fix behaviour was to drop it on the first one).
        std::thread::sleep(Duration::from_millis(200));
        stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "idle connection was dropped: {line}");

        // Shut down WITHOUT closing our connection: the worker blocked on
        // our socket must notice the stop flag within one timeout tick
        // (pre-fix behaviour held the pool open indefinitely).
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // unblock accept
        handle.join().unwrap().unwrap();
    }
}
