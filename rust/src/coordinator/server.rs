//! Match-as-a-service: a line-delimited JSON protocol over TCP.
//!
//! The wire surface is defined by [`crate::protocol`] (see `PROTOCOL.md`
//! at the repository root): every line is decoded into one typed
//! [`Request`], dispatched by [`dispatch`] into a typed [`Response`] or a
//! typed [`ServerError`], and rendered back in the envelope the line
//! arrived in. Protocol v2 wraps commands as
//! `{"v":2,"id":N,"type":"...",...}` with per-request ids (pipelining
//! safe); legacy v1 lines — any line without a `"v"` key — keep the
//! pre-envelope `{"cmd": ...}` command set and are answered
//! byte-compatibly (pinned by golden tests in
//! `rust/tests/server_protocol.rs`).
//!
//! Requests (v1 spelling; v2 uses `"type"` instead of `"cmd"` plus the
//! envelope keys):
//!   {"cmd": "ping"}
//!   {"cmd": "stats"}
//!   {"cmd": "apps"}
//!   {"cmd": "shard_info"}
//!   {"cmd": "match", "series": [..], "config": {"mappers": M, "reducers": R,
//!    "split_mb": FS, "input_mb": I}}
//!   {"cmd": "knn", "series": [..], "k": K[, "config": {..}]}
//!   {"cmd": "knn_batch", "queries": [[..], ..], "k": K[, "config": {..}]}
//!   {"cmd": "stream_open"[, "config": {..}][, "final_len": N][, "max_len": N]
//!    [, "min_fraction": F][, "margin": M][, "min_samples": S]}
//!   {"cmd": "stream_feed", "session": ID, "samples": [..][, "progress": P]}
//!   {"cmd": "stream_poll", "session": ID[, "k": K]}
//!   {"cmd": "stream_poll_all"[, "k": K]}
//!   {"cmd": "stream_close", "session": ID}
//!   {"cmd": "stream_tune", "session": ID}
//!
//! The `match` request carries a *raw* captured CPU series (what a real
//! deployment's SysStat agent would send); the server preprocesses it,
//! compares against every stored reference under the same configuration
//! set, and answers with the per-app similarities and the best match.
//!
//! The `knn` request runs the lower-bound-cascade index instead: the k
//! nearest references under the banded-DTW distance — over the whole
//! database, or one configuration set when `config` is given — plus each
//! neighbour's correlation similarity and the pruning counters for this
//! search. Whole-database searches are scored across the worker cores
//! with a shared early-abandoning cutoff (`IndexedDb::knn_parallel`,
//! result identical to the serial scan). `knn_batch` carries many queries
//! in one request and answers them in one entry-major pass that shares
//! envelope work across same-length queries (`IndexedDb::knn_batch`); the
//! per-batch size and latency land in the metrics report. The state holds
//! an [`IndexedDb`], so concurrent connections share one immutable
//! envelope cache.
//!
//! The `shard_info` request reports what this server owns — entry count,
//! applications, configuration-set labels, live session ids. It is the
//! handshake [`crate::coordinator::router::ShardRouter`] uses to compose
//! per-config shards into one logical database.
//!
//! The `stream_*` commands expose the online classifier
//! (`crate::streaming`): `stream_open` registers a live session (scoped to
//! one configuration set, or the whole database), `stream_feed` ingests
//! raw CPU sample batches and reports the anytime state (including the
//! early decision the moment the session's exit policy declares one),
//! `stream_poll` returns the current top-k without feeding, and
//! `stream_close` finalizes with the exact indexed search over the full
//! capture. A feed may carry the producing job's completed fraction as
//! `progress`; the server runs a per-session
//! [`crate::tuning::LengthPredictor`] over those reports and tightens the
//! session's final-length geometry (`StreamSession::set_final_len`) as
//! the prediction band narrows. `stream_tune` answers the closed-loop
//! question — the session's current match (frozen decision or anytime
//! leader) plus the matched application's *cached* optimal configuration
//! (`IndexedDb::optimal`); it never grid-searches, so it is cheap enough
//! to poll every tick. Sessions are addressed by id, not by connection: they survive
//! reconnects, so a feeder may open on one TCP connection and feed, poll
//! or close from another. Because live streams hold their connection open
//! for the whole job, the read loop tolerates idle timeouts instead of
//! dropping the peer: each timeout tick re-checks the server stop flag (so
//! shutdown is never wedged by a blocked read) and sweeps sessions
//! abandoned by dead clients.
//!
//! Hardening: every malformed line — unparseable JSON, nesting past the
//! parser's depth bound, invalid UTF-8, unknown commands, missing fields,
//! oversized lines or batches — is answered with a structured error
//! response and the connection stays up; rejects are counted per
//! [`ErrorCode`] in [`Metrics`].

use super::batcher::{prepare_query, similarities_auto};
use super::metrics::Metrics;
use crate::dtw::corr::MATCH_THRESHOLD;
use crate::index::{IndexedDb, SearchStats};
use crate::protocol::{
    decode_line, encode_reply, DecisionBody, ErrorCode, FinalBody, KnnBatchBody, KnnBody,
    MatchBody, MatchRow, NeighborRow, Request, Response, ServerError, SessionPollBody,
    ShardInfoBody, StatsBody, StreamCloseBody, StreamFeedBody, StreamOpenBody, StreamPollBody,
    StreamTunedBody, TopRow, Wire,
};
use crate::runtime::RuntimeHandle;
use crate::streaming::{
    DecisionPolicy, FinalLen, SessionManager, StreamDecision, StreamSession, TopEntry,
    MAX_RETAINED, MAX_STREAM_LEN,
};
use crate::trace::{FlightRecorder, Span, TraceHandle};
use crate::tuning::LengthPredictor;
use crate::util::json::Json;
use crate::util::pool::{default_workers, PanicHook, ThreadPool};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Per-connection read timeout: the cadence at which blocked readers
/// re-check the stop flag and sweep idle sessions. A single timeout does
/// NOT close the connection — live streams legitimately sit idle between
/// feeds — but a connection idle past [`CONN_IDLE`] is dropped, so a pool
/// worker can never be pinned for long by a dead client.
pub const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Connections idle this long are dropped. Harmless to live streams:
/// sessions are addressed by id and survive reconnects, and a SysStat
/// feeder sends every few seconds anyway.
pub const CONN_IDLE: Duration = Duration::from_secs(60);

/// Sessions untouched for this long belong to dead clients and are
/// reaped (checked on every idle tick and on every `stream_open`, so
/// abandoned sessions die even when no connection is idling).
pub const SESSION_IDLE: Duration = Duration::from_secs(600);

/// Largest accepted request line. A full-width `knn_batch` (256 queries of
/// 512 samples) serializes to ~3 MB; anything past this bound is rejected
/// with a structured `too_large` error. The bound is enforced *while
/// framing* ([`read_line_bounded`]): a hostile newline-free stream never
/// buffers more than this plus one `BufReader` block, it is discarded as
/// it arrives.
pub const MAX_LINE_BYTES: usize = 16 << 20;

/// Shared server state.
pub struct ServerState {
    pub db: IndexedDb,
    pub runtime: Option<RuntimeHandle>,
    pub metrics: Metrics,
    pub sessions: SessionManager,
    /// Span sink + clock for this server's request tracing (see
    /// `OBSERVABILITY.md`). [`TraceHandle::disabled`] — the default — costs
    /// nothing on the request path.
    pub tracer: TraceHandle,
    /// The always-on black box behind the `trace_dump` command and the
    /// read-loop dump-on-error path. Wired by `main` as one sink of the
    /// tracer's fan-out ([`crate::trace::MultiTracker`]); kept here too so
    /// the dispatch layer can snapshot it. `None` when tracing is off.
    pub recorder: Option<Arc<FlightRecorder>>,
    /// Per-session final-length predictors, fed by `stream_feed` lines
    /// that carry a `progress` fraction. Kept beside (not inside) the
    /// session registry: the streaming layer stays a pure classifier and
    /// the tuning loop composes on top. Entries die with their session
    /// (close or reap). `Default::default()` — an empty map — is always a
    /// correct initial value.
    pub predictors: Mutex<HashMap<u64, LengthPredictor>>,
}

/// The predictor map, recovered even if a panicking holder poisoned it —
/// a predictor in an odd state can only mis-hint, never corrupt results.
fn predictor_map(state: &ServerState) -> MutexGuard<'_, HashMap<u64, LengthPredictor>> {
    match state.predictors.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// The TCP server.
pub struct MatchServer {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
}

impl MatchServer {
    /// Bind to `addr` (use port 0 for an ephemeral port).
    pub fn bind(addr: &str, state: ServerState) -> Result<MatchServer> {
        let listener = TcpListener::bind(addr)?;
        Ok(MatchServer {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Local address (for tests with ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Stop handle: set true and connect once to unblock accept(). Workers
    /// blocked on idle connections notice within one [`READ_TIMEOUT`].
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until the stop flag is raised (default read timeout).
    pub fn serve(&self, workers: usize) -> Result<()> {
        self.serve_with(workers, READ_TIMEOUT)
    }

    /// Serve until the stop flag is raised. Each connection is handled on
    /// the pool; one line per request, one line per response.
    pub fn serve_with(&self, workers: usize, read_timeout: Duration) -> Result<()> {
        // A panicking handler is a bug, not a reason to shed a worker:
        // the pool catches the unwind and this hook surfaces it in the
        // metrics report as `pool_panics`.
        let hook: PanicHook = {
            let state = Arc::clone(&self.state);
            Arc::new(move || state.metrics.inc_pool_panics())
        };
        let pool = ThreadPool::with_panic_hook(workers.max(1), Some(hook));
        log::info!("serving on {}", self.listener.local_addr()?);
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    let stop = Arc::clone(&self.stop);
                    pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &state, &stop, read_timeout) {
                            log::debug!("connection ended: {e:#}");
                        }
                    });
                }
                Err(e) => log::warn!("accept failed: {e}"),
            }
        }
        Ok(())
    }
}

fn handle_connection(
    stream: TcpStream,
    state: &ServerState,
    stop: &AtomicBool,
    read_timeout: Duration,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let result = serve_connection_lines(
        stream,
        &state.metrics,
        &state.tracer,
        stop,
        read_timeout,
        || reap_sessions(state),
        |line| handle_line(line, state),
    );
    if result.is_err() {
        dump_recorder_on_error(state);
    }
    log::debug!("peer {peer} disconnected");
    result
}

/// One read of the bounded line framer.
enum LineRead {
    /// A complete line is in the buffer (newline consumed, not included).
    Line,
    /// Peer closed; any unterminated trailing bytes are in the buffer.
    Eof,
    /// The line crossed [`MAX_LINE_BYTES`]. `complete` says whether its
    /// newline has already been consumed; if not, the caller must discard
    /// until the next newline before framing resumes.
    Overflow { complete: bool },
}

/// Read one `\n`-terminated line into `buf`, never holding more than
/// `max` bytes of it in memory — unlike `BufRead::read_line`, which
/// buffers the whole line before any length check can run, this caps a
/// hostile newline-free stream at `max` + one `BufReader` block. Partial
/// bytes accumulate in `buf` across timeout ticks (the error is returned
/// to the caller's idle handling).
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    loop {
        let available = reader.fill_buf()?;
        if available.is_empty() {
            return Ok(LineRead::Eof);
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if buf.len() + pos > max {
                    buf.clear();
                    reader.consume(pos + 1);
                    return Ok(LineRead::Overflow { complete: true });
                }
                buf.extend_from_slice(&available[..pos]);
                reader.consume(pos + 1);
                return Ok(LineRead::Line);
            }
            None => {
                let n = available.len();
                if buf.len() + n > max {
                    buf.clear();
                    reader.consume(n);
                    return Ok(LineRead::Overflow { complete: false });
                }
                buf.extend_from_slice(available);
                reader.consume(n);
            }
        }
    }
}

/// Drop bytes until (and including) the next newline: the tail of an
/// oversized line. `Ok(true)` means the newline was found, `Ok(false)`
/// EOF; timeout errors surface to the caller's idle handling.
fn discard_to_newline(reader: &mut BufReader<TcpStream>) -> std::io::Result<bool> {
    loop {
        let available = reader.fill_buf()?;
        if available.is_empty() {
            return Ok(false);
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                reader.consume(pos + 1);
                return Ok(true);
            }
            None => {
                let n = available.len();
                reader.consume(n);
            }
        }
    }
}

fn is_idle_error(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Drive one connection's read loop: memory-bounded line framing, idle
/// ticks that tolerate read timeouts (re-checking the stop flag so
/// shutdown can never be wedged by a blocked read; connections idle past
/// [`CONN_IDLE`] are dropped so dead clients cannot pin pool workers),
/// and structured rejects for invalid UTF-8 and oversized lines — a
/// garbage line never costs the peer its connection. `on_idle` runs every
/// timeout tick (the match server sweeps abandoned sessions there);
/// `on_line` answers one trimmed request line. Shared by [`MatchServer`]
/// and `router::RouterServer`, so their read-loop hardening cannot
/// diverge.
pub(crate) fn serve_connection_lines(
    stream: TcpStream,
    metrics: &Metrics,
    tracer: &TraceHandle,
    stop: &AtomicBool,
    read_timeout: Duration,
    mut on_idle: impl FnMut(),
    mut on_line: impl FnMut(&str) -> Json,
) -> Result<()> {
    stream.set_read_timeout(Some(read_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut discarding = false;
    // Idle accounting goes through the tracer's clock, so tests can drive
    // it virtually and the raw-clock lint stays scoped to `trace/`.
    let idle_ns = CONN_IDLE.as_nanos() as u64;
    let mut last_activity = tracer.now_ns();
    let reject = |writer: &mut TcpStream, err: ServerError| -> std::io::Result<()> {
        metrics.inc_requests();
        metrics.inc_errors();
        metrics.inc_proto_error(err.code);
        write_reply(writer, &encode_reply(&Wire::V1, &Err(err)))
    };
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if discarding {
            // Mid-discard of an oversized line (already answered).
            match discard_to_newline(&mut reader) {
                Ok(true) => {
                    discarding = false;
                    last_activity = tracer.now_ns();
                }
                Ok(false) => break, // EOF
                Err(e) if is_idle_error(&e) => {
                    on_idle();
                    if tracer.now_ns().saturating_sub(last_activity) > idle_ns {
                        break;
                    }
                }
                Err(e) => return Err(e.into()),
            }
            continue;
        }
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES) {
            Ok(LineRead::Line) => {
                last_activity = tracer.now_ns();
                let text: Option<String> =
                    std::str::from_utf8(&buf).ok().map(|s| s.trim().to_string());
                buf.clear();
                match text {
                    None => reject(
                        &mut writer,
                        ServerError::bad_request("request line is not valid utf-8"),
                    )?,
                    Some(t) if t.is_empty() => {}
                    Some(t) => {
                        metrics.inc_requests();
                        let r = metrics.time(|| on_line(&t));
                        write_reply(&mut writer, &r)?;
                    }
                }
            }
            Ok(LineRead::Overflow { complete }) => {
                last_activity = tracer.now_ns();
                reject(
                    &mut writer,
                    ServerError::new(
                        ErrorCode::TooLarge,
                        format!("request line too large (max {MAX_LINE_BYTES} bytes)"),
                    ),
                )?;
                discarding = !complete;
            }
            Ok(LineRead::Eof) => {
                // A line is a request only once its newline arrives:
                // unterminated trailing bytes are NEVER executed — that is
                // what makes a client's rewrite-after-failed-write safe
                // even for non-idempotent requests (a half-delivered line
                // cannot have been applied). Answer a structured, counted
                // reject (best-effort: the peer may be gone) so a
                // half-closed sender still learns its tail was dropped.
                if !buf.is_empty() {
                    buf.clear();
                    let _ = reject(
                        &mut writer,
                        ServerError::bad_request("request line is not terminated"),
                    );
                }
                break;
            }
            Err(e) if is_idle_error(&e) => {
                // Idle tick: keep the connection (a live stream may simply
                // have nothing to feed yet); partial bytes stay in `buf`.
                on_idle();
                let idle = tracer.now_ns().saturating_sub(last_activity);
                if idle > idle_ns {
                    log::debug!(
                        "dropping connection idle for {:?}",
                        Duration::from_nanos(idle)
                    );
                    break;
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn write_reply(writer: &mut TcpStream, reply: &Json) -> std::io::Result<()> {
    writer.write_all(reply.to_string().as_bytes())?;
    writer.write_all(b"\n")
}

/// Decode, dispatch and render one request line. Never fails: malformed
/// input becomes a structured error response (counted per [`ErrorCode`]
/// in the metrics registry), rendered in the envelope the line arrived in.
///
/// With tracing enabled, each line becomes one `request` span with
/// `decode` / `handle` / `encode` children; a v2 envelope carrying a
/// `trace` field links the request span under that remote span id, so a
/// routed shard's tree nests below the router's fan-out span.
///
/// Roots go through [`TraceHandle::root_sampled`]: a `trace` field of
/// [`crate::trace::TRACE_SAMPLED_OUT`] (the router sampled this request
/// out) records nothing, a real span id records unconditionally, and an
/// absent field asks the local sampling policy with the v2 request id as
/// the key (v1 lines key on 0). Kept/dropped roots land in the
/// `spans_recorded` / `spans_sampled_out` metrics counters.
pub fn handle_line(line: &str, state: &ServerState) -> Json {
    let t0 = state.tracer.timestamp();
    let (wire, decoded) = decode_line(line);
    let t1 = state.tracer.timestamp();
    let (remote, key) = match wire {
        Wire::V2 { trace, id, .. } => (trace, id),
        Wire::V1 => (0, 0),
    };
    let root = state.tracer.root_sampled("request", remote, key);
    if state.tracer.enabled() {
        if root.active() {
            state.metrics.inc_spans_recorded();
            state.tracer.span_at("decode", root.id(), t0, t1);
        } else {
            state.metrics.inc_spans_sampled_out();
        }
    }
    let result = {
        let handle = root.child("handle");
        decoded.and_then(|req| {
            handle.note("type", req.type_name());
            dispatch_traced(&req, state, &handle)
        })
    };
    if let Err(e) = &result {
        state.metrics.inc_errors();
        state.metrics.inc_proto_error(e.code);
        root.note("error", e.code.as_str());
    }
    let encode = root.child("encode");
    let reply = encode_reply(&wire, &result);
    drop(encode);
    reply
}

/// Legacy entry point kept for benches/tests: dispatch one request line,
/// reporting protocol errors as `Err` (the pre-envelope contract) instead
/// of rendering them. Does not touch the error counters — errors are
/// accounted where responses are written, in the read loop / `handle_line`.
pub fn handle_request(line: &str, state: &ServerState) -> Result<Json> {
    let (wire, decoded) = decode_line(line);
    match decoded.and_then(|req| dispatch(&req, state)) {
        Ok(resp) => Ok(encode_reply(&wire, &Ok(resp))),
        Err(e) => Err(anyhow!("{}", e.message)),
    }
}

/// Dispatch one typed request against the server state. This is the single
/// execution path behind both envelope flavors — and the reason they can
/// never drift: v1 and v2 differ only in decode/render.
pub fn dispatch(req: &Request, state: &ServerState) -> Result<Response, ServerError> {
    dispatch_traced(req, state, &Span::none())
}

/// [`dispatch`] under a parent span: command handlers that do real work
/// (k-NN, streaming) get their own child spans; trivial lookups do not.
pub fn dispatch_traced(
    req: &Request,
    state: &ServerState,
    parent: &Span,
) -> Result<Response, ServerError> {
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Stats => Ok(Response::Stats(StatsBody {
            report: state.metrics.report(),
            db_entries: state.db.len(),
            live_sessions: state.sessions.len(),
        })),
        Request::Apps => Ok(Response::Apps(app_names(state))),
        Request::Metrics => {
            // Pull-based recorder gauges: freshened at snapshot time, so
            // the recorder never touches the metrics registry on the hot
            // record path.
            if let Some(rec) = &state.recorder {
                state.metrics.set_recorder_stats(rec.dropped(), rec.dumps());
            }
            Ok(Response::Metrics(state.metrics.snapshot()))
        }
        Request::TraceDump => Ok(Response::TraceDump(trace_dump_body(state))),
        Request::ShardInfo => Ok(Response::ShardInfo(ShardInfoBody {
            entries: state.db.len(),
            apps: app_names(state),
            configs: state.db.config_labels(),
            sessions: state.sessions.ids(),
        })),
        Request::Match { series, config } => handle_match(series, config, state),
        Request::Knn { series, k, config, .. } => {
            let span = parent.child("knn");
            span.event("k", *k as u64);
            handle_knn(series, *k, config.as_ref(), state, &span)
        }
        Request::KnnBatch { queries, k, config, .. } => {
            let span = parent.child("knn_batch");
            span.event("queries", queries.len() as u64);
            handle_knn_batch(queries, *k, config.as_ref(), state, &span)
        }
        Request::StreamOpen {
            config,
            final_len,
            max_len,
            min_fraction,
            margin,
            min_samples,
        } => {
            let span = parent.child("stream_open");
            handle_stream_open(
                config.as_ref(),
                *final_len,
                *max_len,
                *min_fraction,
                *margin,
                *min_samples,
                state,
                &span,
            )
        }
        Request::StreamFeed {
            session,
            samples,
            progress,
        } => {
            let span = parent.child("stream_feed");
            span.event("session", *session);
            span.event("samples", samples.len() as u64);
            handle_stream_feed(*session, samples, *progress, state, &span)
        }
        Request::StreamPoll { session, k } => handle_stream_poll(*session, *k, state),
        Request::StreamPollAll { k } => handle_stream_poll_all(*k, state),
        Request::StreamClose { session } => {
            let span = parent.child("stream_close");
            span.event("session", *session);
            handle_stream_close(*session, state, &span)
        }
        Request::StreamTune { session } => {
            let span = parent.child("stream_tune");
            span.event("session", *session);
            handle_stream_tune(*session, state, &span)
        }
    }
}

fn app_names(state: &ServerState) -> Vec<String> {
    state
        .db
        .apps()
        .iter()
        .map(|a| a.name().to_string())
        .collect()
}

/// Session-registry misses become the typed `unknown_session` code (the
/// message stays byte-compatible with the legacy error string).
fn session_err(e: anyhow::Error) -> ServerError {
    ServerError::new(ErrorCode::UnknownSession, format!("{e:#}"))
}

/// Body of a `trace_dump` response: the flight recorder's ring as a
/// Chrome-loadable document plus its occupancy counters. A server with no
/// recorder answers an empty snapshot (zero spans) rather than an error,
/// so fleet-wide dump sweeps never trip on untraced processes.
fn trace_dump_body(state: &ServerState) -> Json {
    let (spans, dropped, trace) = match &state.recorder {
        Some(rec) => {
            let doc = rec.dump();
            state.metrics.set_recorder_stats(rec.dropped(), rec.dumps());
            (rec.len(), rec.dropped(), doc)
        }
        None => (
            0,
            0,
            Json::obj(vec![
                ("displayTimeUnit", Json::Str("ms".to_string())),
                ("traceEvents", Json::arr(Vec::new())),
            ]),
        ),
    };
    Json::obj(vec![
        ("spans", Json::Num(spans as f64)),
        ("dropped", Json::Num(dropped as f64)),
        ("trace", trace),
    ])
}

/// Crash forensics: when the `MRTUNER_FLIGHT_DUMP` env var names a path
/// and a flight recorder is wired, a connection that dies on a real I/O
/// error (not an idle drop or clean EOF) writes the recorder's
/// recent-span ring there — the last thing the server was doing when the
/// peer blew up, without anyone having to ask for it in time.
fn dump_recorder_on_error(state: &ServerState) {
    let Some(rec) = &state.recorder else { return };
    let Ok(path) = std::env::var("MRTUNER_FLIGHT_DUMP") else { return };
    if path.is_empty() {
        return;
    }
    match rec.write_to(std::path::Path::new(&path)) {
        Ok(()) => log::warn!("flight recorder dumped to {path}"),
        Err(e) => log::warn!("flight recorder dump failed: {e:#}"),
    }
}

/// Sweep sessions abandoned by dead clients into the metrics counters.
/// Their final-length predictors die with them.
fn reap_sessions(state: &ServerState) {
    let reaped = state.sessions.reap_idle(SESSION_IDLE);
    if reaped > 0 {
        state.metrics.add_stream_reaped(reaped as u64);
        log::debug!("reaped {reaped} idle stream sessions");
        let live: std::collections::HashSet<u64> = state.sessions.ids().into_iter().collect();
        predictor_map(state).retain(|id, _| live.contains(id));
    }
}

fn decision_body(d: &StreamDecision) -> DecisionBody {
    DecisionBody {
        app: d.app.name().to_string(),
        config: d.config.label(),
        entry: d.entry,
        distance: d.distance,
        similarity: d.similarity,
        at_sample: d.at_sample,
        fraction: d.fraction,
    }
}

fn top_rows(top: &[TopEntry]) -> Vec<TopRow> {
    top.iter()
        .map(|t| TopRow {
            entry: t.entry,
            app: t.app.name().to_string(),
            config: t.config.label(),
            distance: t.distance,
            lower_bound: t.lower_bound,
        })
        .collect()
}

/// Open a live classification session.
#[allow(clippy::too_many_arguments)]
fn handle_stream_open(
    config: Option<&crate::simulator::job::JobConfig>,
    final_len: Option<usize>,
    max_len: Option<usize>,
    min_fraction: Option<f64>,
    margin: Option<f64>,
    min_samples: Option<usize>,
    state: &ServerState,
    span: &Span,
) -> Result<Response, ServerError> {
    // Every open sweeps stale sessions, so open-and-abandon clients cannot
    // grow the registry even when no connection ever sits idle.
    reap_sessions(state);
    // Sessions decimate past the 512-sample resample cap, so length hints
    // are honoured up to the retention cap; anything beyond it would
    // never be observed anyway. The *default* expectation stays at the
    // incremental cap — short jobs decide fastest against it.
    let final_len = match final_len {
        Some(n) if n > 0 => FinalLen::Known(n.min(MAX_RETAINED)),
        _ => FinalLen::AtMost(max_len.unwrap_or(MAX_STREAM_LEN).clamp(1, MAX_RETAINED)),
    };
    let mut policy = DecisionPolicy::default();
    if let Some(f) = min_fraction {
        policy.min_fraction = f.clamp(0.0, 2.0);
    }
    if let Some(m) = margin {
        policy.margin = m.max(1.0);
    }
    if let Some(s) = min_samples {
        policy.min_samples = s;
    }
    let margin_x1000 = (policy.margin * 1000.0) as u64;
    let session = StreamSession::open(&state.db, config, final_len, policy);
    let candidates = session.candidates();
    let id = state.sessions.open(session);
    state.metrics.inc_stream_opened();
    span.event("session", id);
    span.event("candidates", candidates as u64);
    // Annotate the session-lifetime span (opened by the manager) with the
    // exit policy it runs under; inert when untraced or sampled out.
    let _ = state.sessions.with_span(id, |_, sspan| {
        sspan.event("margin", margin_x1000);
        sspan.event("candidates", candidates as u64);
    });
    Ok(Response::StreamOpened(StreamOpenBody {
        session: id,
        candidates,
    }))
}

/// Feed one batch of raw CPU samples into a live session. When the feed
/// carries a `progress` fraction, the session's final-length predictor
/// observes it and any refined hint is pushed into the session before the
/// batch is classified — so the tightened geometry benefits this very
/// batch's bounds.
fn handle_stream_feed(
    id: u64,
    samples: &[f64],
    progress: Option<f64>,
    state: &ServerState,
    span: &Span,
) -> Result<Response, ServerError> {
    let (decided_now, decision, observed, live) = state
        .sessions
        .with_span(id, |s, sspan| {
            // One `feed` child per batch on the session-lifetime span, so
            // a stream renders as one long bar with its feeds inside.
            let feed = sspan.child("feed");
            feed.event("samples", samples.len() as u64);
            if let Some(p) = progress {
                // Elapsed = raw samples observed once this batch lands;
                // the predictor extrapolates the final capture length.
                let elapsed = (s.observed() + samples.len()) as f64;
                let hint = {
                    let mut map = predictor_map(state);
                    let pred = map.entry(id).or_default();
                    pred.observe(p, elapsed);
                    pred.final_len_hint(MAX_RETAINED)
                };
                state.metrics.inc_tuning_predictor_update();
                if let Some(hint) = hint {
                    let tspan = feed.child("tuning_hint");
                    match hint {
                        FinalLen::Known(n) => {
                            tspan.event("known", n as u64);
                            state.metrics.inc_tuning_hint_known();
                        }
                        FinalLen::AtMost(n) => {
                            tspan.event("at_most", n as u64);
                            state.metrics.inc_tuning_hint_at_most();
                        }
                    }
                    s.set_final_len(&state.db, hint);
                }
            }
            let had = s.decision().is_some();
            s.push(&state.db, samples);
            let d = s.decision().cloned();
            let decided_now = d.is_some() && !had;
            if decided_now {
                if let Some(d) = &d {
                    sspan.event("decided", d.at_sample as u64);
                    sspan.event("samples_seen", s.observed() as u64);
                }
            }
            (decided_now, d, s.observed(), s.live_candidates())
        })
        .map_err(session_err)?;
    if decided_now {
        if let Some(d) = &decision {
            state.metrics.record_stream_decision(d.at_sample, d.fraction);
            span.event("decision_at", d.at_sample as u64);
            span.note("decision", d.app.name());
        }
    }
    span.event("live_candidates", live as u64);
    Ok(Response::StreamFed(StreamFeedBody {
        observed,
        live_candidates: live,
        decision: decision.as_ref().map(decision_body),
    }))
}

/// Report a live session's anytime top-k without feeding it.
fn handle_stream_poll(id: u64, k: usize, state: &ServerState) -> Result<Response, ServerError> {
    let (top, decision, observed, live, culled) = state
        .sessions
        .with_span(id, |s, sspan| {
            let poll = sspan.child("poll");
            poll.event("k", k as u64);
            (
                s.top(&state.db, k),
                s.decision().cloned(),
                s.observed(),
                s.live_candidates(),
                s.stats().culled,
            )
        })
        .map_err(session_err)?;
    Ok(Response::StreamTop(StreamPollBody {
        observed,
        live_candidates: live,
        culled,
        top: top_rows(&top),
        decision: decision.as_ref().map(decision_body),
    }))
}

/// Snapshot every live session in one request — the fleet dashboard's
/// poll, backed by `SessionManager::poll_all`.
fn handle_stream_poll_all(k: usize, state: &ServerState) -> Result<Response, ServerError> {
    let polls = state.sessions.poll_all(&state.db, k);
    let rows = polls
        .iter()
        .map(|p| SessionPollBody {
            session: p.id,
            poll: StreamPollBody {
                observed: p.observed,
                live_candidates: p.live_candidates,
                culled: p.culled,
                top: top_rows(&p.top),
                decision: p.decision.as_ref().map(decision_body),
            },
        })
        .collect();
    Ok(Response::Sessions(rows))
}

/// Close a session: exact final search over the whole capture.
fn handle_stream_close(
    id: u64,
    state: &ServerState,
    span: &Span,
) -> Result<Response, ServerError> {
    let session = state.sessions.close(id).map_err(session_err)?;
    predictor_map(state).remove(&id);
    state.metrics.inc_stream_closed();
    state.metrics.record_stream_session(&session.stats());
    let finalize = span.child("finalize");
    let (neighbors, stats) = session.finalize(&state.db, 1);
    finalize.event("candidates", stats.candidates);
    finalize.event("dtw_evals", stats.dtw_evals);
    drop(finalize);
    state.metrics.record_search(&stats);
    let entries = state.db.entries();
    let final_match = neighbors.first().map(|nb| {
        let e = &entries[nb.index];
        let q = prepare_query(session.raw());
        let sim = crate::dtw::corr::similarity_percent_banded(&q, &e.series);
        FinalBody {
            app: e.app.name().to_string(),
            config: e.config_key(),
            entry: nb.index,
            distance: nb.distance,
            similarity: sim,
            matched: sim >= MATCH_THRESHOLD,
        }
    });
    Ok(Response::StreamClosed(StreamCloseBody {
        observed: session.observed(),
        final_match,
        decision: session.decision().map(decision_body),
    }))
}

/// Tuning advice for a live session: the current match — frozen decision
/// if the session has one, anytime top-1 otherwise — joined with the
/// matched application's *cached* optimal configuration. Read-only and
/// cheap: the expensive grid search happened when the reference was
/// profiled (`Tuner::find_optimal`); this only looks the result up, so a
/// live controller can poll it every tick.
fn handle_stream_tune(id: u64, state: &ServerState, span: &Span) -> Result<Response, ServerError> {
    let (decided, app, similarity, fraction) = state
        .sessions
        .with_span(id, |s, sspan| {
            let tspan = sspan.child("tuning_serve");
            match s.decision() {
                Some(d) => {
                    tspan.event("decided_at", d.at_sample as u64);
                    (true, Some(d.app), Some(d.similarity), Some(d.fraction))
                }
                None => {
                    let leader = s.top(&state.db, 1).first().map(|t| t.app);
                    (false, leader, None, None)
                }
            }
        })
        .map_err(session_err)?;
    let (optimal, optimal_secs) = match app.and_then(|a| state.db.optimal(a)) {
        Some(o) => (Some(o.config), Some(o.completion_secs)),
        None => (None, None),
    };
    state.metrics.inc_tuning_tune_served();
    if let Some(a) = app {
        span.note("app", a.name());
    }
    span.event("has_optimal", optimal.is_some() as u64);
    Ok(Response::StreamTuned(StreamTunedBody {
        session: id,
        decided,
        app: app.map(|a| a.name().to_string()),
        similarity,
        optimal,
        optimal_secs,
        fraction,
    }))
}

/// One neighbour as a typed response row (with its correlation similarity
/// and its database position, which the shard router rebases).
fn neighbor_row(state: &ServerState, q: &[f64], nb: &crate::index::Neighbor) -> NeighborRow {
    let e = &state.db.entries()[nb.index];
    NeighborRow {
        index: nb.index,
        app: e.app.name().to_string(),
        config: e.config_key(),
        distance: nb.distance,
        similarity: crate::dtw::corr::similarity_percent_banded(q, &e.series),
    }
}

/// Whole-DB k-NN searches currently fanning out (process-wide). The
/// physical cores are one shared budget: a lone request gets them all,
/// concurrent requests split them, so CPU-bound scan threads never
/// oversubscribe the machine however many pool workers are serving.
static KNN_IN_FLIGHT: AtomicUsize = AtomicUsize::new(0);

/// RAII share of the core budget for one whole-DB search.
struct KnnFanout;

impl KnnFanout {
    fn enter() -> KnnFanout {
        // relaxed: advisory load estimate — a stale count only mis-sizes a
        // worker split, it never affects result correctness.
        KNN_IN_FLIGHT.fetch_add(1, Ordering::Relaxed);
        KnnFanout
    }
    /// Cores this search may use: total divided by searches in flight
    /// (including this one), floored at 1 (= serial scan).
    fn workers(&self) -> usize {
        // relaxed: advisory — see `enter`.
        (default_workers() / KNN_IN_FLIGHT.load(Ordering::Relaxed).max(1)).max(1)
    }
}

impl Drop for KnnFanout {
    fn drop(&mut self) {
        // relaxed: advisory — see `enter`.
        KNN_IN_FLIGHT.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Index-backed k-NN: exact nearest references under the banded-DTW
/// distance via the lower-bound cascade. Whole-database searches fan the
/// candidate scan over the cores with a shared cutoff
/// (`IndexedDb::knn_parallel`, result identical to the serial scan),
/// splitting the core budget across concurrent requests; config-scoped
/// buckets are small and stay serial. `k = 0` (reachable through v2 only)
/// answers cleanly with zero neighbours.
fn handle_knn(
    series: &[f64],
    k: usize,
    config: Option<&crate::simulator::job::JobConfig>,
    state: &ServerState,
    span: &Span,
) -> Result<Response, ServerError> {
    let q = prepare_query(series);
    let (neighbors, stats) = match config {
        Some(cfg) => state.db.knn_in_config_traced(&q, &cfg.label(), k, span),
        None => {
            let fanout = KnnFanout::enter();
            state.db.knn_parallel_traced(&q, k, fanout.workers(), span)
        }
    };
    state.metrics.record_search(&stats);
    state.metrics.inc_comparisons(stats.dtw_evals);

    let rows = neighbors.iter().map(|nb| neighbor_row(state, &q, nb)).collect();
    Ok(Response::Knn(KnnBody {
        neighbors: rows,
        stats,
        degraded: vec![],
    }))
}

/// Batched k-NN: many queries answered in one entry-major pass that
/// shares envelope work across same-length queries. Response carries one
/// result row per query (input order) plus the merged pruning counters;
/// the batch size and wall-clock land in the metrics registry.
fn handle_knn_batch(
    queries: &[Vec<f64>],
    k: usize,
    config: Option<&crate::simulator::job::JobConfig>,
    state: &ServerState,
    span: &Span,
) -> Result<Response, ServerError> {
    let prepared: Vec<Vec<f64>> = queries.iter().map(|q| prepare_query(q)).collect();
    let qrefs: Vec<&[f64]> = prepared.iter().map(Vec::as_slice).collect();
    let t0 = state.tracer.now_ns();
    let results = match config {
        Some(cfg) => state.db.knn_batch_in_config_traced(&qrefs, &cfg.label(), k, span),
        None => state.db.knn_batch_traced(&qrefs, k, span),
    };
    state
        .metrics
        .record_knn_batch(qrefs.len() as u64, state.tracer.elapsed_secs(t0));

    let mut merged = SearchStats::default();
    let rows = results
        .iter()
        .zip(&prepared)
        .map(|((neighbors, stats), q)| {
            merged.merge(stats);
            KnnBody {
                neighbors: neighbors.iter().map(|nb| neighbor_row(state, q, nb)).collect(),
                stats: *stats,
                degraded: vec![],
            }
        })
        .collect();
    state.metrics.record_search(&merged);
    state.metrics.inc_comparisons(merged.dtw_evals);
    Ok(Response::KnnBatch(KnnBatchBody {
        results: rows,
        stats: merged,
        degraded: vec![],
    }))
}

fn handle_match(
    series: &[f64],
    config: &crate::simulator::job::JobConfig,
    state: &ServerState,
) -> Result<Response, ServerError> {
    let refs = state.db.by_config(&config.label());
    let ref_series: Vec<Vec<f64>> = refs.iter().map(|e| e.series.clone()).collect();
    let sims = similarities_auto(state.runtime.as_ref(), series, &ref_series);
    state.metrics.inc_comparisons(sims.len() as u64);

    let mut results = Vec::new();
    let mut best: Option<(&str, f64)> = None;
    for (e, s) in refs.iter().zip(&sims) {
        results.push(MatchRow {
            app: e.app.name().to_string(),
            similarity: *s,
        });
        if best.map_or(true, |(_, bs)| *s > bs) {
            best = Some((e.app.name(), *s));
        }
    }
    let (matched, best_similarity) = match best {
        Some((a, s)) if s >= MATCH_THRESHOLD => (Some(a.to_string()), s),
        Some((_, s)) => (None, s),
        None => (None, 0.0),
    };
    Ok(Response::Match(MatchBody {
        results,
        matched,
        best_similarity,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::database::profile::ProfileEntry;
    use crate::simulator::job::JobConfig;
    use crate::workloads::AppId;

    fn raw_wave(freq: f64) -> Vec<f64> {
        (0..64)
            .map(|i| (0.5 + 0.4 * ((i as f64) * freq).sin()).clamp(0.0, 1.0))
            .collect()
    }

    fn state_with_db() -> ServerState {
        let mut db = IndexedDb::new();
        let series = raw_wave(0.2);
        db.insert(ProfileEntry {
            app: AppId::WordCount,
            config: JobConfig::new(4, 2, 10.0, 20.0),
            series: crate::signal::preprocess(&series),
            raw_len: 64,
            completion_secs: 100.0,
        });
        let shifted = raw_wave(0.55);
        db.insert(ProfileEntry {
            app: AppId::TeraSort,
            config: JobConfig::new(4, 2, 10.0, 20.0),
            series: crate::signal::preprocess(&shifted),
            raw_len: 64,
            completion_secs: 80.0,
        });
        ServerState {
            db,
            runtime: None,
            metrics: Metrics::new(),
            sessions: SessionManager::new(),
            tracer: TraceHandle::disabled(),
            recorder: None,
            predictors: Default::default(),
        }
    }

    fn config_json() -> Json {
        Json::obj(vec![
            ("mappers", Json::Num(4.0)),
            ("reducers", Json::Num(2.0)),
            ("split_mb", Json::Num(10.0)),
            ("input_mb", Json::Num(20.0)),
        ])
    }

    #[test]
    fn ping_roundtrip() {
        let state = state_with_db();
        let resp = handle_request(r#"{"cmd":"ping"}"#, &state).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn match_request_finds_similar_series() {
        let state = state_with_db();
        let series: Vec<f64> = raw_wave(0.2);
        let req = Json::obj(vec![
            ("cmd", Json::Str("match".into())),
            ("series", Json::nums(&series)),
            ("config", config_json()),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let best = resp.get("best_similarity").and_then(Json::as_f64).unwrap();
        assert!(best > 90.0, "best={best}");
        assert_eq!(resp.get("match").and_then(Json::as_str), Some("wordcount"));
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        let state = state_with_db();
        assert!(handle_request("not json", &state).is_err());
        assert!(handle_request(r#"{"cmd":"nope"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"match"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn","series":[1,2]}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_feed","samples":[1]}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_feed","session":99,"samples":[0.5]}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_poll","session":99}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"stream_close","session":99}"#, &state).is_err());
    }

    #[test]
    fn handle_line_answers_structured_errors_and_counts_rejects() {
        let state = state_with_db();
        // v1 flavor: legacy error shape.
        let resp = handle_line("not json", &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get("error").and_then(Json::as_str).unwrap().starts_with("bad json"));
        assert_eq!(state.metrics.proto_error_count(ErrorCode::BadRequest), 1);

        // v2 flavor: typed code + echoed id.
        let resp = handle_line(r#"{"v":2,"id":41,"type":"stream_poll","session":99}"#, &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("id").and_then(Json::as_u64), Some(41));
        assert_eq!(
            resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("unknown_session")
        );
        assert_eq!(state.metrics.proto_error_count(ErrorCode::UnknownSession), 1);

        // Wrong version: typed code, never misparsed as v1.
        let resp = handle_line(r#"{"v":1,"id":2,"type":"ping"}"#, &state);
        assert_eq!(
            resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("wrong_version")
        );
        assert_eq!(state.metrics.proto_error_count(ErrorCode::WrongVersion), 1);
        assert_eq!(state.metrics.errors.load(Ordering::Relaxed), 3);
        assert_eq!(state.metrics.proto_errors_total(), 3);
    }

    #[test]
    fn v2_envelope_roundtrip_through_dispatch() {
        let state = state_with_db();
        let series = raw_wave(0.2);
        let req = Request::Knn {
            series: series.clone(),
            k: 2,
            config: None,
            allow_partial: false,
        };
        let resp = handle_line(&req.to_v2(7).to_string(), &state);
        assert_eq!(resp.get("v").and_then(Json::as_u64), Some(2));
        assert_eq!(resp.get("id").and_then(Json::as_u64), Some(7));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("type").and_then(Json::as_str), Some("knn"));
        let body = resp.get("body").unwrap();
        let rows = body.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        // v2 rows carry the entry index (the router's merge key).
        assert_eq!(rows[0].get("entry").and_then(Json::as_usize), Some(0));
        assert_eq!(rows[0].get("app").and_then(Json::as_str), Some("wordcount"));
    }

    #[test]
    fn v2_knn_k_zero_answers_empty_not_error() {
        let state = state_with_db();
        let series = raw_wave(0.2);
        let req = Request::Knn {
            series: series.clone(),
            k: 0,
            config: None,
            allow_partial: false,
        };
        let resp = handle_line(&req.to_v2(1).to_string(), &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let body = resp.get("body").unwrap();
        assert!(body.get("neighbors").and_then(Json::as_arr).unwrap().is_empty());

        // Batched form: one empty row per query.
        let req = Request::KnnBatch {
            queries: vec![series.clone(), series],
            k: 0,
            config: None,
            allow_partial: false,
        };
        let resp = handle_line(&req.to_v2(2).to_string(), &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let results = resp.get("body").unwrap().get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        for r in results {
            assert!(r.get("neighbors").and_then(Json::as_arr).unwrap().is_empty());
        }
    }

    #[test]
    fn knn_k_beyond_db_len_clamps_to_everything() {
        let state = state_with_db();
        let series = raw_wave(0.2);
        for line in [
            // v1 and v2 both: k far beyond the 2 stored entries.
            format!(
                r#"{{"cmd":"knn","series":{},"k":50}}"#,
                Json::nums(&series)
            ),
            Request::Knn {
                series: series.clone(),
                k: 50,
                config: None,
                allow_partial: false,
            }
            .to_v2(1)
            .to_string(),
        ] {
            let resp = handle_line(&line, &state);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{line}");
            let rows = match resp.get("neighbors") {
                Some(n) => n.as_arr().unwrap(),
                None => resp
                    .get("body")
                    .unwrap()
                    .get("neighbors")
                    .and_then(Json::as_arr)
                    .unwrap(),
            };
            assert_eq!(rows.len(), 2, "every entry, no phantom rows: {line}");
        }
    }

    #[test]
    fn shard_info_reports_ownership() {
        let state = state_with_db();
        let resp = handle_request(r#"{"cmd":"shard_info"}"#, &state).unwrap();
        assert_eq!(resp.get("entries").and_then(Json::as_usize), Some(2));
        let configs = resp.get("configs").and_then(Json::as_arr).unwrap();
        assert_eq!(configs.len(), 1);
        assert_eq!(configs[0].as_str(), Some("M=4,R=2,FS=10M,I=20M"));
        let apps = resp.get("apps").and_then(Json::as_arr).unwrap();
        assert_eq!(apps.len(), 2);
        assert!(resp.get("sessions").and_then(Json::as_arr).unwrap().is_empty());
    }

    #[test]
    fn knn_request_returns_neighbors_and_stats() {
        let state = state_with_db();
        let series: Vec<f64> = raw_wave(0.2);
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(2.0)),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let neighbors = resp.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(neighbors.len(), 2);
        // The untouched sine is the query itself: distance 0, first.
        assert_eq!(
            neighbors[0].get("app").and_then(Json::as_str),
            Some("wordcount")
        );
        assert_eq!(neighbors[0].get("distance").and_then(Json::as_f64), Some(0.0));
        // v1 rows must not leak the v2-only entry index.
        assert!(neighbors[0].get("entry").is_none());
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("candidates").and_then(Json::as_f64), Some(2.0));
        // The search was folded into the shared metrics registry.
        assert_eq!(state.metrics.search_stats().candidates, 2);

        // Config-scoped search sees only that bucket.
        let scoped = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(5.0)),
            ("config", config_json()),
        ]);
        let resp = handle_request(&scoped.to_string(), &state).unwrap();
        let neighbors = resp.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(neighbors.len(), 2, "both entries share the config set");
    }

    #[test]
    fn knn_batch_request_answers_every_query() {
        let state = state_with_db();
        let q1 = raw_wave(0.2); // wordcount-shaped
        let q2 = raw_wave(0.55); // terasort-shaped
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn_batch".into())),
            ("queries", Json::arr(vec![Json::nums(&q1), Json::nums(&q2)])),
            ("k", Json::Num(1.0)),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let results = resp.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        let top_app = |i: usize| {
            results[i]
                .get("neighbors")
                .and_then(Json::as_arr)
                .unwrap()[0]
                .get("app")
                .and_then(Json::as_str)
                .unwrap()
                .to_string()
        };
        assert_eq!(top_app(0), "wordcount");
        assert_eq!(top_app(1), "terasort");
        // Merged counters: 2 queries x 2 candidates.
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("candidates").and_then(Json::as_f64), Some(4.0));
        let (batches, queries, _) = state.metrics.knn_batch_summary();
        assert_eq!((batches, queries), (1, 2));
        assert_eq!(state.metrics.search_stats().candidates, 4);

        // Malformed batches error cleanly.
        assert!(handle_request(r#"{"cmd":"knn_batch"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn_batch","queries":[]}"#, &state).is_err());
        assert!(
            handle_request(r#"{"cmd":"knn_batch","queries":[[1,2]]}"#, &state).is_err(),
            "short series accepted"
        );
    }

    #[test]
    fn stream_poll_all_snapshots_sessions() {
        let state = state_with_db();
        for _ in 0..2 {
            let open = Json::obj(vec![
                ("cmd", Json::Str("stream_open".into())),
                ("config", config_json()),
                ("final_len", Json::Num(64.0)),
            ]);
            handle_request(&open.to_string(), &state).unwrap();
        }
        // Feed only the first session.
        let feed = Json::obj(vec![
            ("cmd", Json::Str("stream_feed".into())),
            ("session", Json::Num(1.0)),
            ("samples", Json::nums(&raw_wave(0.2)[..16])),
        ]);
        handle_request(&feed.to_string(), &state).unwrap();
        let resp =
            handle_request(r#"{"cmd":"stream_poll_all","k":2}"#, &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let sessions = resp.get("sessions").and_then(Json::as_arr).unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].get("session").and_then(Json::as_f64), Some(1.0));
        assert_eq!(sessions[0].get("observed").and_then(Json::as_f64), Some(16.0));
        assert_eq!(sessions[1].get("observed").and_then(Json::as_f64), Some(0.0));
        assert!(sessions[0].get("top").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn stream_lifecycle_end_to_end() {
        let state = state_with_db();
        // Open a session scoped to the stored config set.
        let open = Json::obj(vec![
            ("cmd", Json::Str("stream_open".into())),
            ("config", config_json()),
            ("final_len", Json::Num(64.0)),
        ]);
        let resp = handle_request(&open.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("candidates").and_then(Json::as_f64), Some(2.0));
        let id = resp.get("session").and_then(Json::as_f64).unwrap();
        assert_eq!(state.sessions.len(), 1);

        // Feed the wordcount-shaped capture in batches.
        let series = raw_wave(0.2);
        let mut decided = false;
        for chunk in series.chunks(16) {
            let feed = Json::obj(vec![
                ("cmd", Json::Str("stream_feed".into())),
                ("session", Json::Num(id)),
                ("samples", Json::nums(chunk)),
            ]);
            let resp = handle_request(&feed.to_string(), &state).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            decided |= resp.get("decision") != Some(&Json::Null);
        }

        // Poll: the anytime top-1 must be the wordcount reference.
        let poll = Json::obj(vec![
            ("cmd", Json::Str("stream_poll".into())),
            ("session", Json::Num(id)),
            ("k", Json::Num(2.0)),
        ]);
        let resp = handle_request(&poll.to_string(), &state).unwrap();
        let top = resp.get("top").and_then(Json::as_arr).unwrap();
        assert!(!top.is_empty());
        assert_eq!(top[0].get("app").and_then(Json::as_str), Some("wordcount"));
        assert_eq!(resp.get("observed").and_then(Json::as_f64), Some(64.0));

        // Close: exact final answer.
        let close = Json::obj(vec![
            ("cmd", Json::Str("stream_close".into())),
            ("session", Json::Num(id)),
        ]);
        let resp = handle_request(&close.to_string(), &state).unwrap();
        let final_obj = resp.get("final").expect("final result");
        assert_eq!(final_obj.get("app").and_then(Json::as_str), Some("wordcount"));
        assert_eq!(state.sessions.len(), 0);
        if decided {
            assert_eq!(state.metrics.stream_decisions.load(std::sync::atomic::Ordering::Relaxed), 1);
        }
        assert_eq!(state.metrics.stream_opened.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(state.metrics.stream_closed.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn metrics_request_answers_the_snapshot() {
        let state = state_with_db();
        let resp = handle_line(r#"{"v":2,"id":3,"type":"metrics"}"#, &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let body = resp.get("body").unwrap();
        assert!(body.get("requests").and_then(Json::as_u64).is_some());
        assert!(body.get("latency").and_then(|l| l.get("p99_ms")).is_some());
        assert!(body.get("proto_errors").and_then(|p| p.get("total")).is_some());
        // The v1 spelling works too (shard_info-style "ok" merge).
        let resp = handle_line(r#"{"cmd":"metrics"}"#, &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert!(resp.get("requests").and_then(Json::as_u64).is_some());
    }

    #[test]
    fn trace_dump_answers_the_recorder_ring() {
        use crate::trace::{FlightRecorder, VirtualClock};
        use std::sync::Arc;

        // No recorder wired: an empty snapshot, not an error.
        let state = state_with_db();
        let resp = handle_line(r#"{"v":2,"id":1,"type":"trace_dump"}"#, &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let body = resp.get("body").unwrap();
        assert_eq!(body.get("spans").and_then(Json::as_u64), Some(0));
        assert!(body
            .get("trace")
            .and_then(|t| t.get("traceEvents"))
            .and_then(Json::as_arr)
            .unwrap()
            .is_empty());

        // Recorder wired as the tracer's sink: requests land in the ring
        // and come back Chrome-shaped.
        let recorder = Arc::new(FlightRecorder::new(64));
        let mut state = state_with_db();
        state.tracer = TraceHandle::with_clock(
            Arc::clone(&recorder) as Arc<dyn crate::trace::Tracker>,
            Arc::new(VirtualClock::new(10)),
        );
        state.recorder = Some(Arc::clone(&recorder));
        let req = Request::Knn { series: raw_wave(0.2), k: 1, config: None, allow_partial: false };
        handle_line(&req.to_v2(1).to_string(), &state);

        let resp = handle_line(r#"{"v":2,"id":2,"type":"trace_dump"}"#, &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let body = resp.get("body").unwrap();
        let spans = body.get("spans").and_then(Json::as_u64).unwrap();
        assert!(spans > 0, "the knn request's tree is in the ring");
        let events = body
            .get("trace")
            .and_then(|t| t.get("traceEvents"))
            .and_then(Json::as_arr)
            .unwrap();
        assert!(events.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("request")));
        // The v1 spelling answers too (shard_info-style "ok" merge).
        let resp = handle_line(r#"{"cmd":"trace_dump"}"#, &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert!(resp.get("spans").and_then(Json::as_u64).unwrap() > 0);
        // Dump calls were folded back into the metrics gauges.
        let (_, _, _, dumps) = state.metrics.trace_summary();
        assert_eq!(dumps, 2);
    }

    #[test]
    fn wire_sampling_decisions_are_honored_and_counted() {
        use crate::trace::{InMemoryTracker, VirtualClock, TRACE_SAMPLED_OUT};
        use std::sync::Arc;

        let tracker = Arc::new(InMemoryTracker::new());
        let mut state = state_with_db();
        state.tracer = TraceHandle::with_clock(
            Arc::clone(&tracker) as Arc<dyn crate::trace::Tracker>,
            Arc::new(VirtualClock::new(10)),
        );
        let req = Request::Ping;

        // Upstream sampled this request out: nothing recorded, counted.
        let resp = handle_line(&req.to_v2_traced(1, TRACE_SAMPLED_OUT).to_string(), &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert!(tracker.spans().is_empty(), "sampled-out request left no spans");

        // Upstream sampled it in: recorded under the remote parent.
        let resp = handle_line(&req.to_v2_traced(2, 77).to_string(), &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(tracker.roots().len(), 1);
        assert_eq!(tracker.roots()[0].remote_parent, 77);

        let (recorded, sampled_out, _, _) = state.metrics.trace_summary();
        assert_eq!((recorded, sampled_out), (1, 1));
    }

    #[test]
    fn handle_line_builds_the_span_taxonomy() {
        use crate::trace::{InMemoryTracker, VirtualClock};
        use std::sync::Arc;

        let tracker = Arc::new(InMemoryTracker::new());
        let clock = Arc::new(VirtualClock::new(10));
        let mut state = state_with_db();
        state.tracer = TraceHandle::with_clock(
            Arc::clone(&tracker) as Arc<dyn crate::trace::Tracker>,
            clock,
        );

        let req = Request::Knn {
            series: raw_wave(0.2),
            k: 1,
            config: None,
            allow_partial: false,
        };
        let resp = handle_line(&req.to_v2_traced(1, 77).to_string(), &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");

        let spans = tracker.spans();
        let root = spans.iter().find(|s| s.name == "request").expect("request span");
        assert_eq!(root.parent, 0);
        assert_eq!(root.remote_parent, 77, "router's span id propagated");
        assert!(root.end_ns > root.start_ns);
        for name in ["decode", "handle", "encode"] {
            let s = spans.iter().find(|s| s.name == name).unwrap_or_else(|| panic!("{name} span"));
            assert_eq!(s.parent, root.id, "{name} nests under request");
            assert!(s.end_ns > s.start_ns, "{name} has a duration");
        }
        let handle = spans.iter().find(|s| s.name == "handle").unwrap();
        let knn = spans.iter().find(|s| s.name == "knn").expect("knn span");
        assert_eq!(knn.parent, handle.id);
        let cascade = spans.iter().find(|s| s.name == "cascade").expect("cascade span");
        assert_eq!(cascade.parent, knn.id);
        for stage in ["lb_kim", "lb_paa", "lb_keogh", "dp"] {
            let s = spans
                .iter()
                .find(|s| s.name == stage)
                .unwrap_or_else(|| panic!("{stage} span"));
            assert_eq!(s.parent, cascade.id, "{stage} nests under cascade");
            assert!(s.end_ns > s.start_ns, "{stage} has a duration");
        }
        // An untraced request (trace absent) still gets a local root.
        let resp = handle_line(&req.to_v2(2).to_string(), &state);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let roots = tracker.roots();
        assert_eq!(roots.len(), 2);
        assert_eq!(roots[1].remote_parent, 0);
    }

    #[test]
    fn concurrent_knn_requests_share_the_index() {
        let state = std::sync::Arc::new(state_with_db());
        let series: Vec<f64> = raw_wave(0.2);
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(1.0)),
        ])
        .to_string();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let state = std::sync::Arc::clone(&state);
                let req = req.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let resp = handle_request(&req, &state).unwrap();
                        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
                    }
                });
            }
        });
        assert_eq!(state.metrics.search_stats().candidates, 8 * 20 * 2);
    }

    #[test]
    fn tcp_end_to_end() {
        let server = MatchServer::bind("127.0.0.1:0", state_with_db()).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_flag();
        let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "line={line}");

        stream.write_all(b"{\"cmd\":\"apps\"}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("wordcount"));

        drop(reader);
        drop(stream);
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // unblock accept
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn idle_connections_survive_timeouts_and_do_not_wedge_shutdown() {
        let server = MatchServer::bind("127.0.0.1:0", state_with_db()).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_flag();
        let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // Idle well past several read timeouts: the connection must still
        // be served (pre-fix behaviour was to drop it on the first one).
        std::thread::sleep(Duration::from_millis(200));
        stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "idle connection was dropped: {line}");

        // Shut down WITHOUT closing our connection: the worker blocked on
        // our socket must notice the stop flag within one timeout tick
        // (pre-fix behaviour held the pool open indefinitely).
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // unblock accept
        handle.join().unwrap().unwrap();
    }
}
