//! PJRT runtime: loads the HLO-text artifacts `make artifacts` produced and
//! executes them from the request path. Python is never involved here.
//!
//! * [`artifacts`] — manifest parsing and bucket selection;
//! * [`client`]    — the (thread-local) PJRT CPU client and typed wrappers;
//! * [`executor`]  — a dedicated service thread + `Send + Sync` handle.

pub mod artifacts;
pub mod client;
pub mod executor;

pub use client::{BatchOutput, Padded};
pub use executor::{RuntimeHandle, RuntimeService};
