//! Piecewise-constant resource timelines and the 1 Hz SysStat-style sampler.

/// A piecewise-constant function of simulated time built by pushing
/// `(time, value)` change-points in nondecreasing time order.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    points: Vec<(f64, f64)>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { points: Vec::new() }
    }

    /// Record that the value becomes `v` at time `t`.
    pub fn push(&mut self, t: f64, v: f64) {
        if let Some(&(lt, lv)) = self.points.last() {
            debug_assert!(t >= lt - 1e-9, "time went backwards: {t} < {lt}");
            if (lv - v).abs() < 1e-12 {
                return; // no change
            }
            if (t - lt).abs() < 1e-12 {
                // Same instant: overwrite.
                self.points.last_mut().expect("nonempty").1 = v;
                return;
            }
        }
        self.points.push((t, v));
    }

    /// Mean value over each 1-second bucket `[s, s+1)` up to `t_end`
    /// (the paper samples CPU utilization at 1 Hz).
    pub fn sample_per_second(&self, t_end: f64) -> Vec<f64> {
        let n = t_end.ceil().max(0.0) as usize;
        let mut out = vec![0.0f64; n];
        if self.points.is_empty() || n == 0 {
            return out;
        }
        let mut idx = 0usize;
        for (s, slot) in out.iter_mut().enumerate() {
            let lo = s as f64;
            let hi = ((s + 1) as f64).min(t_end);
            let mut acc = 0.0;
            // Advance to the last change-point at or before `lo`.
            while idx + 1 < self.points.len() && self.points[idx + 1].0 <= lo {
                idx += 1;
            }
            let mut j = idx;
            let mut cur = lo;
            while cur < hi - 1e-12 {
                let seg_val = if self.points[j].0 <= cur { self.points[j].1 } else { 0.0 };
                let seg_end = if j + 1 < self.points.len() {
                    self.points[j + 1].0.min(hi)
                } else {
                    hi
                };
                let seg_end = seg_end.max(cur);
                acc += seg_val * (seg_end - cur);
                cur = seg_end;
                if j + 1 < self.points.len() && self.points[j + 1].0 <= cur + 1e-12 {
                    j += 1;
                }
            }
            *slot = acc / (hi - lo).max(1e-12);
        }
        out
    }

    /// Mean value over each *complete* 1-second bucket `[s, s+1)` for `s`
    /// in `[from_sec, upto_sec)`, resuming the change-point scan from
    /// `cursor` (pass the same cursor across calls for O(points) total
    /// work). Uses the exact bucket arithmetic of [`sample_per_second`]
    /// so a prefix sampled incrementally while the timeline is still
    /// growing agrees with the post-hoc sampling of the finished
    /// timeline, as long as only already-final buckets are requested
    /// (i.e. `upto_sec <= floor(now)` for a timeline last pushed at
    /// `now`).
    ///
    /// [`sample_per_second`]: Timeline::sample_per_second
    pub fn sample_seconds(&self, from_sec: usize, upto_sec: usize, cursor: &mut usize) -> Vec<f64> {
        let mut out = vec![0.0f64; upto_sec.saturating_sub(from_sec)];
        if self.points.is_empty() || out.is_empty() {
            return out;
        }
        let mut idx = (*cursor).min(self.points.len() - 1);
        for (k, slot) in out.iter_mut().enumerate() {
            let lo = (from_sec + k) as f64;
            let hi = (from_sec + k + 1) as f64;
            let mut acc = 0.0;
            while idx + 1 < self.points.len() && self.points[idx + 1].0 <= lo {
                idx += 1;
            }
            let mut j = idx;
            let mut cur = lo;
            while cur < hi - 1e-12 {
                let seg_val = if self.points[j].0 <= cur { self.points[j].1 } else { 0.0 };
                let seg_end = if j + 1 < self.points.len() {
                    self.points[j + 1].0.min(hi)
                } else {
                    hi
                };
                let seg_end = seg_end.max(cur);
                acc += seg_val * (seg_end - cur);
                cur = seg_end;
                if j + 1 < self.points.len() && self.points[j + 1].0 <= cur + 1e-12 {
                    j += 1;
                }
            }
            *slot = acc / (hi - lo).max(1e-12);
        }
        *cursor = idx;
        out
    }

    /// Total integral over `[0, t_end]`.
    pub fn integral(&self, t_end: f64) -> f64 {
        let mut acc = 0.0;
        for (i, &(t, v)) in self.points.iter().enumerate() {
            if t >= t_end {
                break;
            }
            let next = if i + 1 < self.points.len() {
                self.points[i + 1].0.min(t_end)
            } else {
                t_end
            };
            acc += v * (next - t).max(0.0);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_value_samples_flat() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.5);
        let s = tl.sample_per_second(4.0);
        assert_eq!(s, vec![1.5; 4]);
    }

    #[test]
    fn step_change_mid_bucket() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.0);
        tl.push(0.5, 0.0);
        let s = tl.sample_per_second(2.0);
        assert!((s[0] - 0.5).abs() < 1e-12);
        assert_eq!(s[1], 0.0);
    }

    #[test]
    fn integral_matches_samples() {
        let mut tl = Timeline::new();
        tl.push(0.0, 2.0);
        tl.push(1.25, 0.5);
        tl.push(3.0, 1.0);
        let t_end = 5.0;
        let total = tl.integral(t_end);
        let samples = tl.sample_per_second(t_end);
        let from_samples: f64 = samples.iter().sum();
        assert!((total - from_samples).abs() < 1e-9, "{total} vs {from_samples}");
    }

    #[test]
    fn duplicate_value_pushes_collapse() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.0);
        tl.push(1.0, 1.0);
        tl.push(2.0, 1.0);
        assert_eq!(tl.points.len(), 1);
    }

    #[test]
    fn same_instant_overwrites() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.0);
        tl.push(1.0, 2.0);
        tl.push(1.0, 3.0);
        let s = tl.sample_per_second(2.0);
        assert!((s[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn partial_final_bucket() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.0);
        let s = tl.sample_per_second(1.5);
        assert_eq!(s.len(), 2);
        assert!((s[1] - 1.0).abs() < 1e-12); // mean over [1, 1.5)
    }

    #[test]
    fn empty_timeline_is_zero() {
        let tl = Timeline::new();
        assert_eq!(tl.sample_per_second(3.0), vec![0.0; 3]);
        assert_eq!(tl.integral(3.0), 0.0);
    }

    #[test]
    fn incremental_prefix_matches_posthoc_sampling() {
        // Grow a timeline while sampling only the already-final seconds;
        // the concatenated prefix must equal the post-hoc full sampling.
        let mut tl = Timeline::new();
        let mut cursor = 0usize;
        let mut sampled_upto = 0usize;
        let mut prefix: Vec<f64> = Vec::new();
        let pushes = [
            (0.0, 2.0),
            (0.7, 0.5),
            (1.25, 1.0),
            (3.0, 0.0),
            (3.5, 4.0),
            (6.2, 1.5),
        ];
        for &(t, v) in &pushes {
            tl.push(t, v);
            let whole = t.floor() as usize;
            if whole > sampled_upto {
                // Buckets strictly before the latest push time are final.
                prefix.extend(tl.sample_seconds(sampled_upto, whole, &mut cursor));
                sampled_upto = whole;
            }
        }
        let t_end = 6.2f64;
        let full = tl.sample_per_second(t_end);
        assert_eq!(prefix.len(), sampled_upto);
        for (i, (&a, &b)) in prefix.iter().zip(full.iter()).enumerate() {
            assert!((a - b).abs() < 1e-12, "bucket {i}: {a} vs {b}");
        }
    }

    #[test]
    fn sample_seconds_empty_ranges() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.0);
        let mut cursor = 0usize;
        assert!(tl.sample_seconds(3, 3, &mut cursor).is_empty());
        assert!(tl.sample_seconds(5, 2, &mut cursor).is_empty());
        assert_eq!(Timeline::new().sample_seconds(0, 2, &mut cursor), vec![0.0; 2]);
    }
}
