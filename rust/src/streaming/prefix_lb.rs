//! Monotone, admissible prefix lower bounds on the final banded-DTW
//! distance of a live stream.
//!
//! The difficulty is that the stored references are min-max normalized
//! over their *whole* series (§3.1.1), while mid-stream we only know the
//! extrema of the prefix — future samples can still widen the range and
//! retroactively re-scale every value we have seen. The bound therefore
//! scores each observed row against an *interval* of values its final
//! normalization can still take, and each interval only ever shrinks:
//!
//! * Let `v = filtered[i]` be a (causally) filtered sample, `[lo_p, hi_p]`
//!   the prefix extrema so far and `[L, H]` the value domain of the filter
//!   (every filtered sample of a `[0,1]` raw capture lies in it — see
//!   [`crate::signal::chebyshev::Sos::output_bounds`]). The final extrema
//!   `(lo_f, hi_f)` satisfy `L <= lo_f <= lo_p` and `hi_p <= hi_f <= H`,
//!   and the final normalized value `(v - lo_f) / (hi_f - lo_f)` is
//!   monotone decreasing in both `lo_f` and `hi_f`, so it lies in
//!   `[(v - lo_p) / (H - lo_p), (v - L) / (hi_p - L)]` (clamped to
//!   `[0,1]`). As samples arrive `lo_p` only decreases and `hi_p` only
//!   increases, so the interval nests — contributions never shrink.
//! * Every admissible warping path of the final alignment visits every
//!   query row `i` at some reference column inside the Sakoe–Chiba band
//!   ([`crate::dtw::band_edges`]). Row `i`'s contribution is therefore at
//!   least the gap between its value interval and the reference envelope
//!   over a *cover* of those columns; with the final length known the
//!   cover is the exact band row, with only an upper bound on the length
//!   it is the union of the band rows over all lengths still possible —
//!   again shrinking as the prefix grows.
//!
//! Summing the per-row gaps gives a bound that is monotone non-decreasing
//! in stream length and never exceeds the final banded distance
//! (`rust/tests/properties.rs` sweeps both properties). The guarantee
//! covers streams up to the matching pipeline's 512-sample resample cap
//! ([`super::MAX_STREAM_LEN`]); past it sessions decimate the raw capture
//! to stay incremental — the bound then runs on the decimated query,
//! still monotone between decimation rebuilds but heuristic with respect
//! to the pipeline's linear resample, and the exact answer always comes
//! from finalization.

use crate::dtw::{band_edges, band_radius, band_slope};
use crate::index::Envelope;
use crate::signal::normalize::OnlineMinMax;

/// What is known about the final length of a live stream.
///
/// MapReduce completion times are predictable mid-run (companion work,
/// arXiv:1303.3632), so [`FinalLen::Known`] is the common case for
/// simulator-driven sessions; [`FinalLen::AtMost`] only assumes the
/// pipeline's resample cap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinalLen {
    /// The final series length is known (or reliably predicted).
    Known(usize),
    /// Only an upper bound on the final length is known.
    AtMost(usize),
}

impl FinalLen {
    /// The length the bound geometry assumes, given `observed` samples so
    /// far: a `Known` hint shorter than the observed prefix self-corrects
    /// (the hint was wrong; monotonicity holds again once the geometry
    /// stabilizes).
    pub fn expected(&self, observed: usize) -> usize {
        match *self {
            FinalLen::Known(n) => n.max(observed),
            FinalLen::AtMost(n) => n.max(observed),
        }
    }
}

/// Lower bound on the banded-DTW distance between the *completed* query
/// (filtered + min-max normalized over its full length) and a stored
/// reference summarized by `env`.
///
/// * `filtered` — causally filtered prefix (`p` samples).
/// * `norm` — running extrema of exactly `filtered`.
/// * `domain` — `(L, H)` bounds on any filtered sample (see module docs).
/// * `final_len` — what is known about the final query length.
///
/// Returns `0.0` for empty prefixes/references — a trivially admissible
/// answer.
pub fn prefix_lb(
    filtered: &[f64],
    norm: &OnlineMinMax,
    domain: (f64, f64),
    final_len: FinalLen,
    env: &Envelope,
) -> f64 {
    let p = filtered.len();
    if p == 0 || env.is_empty() {
        return 0.0;
    }
    debug_assert_eq!(norm.count(), p, "norm out of sync with prefix");
    let m = env.len();
    let (lo_p, hi_p) = (norm.lo(), norm.hi());
    // Defensive widening: the domain must contain the observed extrema for
    // the interval argument to hold (it does for a correctly configured
    // session; widening keeps the bound admissible either way).
    let dl = domain.0.min(lo_p);
    let dh = domain.1.max(hi_p);
    // A constant prefix could still become the all-zeros normalization of
    // a constant final series, so its rows carry no information yet.
    let degenerate = hi_p - lo_p <= 0.0;

    // Column-cover geometry for each observed row.
    #[derive(Clone, Copy)]
    enum Cols {
        Exact { slope: f64, r: usize },
        Union { slope_min: f64, slope_now: f64, r: usize },
    }
    let cols = match final_len {
        FinalLen::Known(n) => {
            let n = n.max(p);
            Cols::Exact {
                slope: band_slope(n, m),
                r: band_radius(n, m),
            }
        }
        FinalLen::AtMost(n_max) => {
            let n_max = n_max.max(p);
            // r(n) = ceil(max(incr(n), decr(n))) is bounded over [p, n_max]
            // by the max of its endpoint values.
            Cols::Union {
                slope_min: band_slope(n_max, m),
                slope_now: band_slope(p, m),
                r: band_radius(p, m).max(band_radius(n_max, m)),
            }
        }
    };

    let mut sum = 0.0;
    for (i, &v) in filtered.iter().enumerate() {
        let (q_lo, q_hi) = if degenerate {
            (0.0, 1.0)
        } else {
            let nl = if dh - lo_p > 0.0 {
                ((v - lo_p) / (dh - lo_p)).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let nh = if hi_p - dl > 0.0 {
                ((v - dl) / (hi_p - dl)).clamp(0.0, 1.0)
            } else {
                1.0
            };
            (nl, nh)
        };
        let (c_lo, c_hi) = match cols {
            Cols::Exact { slope, r } => band_edges(i, slope, r, m),
            Cols::Union {
                slope_min,
                slope_now,
                r,
            } => {
                let lo = (i as f64 * slope_min - r as f64).floor().max(0.0) as usize;
                let hi = ((i as f64 * slope_now).ceil() as usize + r).min(m - 1);
                (lo.min(m - 1), hi)
            }
        };
        let (y_lo, y_hi) = env.cover_range(c_lo, c_hi);
        if q_lo > y_hi {
            sum += q_lo - y_hi;
        } else if y_lo > q_hi {
            sum += y_lo - q_hi;
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::banded::dtw_banded;
    use crate::index::DEFAULT_BLOCK;
    use crate::signal::chebyshev::Sos;
    use crate::signal::normalize::min_max;
    use crate::util::rng::Pcg32;

    fn raw_series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.3).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    /// Drive the online pipeline over `raw`, checking the bound at every
    /// prefix length against the final banded distance.
    fn check_stream(raw: &[f64], reference: &[f64], final_len: FinalLen) {
        let sos = Sos::lowpass_default();
        let domain = sos.output_bounds(0.0, 1.0, 1024);
        let env = Envelope::build(reference, DEFAULT_BLOCK);

        let final_q = min_max(&sos.filter(raw));
        let n = raw.len();
        let m = reference.len();
        let final_dist = dtw_banded(&final_q, reference, band_radius(n, m)).distance;

        let mut st = sos.stream();
        let mut filtered = Vec::new();
        let mut norm = OnlineMinMax::new();
        let mut last = 0.0;
        for &x in raw {
            let y = st.push(x);
            filtered.push(y);
            norm.push(y);
            let lb = prefix_lb(&filtered, &norm, domain, final_len, &env);
            assert!(
                lb >= last - 1e-12,
                "bound not monotone: {lb} after {last} at p={}",
                filtered.len()
            );
            assert!(
                lb <= final_dist + 1e-9,
                "bound {lb} exceeds final distance {final_dist} at p={}",
                filtered.len()
            );
            last = lb;
        }
    }

    #[test]
    fn monotone_and_admissible_known_length() {
        let mut g = Pcg32::new(140, 1);
        for _ in 0..10 {
            let n = 40 + g.below(200) as usize;
            let m = 40 + g.below(200) as usize;
            let raw = raw_series(&mut g, n);
            let reference = min_max(&Sos::lowpass_default().filter(&raw_series(&mut g, m)));
            check_stream(&raw, &reference, FinalLen::Known(n));
        }
    }

    #[test]
    fn monotone_and_admissible_bounded_length() {
        let mut g = Pcg32::new(141, 2);
        for _ in 0..10 {
            let n = 40 + g.below(200) as usize;
            let m = 40 + g.below(200) as usize;
            let raw = raw_series(&mut g, n);
            let reference = min_max(&Sos::lowpass_default().filter(&raw_series(&mut g, m)));
            check_stream(&raw, &reference, FinalLen::AtMost(512));
        }
    }

    #[test]
    fn separated_series_eventually_get_a_positive_bound() {
        // Raw stream pinned high, reference pinned low: once the prefix has
        // spread, the bound must see the gap.
        let mut g = Pcg32::new(142, 3);
        let raw: Vec<f64> = (0..200)
            .map(|_| (0.9 + (g.f64() - 0.5) * 0.1).clamp(0.0, 1.0))
            .collect();
        // Reference hugging zero with one unit spike so its envelope spans
        // a narrow band near 0 except one block.
        let mut reference = vec![0.02; 200];
        reference[100] = 1.0;
        let sos = Sos::lowpass_default();
        let domain = sos.output_bounds(0.0, 1.0, 1024);
        let env = Envelope::build(&reference, DEFAULT_BLOCK);
        let filtered = sos.filter(&raw);
        let mut norm = OnlineMinMax::new();
        norm.observe(&filtered);
        let lb = prefix_lb(&filtered, &norm, domain, FinalLen::Known(200), &env);
        assert!(lb > 1.0, "expected a clearly positive bound, got {lb}");
    }

    #[test]
    fn empty_inputs_are_zero() {
        let env = Envelope::build(&[0.5; 32], DEFAULT_BLOCK);
        let norm = OnlineMinMax::new();
        assert_eq!(
            prefix_lb(&[], &norm, (0.0, 1.0), FinalLen::Known(10), &env),
            0.0
        );
    }

    #[test]
    fn expected_length_self_corrects() {
        assert_eq!(FinalLen::Known(100).expected(40), 100);
        assert_eq!(FinalLen::Known(100).expected(140), 140);
        assert_eq!(FinalLen::AtMost(512).expected(40), 512);
    }
}
