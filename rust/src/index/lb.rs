//! Lower bounds on the banded DTW distance.
//!
//! All bounds are *admissible* for
//! [`crate::dtw::banded::dtw_banded`] with the same radius: they never
//! exceed the true banded distance (up to f64 rounding, which the search
//! absorbs with a tiny cutoff margin). They are **not** mutually ordered
//! with each other in general — `lb_kim` uses exact endpoint costs while
//! the envelope bounds relax values to block extrema — but
//! `lb_paa <= lb_keogh` always holds because the PAA bound relaxes the
//! query side of the Keogh bound as well. The search cascade orders them
//! by cost, cheapest first.

use super::envelope::Envelope;
use crate::dtw::{band_edges, band_slope};

/// O(1) endpoint bound (Kim's three-point bound reduced to the two corner
/// cells): every admissible warping path starts at `(0,0)` and ends at
/// `(n-1,m-1)`, so it pays at least those two local costs (one cost when
/// both series are singletons and the corners coincide).
pub fn lb_kim(x: &[f64], y: &[f64]) -> f64 {
    debug_assert!(!x.is_empty() && !y.is_empty());
    let first = (x[0] - y[0]).abs();
    if x.len() == 1 && y.len() == 1 {
        return first;
    }
    first + (x[x.len() - 1] - y[y.len() - 1]).abs()
}

/// Per-row Sakoe–Chiba envelope bound (LB_Keogh adapted to unequal lengths
/// via the production band geometry): every path visits every query row
/// `i` at some column inside [`band_edges`]`(i)`, paying at least the
/// distance from `x[i]` to the envelope of the reference over those
/// columns. O(n) rows, O(width/block) per range query.
pub fn lb_keogh(x: &[f64], env: &Envelope, r: usize) -> f64 {
    let n = x.len();
    let m = env.len();
    debug_assert!(n > 0 && m > 0);
    let slope = band_slope(n, m);
    let mut sum = 0.0;
    for (i, &v) in x.iter().enumerate() {
        let (lo, hi) = band_edges(i, slope, r, m);
        let (l, u) = env.cover_range(lo, hi);
        if v > u {
            sum += v - u;
        } else if v < l {
            sum += l - v;
        }
    }
    sum
}

/// Blockwise extrema of the query, `block` samples per block — the query
/// side of [`lb_paa`]. Computed once per search and reused across all
/// candidates. (Same summary an [`Envelope`] holds for stored series.)
pub fn query_extrema(x: &[f64], block: usize) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    query_extrema_into(x, block, &mut out);
    out
}

/// [`query_extrema`] into a reusable buffer (value-identical): the fold
/// order matches [`Envelope::build`], so bounds built from either agree
/// bitwise. Lets the search engine keep one extrema buffer in its scratch
/// arena instead of allocating per query.
pub fn query_extrema_into(x: &[f64], block: usize, out: &mut Vec<(f64, f64)>) {
    assert!(block > 0, "query_extrema: zero block size");
    out.clear();
    for chunk in x.chunks(block) {
        let mut l = f64::INFINITY;
        let mut h = f64::NEG_INFINITY;
        for &v in chunk {
            l = l.min(v);
            h = h.max(v);
        }
        out.push((l, h));
    }
}

/// Precompute the per-row envelope intervals of [`lb_keogh`] for query
/// length `n` against one reference envelope. The intervals depend only on
/// `(n, env, r)` — not on the query's values — so a batch of same-length
/// queries shares one envelope pass per reference entry
/// ([`crate::index::knn::knn_batch`]) instead of walking the envelope once
/// per (query, entry).
pub fn keogh_rows_into(env: &Envelope, n: usize, r: usize, out: &mut Vec<(f64, f64)>) {
    let m = env.len();
    debug_assert!(n > 0 && m > 0);
    let slope = band_slope(n, m);
    out.clear();
    for i in 0..n {
        let (lo, hi) = band_edges(i, slope, r, m);
        out.push(env.cover_range(lo, hi));
    }
}

/// [`lb_keogh`] evaluated against intervals precomputed by
/// [`keogh_rows_into`] — same per-row values, same accumulation order,
/// hence bit-identical to calling [`lb_keogh`] directly.
pub fn lb_keogh_rows(x: &[f64], rows: &[(f64, f64)]) -> f64 {
    debug_assert_eq!(x.len(), rows.len());
    let mut sum = 0.0;
    for (&v, &(l, u)) in x.iter().zip(rows) {
        if v > u {
            sum += v - u;
        } else if v < l {
            sum += l - v;
        }
    }
    sum
}

/// PAA-summarized envelope bound: [`lb_keogh`] relaxed to block
/// resolution on *both* sides. For each query block the rows inside it can
/// only reach columns between the band edge of the block's first row and
/// that of its last row; each of the block's rows pays at least the
/// interval-to-interval distance between the query block's value range and
/// the reference envelope over those columns. O(n/block) per candidate.
pub fn lb_paa(qext: &[(f64, f64)], n: usize, block: usize, env: &Envelope, r: usize) -> f64 {
    let m = env.len();
    debug_assert!(n > 0 && m > 0);
    debug_assert_eq!(qext.len(), (n + block - 1) / block);
    let slope = band_slope(n, m);
    let mut sum = 0.0;
    for (k, &(qlo, qhi)) in qext.iter().enumerate() {
        let i0 = k * block;
        let i1 = (i0 + block - 1).min(n - 1);
        let (clo, _) = band_edges(i0, slope, r, m);
        let (_, chi) = band_edges(i1, slope, r, m);
        let (ylo, yhi) = env.cover_range(clo, chi);
        let gap = if qlo > yhi {
            qlo - yhi
        } else if ylo > qhi {
            ylo - qhi
        } else {
            0.0
        };
        sum += (i1 - i0 + 1) as f64 * gap;
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::banded::dtw_banded;
    use crate::dtw::band_radius;
    use crate::index::DEFAULT_BLOCK;
    use crate::util::rng::Pcg32;

    fn series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.25).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn bounds_are_admissible_for_banded_dtw() {
        let mut g = Pcg32::new(50, 1);
        for _ in 0..60 {
            let n = 2 + g.below(180) as usize;
            let m = 2 + g.below(180) as usize;
            let x = series(&mut g, n);
            let y = series(&mut g, m);
            let r = band_radius(n, m);
            let env = Envelope::build(&y, DEFAULT_BLOCK);
            let qext = query_extrema(&x, DEFAULT_BLOCK);
            let banded = dtw_banded(&x, &y, r).distance;
            let kim = lb_kim(&x, &y);
            let keogh = lb_keogh(&x, &env, r);
            let paa = lb_paa(&qext, n, DEFAULT_BLOCK, &env, r);
            assert!(kim <= banded + 1e-9, "kim {kim} > banded {banded}");
            assert!(keogh <= banded + 1e-9, "keogh {keogh} > banded {banded}");
            assert!(paa <= keogh + 1e-9, "paa {paa} > keogh {keogh}");
        }
    }

    #[test]
    fn identical_series_all_bounds_zero() {
        let mut g = Pcg32::new(51, 2);
        let x = series(&mut g, 100);
        let env = Envelope::build(&x, DEFAULT_BLOCK);
        let r = band_radius(100, 100);
        assert_eq!(lb_kim(&x, &x), 0.0);
        assert_eq!(lb_keogh(&x, &env, r), 0.0);
        let qext = query_extrema(&x, DEFAULT_BLOCK);
        assert_eq!(lb_paa(&qext, 100, DEFAULT_BLOCK, &env, r), 0.0);
    }

    #[test]
    fn separated_series_get_nonzero_bounds() {
        // Query around 0, reference around 1: every bound must see the gap.
        let x = vec![0.0; 128];
        let y = vec![1.0; 96];
        let r = band_radius(128, 96);
        let env = Envelope::build(&y, DEFAULT_BLOCK);
        let qext = query_extrema(&x, DEFAULT_BLOCK);
        assert!(lb_kim(&x, &y) >= 2.0 - 1e-12);
        // Each of the 128 rows is 1.0 away from the envelope.
        assert!((lb_keogh(&x, &env, r) - 128.0).abs() < 1e-9);
        assert!((lb_paa(&qext, 128, DEFAULT_BLOCK, &env, r) - 128.0).abs() < 1e-9);
    }

    #[test]
    fn singleton_series_kim_does_not_double_count() {
        assert_eq!(lb_kim(&[0.3], &[0.8]), 0.5);
        assert!((lb_kim(&[0.3], &[0.8, 0.9]) - (0.5 + 0.6)).abs() < 1e-12);
    }

    #[test]
    fn precomputed_keogh_rows_are_bit_identical() {
        let mut g = Pcg32::new(52, 3);
        let mut rows = Vec::new();
        for _ in 0..30 {
            let n = 2 + g.below(180) as usize;
            let m = 2 + g.below(180) as usize;
            let x = series(&mut g, n);
            let y = series(&mut g, m);
            let r = band_radius(n, m);
            let env = Envelope::build(&y, DEFAULT_BLOCK);
            keogh_rows_into(&env, n, r, &mut rows);
            assert_eq!(
                lb_keogh_rows(&x, &rows).to_bits(),
                lb_keogh(&x, &env, r).to_bits()
            );
        }
    }

    #[test]
    fn query_extrema_into_matches_envelope_build() {
        let mut g = Pcg32::new(53, 4);
        let mut buf = Vec::new();
        for _ in 0..20 {
            let n = 1 + g.below(200) as usize;
            let x = series(&mut g, n);
            query_extrema_into(&x, DEFAULT_BLOCK, &mut buf);
            let want = Envelope::build(&x, DEFAULT_BLOCK).extrema();
            assert_eq!(buf.len(), want.len());
            for ((al, ah), (bl, bh)) in buf.iter().zip(&want) {
                assert_eq!(al.to_bits(), bl.to_bits());
                assert_eq!(ah.to_bits(), bh.to_bits());
            }
        }
    }
}
