"""L2 JAX model: the matching-pipeline entry points lowered AOT.

Composes the L1 kernels into the exact computations the Rust coordinator
executes via PJRT (one compiled executable per shape bucket):

* ``preprocess``   — Chebyshev de-noise + normalize (paper §3.1.1);
* ``dtw_pair``     — masked DTW distance + traceback choices (§3.1.2);
* ``dtw_batch``    — one query against a batch of references;
* ``match_one``    — fused preprocess(query) -> dtw_batch against
  already-preprocessed references: the whole matching hot path in a single
  HLO module, so XLA fuses the filter scans with the DP loop and the query
  never round-trips to the host in between.

The correlation step (paper eqn. 3) runs on the warping *path*, which needs
a data-dependent backtrack — an O(L) pointer chase the Rust side does
faster than XLA; the kernel hands it the s8 choice matrix.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import cheby, dtw


def preprocess(x, n):
    """f32[L], i32[1] -> f32[L] (see kernels.cheby.preprocess)."""
    return cheby.preprocess(x, n)


def dtw_pair(x, y, nx, ny):
    """f32[L] x2, i32[1] x2 -> (f32[1] dist, s8[L,L] choices)."""
    dists, choices = dtw.dtw_batch(x, y[None, :], nx, ny)
    return dists, choices[0]


def dtw_batch(x, ys, nx, nys):
    """f32[L], f32[B,L], i32[1], i32[B] -> (f32[B], s8[B,L,L])."""
    return dtw.dtw_batch(x, ys, nx, nys)


def match_one(raw_x, ys, nx, nys):
    """Fused hot path: preprocess the raw query, then batched DTW against
    preprocessed references.

    Args:
      raw_x: f32[L] raw (noisy) query series.
      ys: f32[B, L] preprocessed reference series.
      nx: i32[1] query length.
      nys: i32[B] reference lengths.

    Returns:
      ``(query f32[L], dists f32[B], choices s8[B,L,L])`` — the
      preprocessed query is returned too (the Rust side needs it for the
      correlation step).
    """
    q = cheby.preprocess(raw_x, nx)
    dists, choices = dtw.dtw_batch(q, ys, nx, nys)
    return q, dists, choices


def similarity_upper_bound(dists, nx, nys):
    """Cheap screening: path-normalized distance, used by the coordinator to
    skip the correlation step for hopeless references (optimization E-opt2;
    normalized distance and correlation are strongly rank-correlated on
    normalized series)."""
    return dists / (nx + nys).astype(jnp.float32)
