//! Utility substrates.
//!
//! The build environment is fully offline and the vendored crate set does not
//! include `rand`, `serde`, `clap`, `criterion` or a thread-pool crate, so
//! this module implements the pieces the rest of the system needs from
//! scratch: a seeded PRNG with the distributions the workload generators use,
//! a JSON value model with serializer/parser (database persistence, artifact
//! manifests, experiment output), a small CLI parser, descriptive statistics,
//! a `log`-facade backend and a fixed thread pool.

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod sync;
