//! Magnitude normalization (paper §3.1.1: series bounded into `[0,1]`).
//!
//! Both normalizations exist in two forms: the batch functions
//! ([`min_max`], [`z_score`]) used when the whole series is available, and
//! incremental accumulators ([`OnlineMinMax`], [`OnlineZScore`]) for the
//! streaming classifier, which must re-normalize a *growing* prefix as
//! samples arrive. The batch functions delegate to the online structs, so
//! the two paths can never drift apart.

use crate::util::stats::Welford;

/// Incremental min/max tracker — the online form of [`min_max`].
///
/// Feed samples with [`push`](OnlineMinMax::push) /
/// [`observe`](OnlineMinMax::observe), then map any value through
/// [`normalize_value`](OnlineMinMax::normalize_value) using the extrema
/// seen *so far*. Observing an entire series and then normalizing it
/// reproduces the batch [`min_max`] output exactly (same fold order, same
/// arithmetic). The extrema are monotone: `lo` only ever decreases and `hi`
/// only ever increases as more samples arrive — the property the streaming
/// prefix bounds (`crate::streaming::prefix_lb`) rely on.
#[derive(Debug, Clone)]
pub struct OnlineMinMax {
    lo: f64,
    hi: f64,
    n: usize,
}

impl Default for OnlineMinMax {
    fn default() -> Self {
        OnlineMinMax::new()
    }
}

impl OnlineMinMax {
    pub fn new() -> OnlineMinMax {
        OnlineMinMax {
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
            n: 0,
        }
    }

    /// Observe one sample.
    pub fn push(&mut self, x: f64) {
        self.lo = self.lo.min(x);
        self.hi = self.hi.max(x);
        self.n += 1;
    }

    /// Observe a batch of samples.
    pub fn observe(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    /// Samples observed so far.
    pub fn count(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Smallest sample seen (`+inf` before any sample).
    pub fn lo(&self) -> f64 {
        self.lo
    }

    /// Largest sample seen (`-inf` before any sample).
    pub fn hi(&self) -> f64 {
        self.hi
    }

    /// `hi - lo`; `0.0` before any sample.
    pub fn span(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.hi - self.lo
        }
    }

    /// Normalize one value with the extrema seen so far. Degenerate ranges
    /// (constant or empty prefix) map to `0.0`, matching [`min_max`].
    pub fn normalize_value(&self, x: f64) -> f64 {
        let span = self.span();
        if span <= 0.0 {
            0.0
        } else {
            (x - self.lo) / span
        }
    }

    /// Normalize a slice with the extrema seen so far.
    pub fn normalize(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.normalize_value(x)).collect()
    }
}

/// Incremental mean/stddev tracker — the online form of [`z_score`],
/// backed by the same Welford accumulator the metrics registry uses.
#[derive(Debug, Clone, Default)]
pub struct OnlineZScore {
    w: Welford,
}

impl OnlineZScore {
    pub fn new() -> OnlineZScore {
        OnlineZScore::default()
    }

    pub fn push(&mut self, x: f64) {
        self.w.push(x);
    }

    pub fn observe(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.w.count()
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    /// Population standard deviation of the samples seen so far.
    pub fn stddev(&self) -> f64 {
        self.w.stddev()
    }

    /// Standardize one value with the moments seen so far. Degenerate
    /// spreads (constant or empty prefix) map to `0.0`, matching
    /// [`z_score`].
    pub fn normalize_value(&self, x: f64) -> f64 {
        let s = self.stddev();
        if s <= 0.0 {
            0.0
        } else {
            (x - self.mean()) / s
        }
    }

    pub fn normalize(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.normalize_value(x)).collect()
    }
}

/// Min-max normalize into `[0,1]`. A constant series maps to all-zeros
/// (no information; avoids division by zero).
pub fn min_max(xs: &[f64]) -> Vec<f64> {
    let mut mm = OnlineMinMax::new();
    mm.observe(xs);
    mm.normalize(xs)
}

/// Z-score normalize (mean 0, stddev 1); constant series maps to zeros.
pub fn z_score(xs: &[f64]) -> Vec<f64> {
    let mut zs = OnlineZScore::new();
    zs.observe(xs);
    zs.normalize(xs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn min_max_bounds() {
        let y = min_max(&[3.0, -1.0, 7.0, 5.0]);
        assert_eq!(y[1], 0.0);
        assert_eq!(y[2], 1.0);
        for v in &y {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn min_max_preserves_order() {
        let xs = [2.0, 9.0, 4.0, 4.5];
        let y = min_max(&xs);
        for i in 0..xs.len() {
            for j in 0..xs.len() {
                assert_eq!(xs[i] < xs[j], y[i] < y[j]);
            }
        }
    }

    #[test]
    fn constant_series_is_zeros() {
        assert_eq!(min_max(&[5.0; 4]), vec![0.0; 4]);
        assert_eq!(z_score(&[5.0; 4]), vec![0.0; 4]);
    }

    #[test]
    fn empty_ok() {
        assert!(min_max(&[]).is_empty());
        assert!(z_score(&[]).is_empty());
    }

    #[test]
    fn z_score_moments() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64 * 0.7 - 3.0).collect();
        let y = z_score(&xs);
        assert!(crate::util::stats::mean(&y).abs() < 1e-9);
        assert!((crate::util::stats::stddev(&y) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn min_max_scale_invariant() {
        let xs = [1.0, 2.0, 5.0, 3.0];
        let scaled: Vec<f64> = xs.iter().map(|x| 10.0 * x + 4.0).collect();
        assert_eq!(min_max(&xs), min_max(&scaled));
    }

    /// Reference implementations of the pre-delegation batch formulas; the
    /// online structs must reproduce them.
    fn batch_min_max(xs: &[f64]) -> Vec<f64> {
        if xs.is_empty() {
            return Vec::new();
        }
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = hi - lo;
        if span <= 0.0 {
            return vec![0.0; xs.len()];
        }
        xs.iter().map(|x| (x - lo) / span).collect()
    }

    fn batch_z_score(xs: &[f64]) -> Vec<f64> {
        let m = crate::util::stats::mean(xs);
        let s = crate::util::stats::stddev(xs);
        if s <= 0.0 {
            return vec![0.0; xs.len()];
        }
        xs.iter().map(|x| (x - m) / s).collect()
    }

    #[test]
    fn online_min_max_equals_batch_exactly() {
        let mut g = Pcg32::new(130, 1);
        for _ in 0..30 {
            let len = 1 + g.below(200) as usize;
            let xs: Vec<f64> = (0..len).map(|_| (g.f64() - 0.5) * 40.0).collect();
            let got = min_max(&xs);
            let want = batch_min_max(&xs);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn online_z_score_equals_batch_within_rounding() {
        // Welford accumulates mean/variance incrementally, so agreement is
        // to rounding, not bitwise.
        let mut g = Pcg32::new(131, 2);
        for _ in 0..30 {
            let len = 2 + g.below(200) as usize;
            let xs: Vec<f64> = (0..len).map(|_| (g.f64() - 0.5) * 40.0).collect();
            let got = z_score(&xs);
            let want = batch_z_score(&xs);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn online_extrema_are_monotone_and_prefix_consistent() {
        // Normalizing a prefix with an OnlineMinMax fed exactly that prefix
        // matches batch-normalizing the prefix; lo/hi move monotonically.
        let mut g = Pcg32::new(132, 3);
        let xs: Vec<f64> = (0..120).map(|_| g.f64() * 3.0 - 1.0).collect();
        let mut mm = OnlineMinMax::new();
        let (mut last_lo, mut last_hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for p in 1..=xs.len() {
            mm.push(xs[p - 1]);
            assert!(mm.lo() <= last_lo && mm.hi() >= last_hi);
            last_lo = mm.lo();
            last_hi = mm.hi();
            let want = batch_min_max(&xs[..p]);
            let got = mm.normalize(&xs[..p]);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(mm.count(), xs.len());
    }

    #[test]
    fn online_empty_and_degenerate() {
        let mm = OnlineMinMax::new();
        assert!(mm.is_empty());
        assert_eq!(mm.span(), 0.0);
        assert_eq!(mm.normalize_value(3.0), 0.0);
        let mut zs = OnlineZScore::new();
        assert_eq!(zs.normalize_value(3.0), 0.0);
        zs.push(5.0);
        assert_eq!(zs.normalize_value(5.0), 0.0, "single sample has no spread");
    }
}
