"""Chebyshev type-I low-pass design (pure numpy).

Mirrors ``rust/src/signal/chebyshev.rs`` step for step — analog prototype
poles -> pre-warped low-pass scale -> bilinear transform -> second-order
sections — and is pinned against ``scipy.signal.cheby1`` in
``python/tests/test_filters.py``. The resulting coefficients are baked into
the L1 Pallas kernel at trace time, so all three layers (scipy-checked
python, the AOT HLO artifact, and the pure-Rust fallback) filter
identically.
"""

from __future__ import annotations

import numpy as np


def cheby1_sos(order: int, ripple_db: float, cutoff: float) -> np.ndarray:
    """Design an even-order Chebyshev type-I low-pass filter.

    Args:
      order: filter order; must be even and >= 2 (the paper uses 6).
      ripple_db: pass-band ripple in dB (> 0).
      cutoff: cutoff as a fraction of Nyquist, in (0, 1).

    Returns:
      ``(order//2, 6)`` second-order sections ``[b0,b1,b2,1,a1,a2]`` in the
      same layout (and section order) as ``scipy.signal.cheby1(...,
      output='sos')``.
    """
    if order < 2 or order % 2:
        raise ValueError("even order >= 2 required")
    if ripple_db <= 0:
        raise ValueError("ripple must be positive")
    if not 0 < cutoff < 1:
        raise ValueError("cutoff must be in (0,1) of Nyquist")

    n = order
    eps = np.sqrt(10.0 ** (ripple_db / 10.0) - 1.0)
    mu = np.arcsinh(1.0 / eps) / n
    k = np.arange(1, n + 1)
    theta = np.pi * (2 * k - 1) / (2 * n)
    poles = -np.sinh(mu) * np.sin(theta) + 1j * np.cosh(mu) * np.cos(theta)
    gain = np.real(np.prod(-poles)) / np.sqrt(1.0 + eps * eps)

    # Low-pass scale with bilinear pre-warping (fs = 2 convention).
    fs2 = 4.0
    warped = fs2 * np.tan(np.pi * cutoff / 2.0)
    poles = poles * warped
    gain *= warped**n

    # Bilinear transform: z = (fs2 + s) / (fs2 - s); n zeros at z = -1.
    zpoles = (fs2 + poles) / (fs2 - poles)
    gain = gain / np.real(np.prod(fs2 - poles))

    # Pair conjugates into biquads, ascending pole radius (scipy order).
    upper = sorted(
        (p for p in zpoles if p.imag > 0), key=lambda p: abs(p) ** 2
    )
    sos = []
    for p in upper:
        sos.append([1.0, 2.0, 1.0, 1.0, -2.0 * p.real, abs(p) ** 2])
    sos = np.asarray(sos, dtype=np.float64)
    sos[0, :3] *= gain
    return sos


def sosfilt(sos: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Direct Form II transposed cascade, zero initial state.

    Reference implementation (matches ``scipy.signal.sosfilt``); the Pallas
    kernel is checked against this in pytest.
    """
    y = np.asarray(x, dtype=np.float64).copy()
    for b0, b1, b2, _, a1, a2 in sos:
        s1 = 0.0
        s2 = 0.0
        for i in range(len(y)):
            xin = y[i]
            yo = b0 * xin + s1
            s1 = b1 * xin - a1 * yo + s2
            s2 = b2 * xin - a2 * yo
            y[i] = yo
    return y


#: The paper's de-noising filter: 6th order, 0.5 dB ripple, 0.1 x Nyquist.
PAPER_SOS = cheby1_sos(6, 0.5, 0.1)
