//! Service metrics: counters and latency statistics for the serve loop and
//! the perf benches.

use crate::index::SearchStats;
use crate::util::stats::Welford;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub comparisons: AtomicU64,
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// Index-search counters (see [`SearchStats`]): candidates examined and
    /// where the cascade culled them. `index_dtw_evals / index_candidates`
    /// is the live "DTW evaluations not avoided" ratio.
    pub index_candidates: AtomicU64,
    pub index_pruned_lb_kim: AtomicU64,
    pub index_pruned_lb_paa: AtomicU64,
    pub index_pruned_lb_keogh: AtomicU64,
    pub index_abandoned: AtomicU64,
    pub index_dtw_evals: AtomicU64,
    latency: Mutex<Welford>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc_comparisons(&self, n: u64) {
        self.comparisons.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_batches(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_errors(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one index search's pruning counters into the registry.
    pub fn record_search(&self, s: &SearchStats) {
        self.index_candidates.fetch_add(s.candidates, Ordering::Relaxed);
        self.index_pruned_lb_kim
            .fetch_add(s.pruned_lb_kim, Ordering::Relaxed);
        self.index_pruned_lb_paa
            .fetch_add(s.pruned_lb_paa, Ordering::Relaxed);
        self.index_pruned_lb_keogh
            .fetch_add(s.pruned_lb_keogh, Ordering::Relaxed);
        self.index_abandoned.fetch_add(s.abandoned, Ordering::Relaxed);
        self.index_dtw_evals.fetch_add(s.dtw_evals, Ordering::Relaxed);
    }

    /// Snapshot of the accumulated index counters.
    pub fn search_stats(&self) -> SearchStats {
        SearchStats {
            candidates: self.index_candidates.load(Ordering::Relaxed),
            pruned_lb_kim: self.index_pruned_lb_kim.load(Ordering::Relaxed),
            pruned_lb_paa: self.index_pruned_lb_paa.load(Ordering::Relaxed),
            pruned_lb_keogh: self.index_pruned_lb_keogh.load(Ordering::Relaxed),
            abandoned: self.index_abandoned.load(Ordering::Relaxed),
            dtw_evals: self.index_dtw_evals.load(Ordering::Relaxed),
        }
    }

    /// Record a request latency.
    pub fn observe_latency(&self, seconds: f64) {
        self.latency.lock().expect("latency lock").push(seconds);
    }

    /// Time a closure and record its latency.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe_latency(t0.elapsed().as_secs_f64());
        out
    }

    /// Snapshot: (count, mean_s, stddev_s, min_s, max_s).
    pub fn latency_summary(&self) -> (u64, f64, f64, f64, f64) {
        let w = self.latency.lock().expect("latency lock");
        (w.count(), w.mean(), w.stddev(), w.min(), w.max())
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let (n, mean, std, min, max) = self.latency_summary();
        format!(
            "requests={} comparisons={} batches={} errors={} latency: n={} mean={:.1}ms sd={:.1}ms min={:.1}ms max={:.1}ms index: {}",
            self.requests.load(Ordering::Relaxed),
            self.comparisons.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            n,
            mean * 1e3,
            std * 1e3,
            min * 1e3,
            max * 1e3,
            self.search_stats(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc_comparisons(5);
        m.inc_comparisons(3);
        m.inc_batches();
        m.inc_requests();
        m.inc_errors();
        assert_eq!(m.comparisons.load(Ordering::Relaxed), 8);
        assert!(m.report().contains("comparisons=8"));
    }

    #[test]
    fn search_counters_accumulate() {
        let m = Metrics::new();
        let s = SearchStats {
            candidates: 10,
            pruned_lb_kim: 4,
            pruned_lb_paa: 1,
            pruned_lb_keogh: 2,
            abandoned: 1,
            dtw_evals: 2,
        };
        m.record_search(&s);
        m.record_search(&s);
        let total = m.search_stats();
        assert_eq!(total.candidates, 20);
        assert_eq!(total.dtw_evals, 4);
        assert!((total.dtw_fraction() - 0.3).abs() < 1e-12);
        assert!(m.report().contains("candidates=20"), "{}", m.report());
    }

    #[test]
    fn latency_stats() {
        let m = Metrics::new();
        m.observe_latency(0.010);
        m.observe_latency(0.020);
        m.observe_latency(0.030);
        let (n, mean, _, min, max) = m.latency_summary();
        assert_eq!(n, 3);
        assert!((mean - 0.020).abs() < 1e-9);
        assert_eq!(min, 0.010);
        assert_eq!(max, 0.030);
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.inc_comparisons(1);
                        m.observe_latency(0.001);
                    }
                });
            }
        });
        assert_eq!(m.comparisons.load(Ordering::Relaxed), 8000);
        assert_eq!(m.latency_summary().0, 8000);
    }
}
