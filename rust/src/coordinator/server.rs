//! Match-as-a-service: a line-delimited JSON protocol over TCP.
//!
//! Requests (one JSON object per line):
//!   {"cmd": "ping"}
//!   {"cmd": "stats"}
//!   {"cmd": "apps"}
//!   {"cmd": "match", "series": [..], "config": {"mappers": M, "reducers": R,
//!    "split_mb": FS, "input_mb": I}}
//!   {"cmd": "knn", "series": [..], "k": K[, "config": {..}]}
//!
//! The `match` request carries a *raw* captured CPU series (what a real
//! deployment's SysStat agent would send); the server preprocesses it,
//! compares against every stored reference under the same configuration
//! set, and answers with the per-app similarities and the best match.
//!
//! The `knn` request runs the lower-bound-cascade index instead: the k
//! nearest references under the banded-DTW distance — over the whole
//! database, or one configuration set when `config` is given — plus each
//! neighbour's correlation similarity and the pruning counters for this
//! search. The state holds an [`IndexedDb`], so concurrent connections
//! share one immutable envelope cache.

use super::batcher::{prepare_query, similarities_auto};
use super::metrics::Metrics;
use crate::dtw::corr::MATCH_THRESHOLD;
use crate::index::IndexedDb;
use crate::runtime::RuntimeHandle;
use crate::simulator::job::JobConfig;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shared server state.
pub struct ServerState {
    pub db: IndexedDb,
    pub runtime: Option<RuntimeHandle>,
    pub metrics: Metrics,
}

/// The TCP server.
pub struct MatchServer {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
}

impl MatchServer {
    /// Bind to `addr` (use port 0 for an ephemeral port).
    pub fn bind(addr: &str, state: ServerState) -> Result<MatchServer> {
        let listener = TcpListener::bind(addr)?;
        Ok(MatchServer {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Local address (for tests with ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Stop handle: set true and connect once to unblock accept().
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until the stop flag is raised. Each connection is handled on
    /// the pool; one line per request, one line per response.
    pub fn serve(&self, workers: usize) -> Result<()> {
        let pool = ThreadPool::new(workers.max(1));
        log::info!("serving on {}", self.listener.local_addr()?);
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &state) {
                            log::debug!("connection ended: {e:#}");
                        }
                    });
                }
                Err(e) => log::warn!("accept failed: {e}"),
            }
        }
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    // Bound how long an idle connection can pin a pool worker.
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        state.metrics.inc_requests();
        let response = state.metrics.time(|| match handle_request(&line, state) {
            Ok(v) => v,
            Err(e) => {
                state.metrics.inc_errors();
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(format!("{e:#}"))),
                ])
            }
        });
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    log::debug!("peer {peer} disconnected");
    Ok(())
}

/// Dispatch one request line.
pub fn handle_request(line: &str, state: &ServerState) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("pong", Json::Bool(true)),
        ])),
        Some("stats") => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("report", Json::Str(state.metrics.report())),
            ("db_entries", Json::Num(state.db.len() as f64)),
        ])),
        Some("apps") => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "apps",
                Json::arr(
                    state
                        .db
                        .apps()
                        .iter()
                        .map(|a| Json::Str(a.name().to_string()))
                        .collect(),
                ),
            ),
        ])),
        Some("match") => handle_match(&req, state),
        Some("knn") => handle_knn(&req, state),
        _ => Err(anyhow!("unknown cmd")),
    }
}

/// Parse the optional/required pieces shared by `match` and `knn`.
fn parse_series(req: &Json) -> Result<Vec<f64>> {
    let series = req
        .get("series")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing series"))?
        .iter()
        .filter_map(Json::as_f64)
        .collect::<Vec<f64>>();
    if series.len() < 4 {
        return Err(anyhow!("series too short"));
    }
    Ok(series)
}

fn parse_config(v: &Json) -> Result<JobConfig> {
    let num = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("config missing {k}"))
    };
    Ok(JobConfig::new(
        num("mappers")? as usize,
        num("reducers")? as usize,
        num("split_mb")?,
        num("input_mb")?,
    ))
}

/// Index-backed k-NN: exact nearest references under the banded-DTW
/// distance via the lower-bound cascade.
fn handle_knn(req: &Json, state: &ServerState) -> Result<Json> {
    let series = parse_series(req)?;
    let k = req
        .get("k")
        .and_then(Json::as_usize)
        .unwrap_or(1)
        .clamp(1, 100);
    let q = prepare_query(&series);
    let (neighbors, stats) = match req.get("config") {
        Some(cfg) => state.db.knn_in_config(&q, &parse_config(cfg)?.label(), k),
        None => state.db.knn(&q, k),
    };
    state.metrics.record_search(&stats);
    state.metrics.inc_comparisons(stats.dtw_evals);

    let entries = state.db.entries();
    let results = neighbors
        .iter()
        .map(|nb| {
            let e = &entries[nb.index];
            Json::obj(vec![
                ("app", Json::Str(e.app.name().to_string())),
                ("config", Json::Str(e.config_key())),
                ("distance", Json::Num(nb.distance)),
                (
                    "similarity",
                    Json::Num(crate::dtw::corr::similarity_percent_banded(&q, &e.series)),
                ),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("neighbors", Json::arr(results)),
        (
            "stats",
            Json::obj(vec![
                ("candidates", Json::Num(stats.candidates as f64)),
                ("pruned_lb_kim", Json::Num(stats.pruned_lb_kim as f64)),
                ("pruned_lb_paa", Json::Num(stats.pruned_lb_paa as f64)),
                ("pruned_lb_keogh", Json::Num(stats.pruned_lb_keogh as f64)),
                ("abandoned", Json::Num(stats.abandoned as f64)),
                ("dtw_evals", Json::Num(stats.dtw_evals as f64)),
            ]),
        ),
    ]))
}

fn handle_match(req: &Json, state: &ServerState) -> Result<Json> {
    let series = parse_series(req)?;
    let config = parse_config(
        req.get("config")
            .ok_or_else(|| anyhow!("match: missing config"))?,
    )?;

    let refs = state.db.by_config(&config.label());
    let ref_series: Vec<Vec<f64>> = refs.iter().map(|e| e.series.clone()).collect();
    let sims = similarities_auto(state.runtime.as_ref(), &series, &ref_series);
    state.metrics.inc_comparisons(sims.len() as u64);

    let mut results = Vec::new();
    let mut best: Option<(&str, f64)> = None;
    for (e, s) in refs.iter().zip(&sims) {
        results.push(Json::obj(vec![
            ("app", Json::Str(e.app.name().to_string())),
            ("similarity", Json::Num(*s)),
        ]));
        if best.map_or(true, |(_, bs)| *s > bs) {
            best = Some((e.app.name(), *s));
        }
    }
    let (match_app, match_sim) = match best {
        Some((a, s)) if s >= MATCH_THRESHOLD => (Json::Str(a.to_string()), Json::Num(s)),
        Some((_, s)) => (Json::Null, Json::Num(s)),
        None => (Json::Null, Json::Num(0.0)),
    };
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("results", Json::arr(results)),
        ("match", match_app),
        ("best_similarity", match_sim),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::database::profile::ProfileEntry;
    use crate::workloads::AppId;

    fn state_with_db() -> ServerState {
        let mut db = IndexedDb::new();
        let series: Vec<f64> = (0..64).map(|i| 0.5 + 0.5 * ((i as f64) * 0.2).sin()).collect();
        db.insert(ProfileEntry {
            app: AppId::WordCount,
            config: JobConfig::new(4, 2, 10.0, 20.0),
            series: crate::signal::preprocess(&series),
            raw_len: 64,
            completion_secs: 100.0,
        });
        let shifted: Vec<f64> = (0..64)
            .map(|i| 0.5 + 0.5 * (((i + 40) as f64) * 0.2).sin())
            .collect();
        db.insert(ProfileEntry {
            app: AppId::TeraSort,
            config: JobConfig::new(4, 2, 10.0, 20.0),
            series: crate::signal::preprocess(&shifted),
            raw_len: 64,
            completion_secs: 80.0,
        });
        ServerState {
            db,
            runtime: None,
            metrics: Metrics::new(),
        }
    }

    #[test]
    fn ping_roundtrip() {
        let state = state_with_db();
        let resp = handle_request(r#"{"cmd":"ping"}"#, &state).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn match_request_finds_similar_series() {
        let state = state_with_db();
        let series: Vec<f64> = (0..64).map(|i| 0.5 + 0.5 * ((i as f64) * 0.2).sin()).collect();
        let req = Json::obj(vec![
            ("cmd", Json::Str("match".into())),
            ("series", Json::nums(&series)),
            (
                "config",
                Json::obj(vec![
                    ("mappers", Json::Num(4.0)),
                    ("reducers", Json::Num(2.0)),
                    ("split_mb", Json::Num(10.0)),
                    ("input_mb", Json::Num(20.0)),
                ]),
            ),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let best = resp.get("best_similarity").and_then(Json::as_f64).unwrap();
        assert!(best > 90.0, "best={best}");
        assert_eq!(resp.get("match").and_then(Json::as_str), Some("wordcount"));
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        let state = state_with_db();
        assert!(handle_request("not json", &state).is_err());
        assert!(handle_request(r#"{"cmd":"nope"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"match"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn"}"#, &state).is_err());
        assert!(handle_request(r#"{"cmd":"knn","series":[1,2]}"#, &state).is_err());
    }

    #[test]
    fn knn_request_returns_neighbors_and_stats() {
        let state = state_with_db();
        let series: Vec<f64> = (0..64).map(|i| 0.5 + 0.5 * ((i as f64) * 0.2).sin()).collect();
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(2.0)),
        ]);
        let resp = handle_request(&req.to_string(), &state).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let neighbors = resp.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(neighbors.len(), 2);
        // The untouched sine is the query itself: distance 0, first.
        assert_eq!(
            neighbors[0].get("app").and_then(Json::as_str),
            Some("wordcount")
        );
        assert_eq!(neighbors[0].get("distance").and_then(Json::as_f64), Some(0.0));
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("candidates").and_then(Json::as_f64), Some(2.0));
        // The search was folded into the shared metrics registry.
        assert_eq!(state.metrics.search_stats().candidates, 2);

        // Config-scoped search sees only that bucket.
        let scoped = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(5.0)),
            (
                "config",
                Json::obj(vec![
                    ("mappers", Json::Num(4.0)),
                    ("reducers", Json::Num(2.0)),
                    ("split_mb", Json::Num(10.0)),
                    ("input_mb", Json::Num(20.0)),
                ]),
            ),
        ]);
        let resp = handle_request(&scoped.to_string(), &state).unwrap();
        let neighbors = resp.get("neighbors").and_then(Json::as_arr).unwrap();
        assert_eq!(neighbors.len(), 2, "both entries share the config set");
    }

    #[test]
    fn concurrent_knn_requests_share_the_index() {
        let state = std::sync::Arc::new(state_with_db());
        let series: Vec<f64> = (0..64).map(|i| 0.5 + 0.5 * ((i as f64) * 0.2).sin()).collect();
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", Json::nums(&series)),
            ("k", Json::Num(1.0)),
        ])
        .to_string();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let state = std::sync::Arc::clone(&state);
                let req = req.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let resp = handle_request(&req, &state).unwrap();
                        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
                    }
                });
            }
        });
        assert_eq!(state.metrics.search_stats().candidates, 8 * 20 * 2);
    }

    #[test]
    fn tcp_end_to_end() {
        let server = MatchServer::bind("127.0.0.1:0", state_with_db()).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_flag();
        let handle = std::thread::spawn(move || server.serve(2));

        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "line={line}");

        stream.write_all(b"{\"cmd\":\"apps\"}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("wordcount"));

        // Shut down: close our connection first (a pool worker is blocked
        // reading it and serve() joins the pool before returning).
        drop(reader);
        drop(stream);
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // unblock accept
        handle.join().unwrap().unwrap();
    }
}
