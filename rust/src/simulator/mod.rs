//! Pseudo-distributed MapReduce simulator.
//!
//! Replaces the paper's Hadoop 0.20.2 testbed (five daemons on a 2-core
//! Dell Latitude E4300) with a discrete-event simulation that reproduces the
//! mechanisms shaping a job's CPU-utilization time series:
//!
//! * HDFS-style input splits (`FS` parameter) and the Hadoop split rule
//!   `num_maps = max(M, ceil(I/FS))`;
//! * task slots per node (2 map + 2 reduce by default) → map/reduce *waves*;
//! * per-task JVM startup cost, per-task speed jitter → ragged wave edges;
//! * CPU as a processor-shared resource per node (slots can oversubscribe
//!   cores) and disk as a processor-shared resource per node;
//! * reduce slow-start and shuffle gating on map completions → the
//!   mid-job utilization trough;
//! * per-workload cost models calibrated from really executing the
//!   map/reduce functions (see [`crate::workloads`]).
//!
//! The output is the per-second CPU-utilization series the paper's SysStat
//! step produces (§4, Figure 2), both clean and with seeded measurement
//! noise, plus per-node disk/memory series for the cluster-scale extension.

pub mod cluster;
pub mod cpu;
pub mod engine;
pub mod job;
pub mod jobtracker;
pub mod task;

pub use engine::{simulate, simulate_controlled, SimCounters, SimResult, SimTick};

use crate::signal::noise::NoiseModel;
use crate::util::rng::Rng;
use crate::workloads::AppId;

/// Convenience wrapper: simulate `app` under `config` on the default
/// pseudo-distributed cluster and return the *noisy* CPU series (what the
/// paper's profiling step captures) along with the full result.
pub fn profile_run(
    app: AppId,
    config: &job::JobConfig,
    noise: &NoiseModel,
    seed: u64,
) -> SimResult {
    let workload = crate::workloads::workload_for(app);
    let cluster = cluster::ClusterConfig::pseudo_distributed();
    simulate(workload.as_ref(), config, &cluster, noise, &mut Rng::new(seed))
}
