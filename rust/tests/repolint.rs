//! Tier-1 enforcement of the repo's own static-analysis pass: the whole
//! `rust/src` tree must be clean under every mrtuner-lint rule. See
//! `tools/mrtuner-lint/README.md` for the rules and the pragma syntax.

use std::path::Path;

#[test]
fn src_tree_is_lint_clean() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let violations = mrtuner_lint::lint_dir(root).expect("walk rust/src");
    assert!(
        violations.is_empty(),
        "mrtuner-lint found {} violation(s):\n{}",
        violations.len(),
        mrtuner_lint::render(&violations)
    );
}
