//! The discrete-event simulation engine.
//!
//! Advances simulated time between "next completion" events. Between
//! events every rate is constant: CPU is processor-shared per node
//! (slots can oversubscribe cores), disk is processor-shared per node,
//! and reducer shuffles are gated on map completions. The engine emits
//! per-node piecewise-constant CPU / disk / memory timelines which the
//! SysStat-style sampler turns into 1 Hz series.
//!
//! [`simulate_controlled`] additionally closes the paper's control loop:
//! a controller callback observes the clean 1 Hz CPU prefix as it forms
//! and may return a new [`JobConfig`] mid-run, upon which the engine
//! re-plans every not-yet-scheduled map under the new split size and —
//! while still safe — re-partitions the reduce side to the new reducer
//! count. Plain [`simulate`] takes the exact same code path with the
//! controller absent, so its float and RNG behavior is untouched.

use super::cluster::ClusterConfig;
use super::cpu::Timeline;
use super::job::JobConfig;
use super::jobtracker::JobTracker;
use super::task::{
    map_spec, phase_mem_mb, plan_job, reduce_spec, JobPlan, PhaseKind, TaskKind, TaskSpec,
};
use crate::signal::noise::NoiseModel;
use crate::util::rng::Rng;
use crate::workloads::Workload;

const EPS: f64 = 1e-9;
/// Background utilization of the five Hadoop daemons + OS (fraction of all
/// cores) — keeps idle periods slightly above zero like real SysStat traces.
const DAEMON_BASELINE: f64 = 0.04;

/// Per-node resource series (the future-work "3 time series per node").
#[derive(Debug, Clone)]
pub struct NodeSeries {
    pub cpu: Vec<f64>,
    pub disk: Vec<f64>,
    pub mem: Vec<f64>,
}

/// Aggregate counters from one simulated job.
#[derive(Debug, Clone, Default)]
pub struct SimCounters {
    pub map_tasks: usize,
    pub reduce_tasks: usize,
    pub speculative_attempts: usize,
    pub shuffle_mb: f64,
    pub events: u64,
    /// Mid-run configuration changes applied by a controller.
    pub reconfigurations: usize,
}

/// Result of one simulated job execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Job completion time in simulated seconds.
    pub completion_secs: f64,
    /// Clean cluster-wide CPU-utilization series, 1 Hz, in `[0,1]`.
    pub cpu_clean: Vec<f64>,
    /// The same series with seeded measurement noise (what profiling sees).
    pub cpu_noisy: Vec<f64>,
    /// Per-node CPU / disk / memory series for the cluster-scale extension.
    pub per_node: Vec<NodeSeries>,
    pub counters: SimCounters,
}

impl SimResult {
    /// Replay this run's noisy CPU capture as a live stream — what a real
    /// deployment's SysStat agent would deliver to the streaming
    /// classifier, batch by batch.
    pub fn live_stream(&self) -> LiveStream {
        LiveStream::new(self.cpu_noisy.clone())
    }
}

/// A recorded CPU capture replayed incrementally: the simulator-side
/// source for `streaming::StreamSession` feeds.
#[derive(Debug, Clone)]
pub struct LiveStream {
    series: Vec<f64>,
    pos: usize,
}

impl LiveStream {
    pub fn new(series: Vec<f64>) -> LiveStream {
        LiveStream { series, pos: 0 }
    }

    /// Total length of the underlying capture (the streaming session's
    /// `FinalLen::Known` hint; a real deployment would predict this from
    /// the job's progress counters).
    pub fn final_len(&self) -> usize {
        self.series.len()
    }

    /// Samples not yet delivered.
    pub fn remaining(&self) -> usize {
        self.series.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.pos >= self.series.len()
    }

    /// Deliver up to `n` more samples, or `None` when the run is over.
    pub fn next_batch(&mut self, n: usize) -> Option<&[f64]> {
        if self.is_done() || n == 0 {
            return None;
        }
        let end = (self.pos + n).min(self.series.len());
        let batch = &self.series[self.pos..end];
        self.pos = end;
        Some(batch)
    }
}

/// A progress snapshot handed to a [`simulate_controlled`] controller
/// whenever new complete simulated seconds exist.
#[derive(Debug)]
pub struct SimTick<'a> {
    /// Current simulated time (seconds).
    pub t: f64,
    /// Clean cluster-mean CPU samples (1 Hz, `[0,1]`) for the seconds
    /// completed since the previous tick — concatenating them across
    /// ticks reproduces the run's `cpu_clean` prefix.
    pub new_samples: &'a [f64],
    pub maps_done: usize,
    pub maps_total: usize,
    pub reduces_done: usize,
    pub reduces_total: usize,
    /// Configuration currently in force (reflects prior reconfigurations).
    pub config: JobConfig,
}

impl SimTick<'_> {
    /// Task-weighted completion fraction in `[0,1]`.
    pub fn progress(&self) -> f64 {
        let total = self.maps_total + self.reduces_total;
        if total == 0 {
            return 1.0;
        }
        (self.maps_done + self.reduces_done) as f64 / total as f64
    }
}

/// One running attempt of a logical task.
#[derive(Debug, Clone)]
struct Attempt {
    logical: usize, // index into specs
    node: usize,
    phase: usize,
    cpu_rem: f64,
    io_rem: f64,
    fixed_rem: f64,
    speed: f64,
    speculative: bool,
}

struct EngineState {
    /// All task specs ever planned; reconfiguration appends, never removes
    /// (retired specs stay so logical ids remain stable).
    specs: Vec<TaskSpec>,
    tracker: JobTracker,
    running: Vec<Attempt>,
    /// Free slots per node: (map, reduce).
    free_map: Vec<usize>,
    free_reduce: Vec<usize>,
    /// Shuffle bytes made available / consumed per reduce *slot*.
    shuffle_avail: Vec<f64>,
    shuffle_taken: Vec<f64>,
    /// Logical-task attempt bookkeeping for speculative execution.
    attempts_of: Vec<usize>,
    done: Vec<bool>,
    counters: SimCounters,
    rng_spec: Rng,
    jitter: f64,
    /// Reduce slot → spec index for the current reduce generation.
    reduce_logical: Vec<usize>,
    /// Current partition weights (len == reduce slots, sums to 1).
    weights: Vec<f64>,
    /// Per-spec map intermediate output MB (0 for reduces).
    map_out_of: Vec<f64>,
    /// Σ `map_out_of` over completed maps (shuffle credit already granted).
    completed_map_out: f64,
    next_map_index: usize,
}

impl EngineState {
    fn spec(&self, logical: usize) -> &TaskSpec {
        &self.specs[logical]
    }

    fn is_map(&self, logical: usize) -> bool {
        matches!(self.specs[logical].kind, TaskKind::Map { .. })
    }

    /// The reduce slot an attempt's shuffle accounting lives in.
    fn reduce_slot(&self, logical: usize) -> Option<usize> {
        match self.specs[logical].kind {
            TaskKind::Reduce { index } => Some(index),
            TaskKind::Map { .. } => None,
        }
    }

    /// Initialize an attempt's phase work, applying the speed factor to CPU.
    fn init_phase(&self, a: &mut Attempt) {
        let ph = &self.spec(a.logical).phases[a.phase];
        a.cpu_rem = ph.cpu_secs * a.speed;
        a.io_rem = ph.io_mb;
        a.fixed_rem = ph.fixed_secs;
    }

    /// Remaining shuffle headroom for a reduce attempt (INF for others).
    fn shuffle_headroom(&self, a: &Attempt) -> f64 {
        let spec = self.spec(a.logical);
        if !matches!(spec.phases[a.phase].kind, PhaseKind::Shuffle) {
            return f64::INFINITY;
        }
        match self.reduce_slot(a.logical) {
            Some(r) => (self.shuffle_avail[r] - self.shuffle_taken[r]).max(0.0),
            None => f64::INFINITY,
        }
    }

    /// Whether the attempt currently has disk work it is allowed to do.
    fn io_active(&self, a: &Attempt) -> bool {
        a.io_rem > EPS && self.shuffle_headroom(a) > EPS
    }

    /// Straggler-jitter speed factor for a freshly planned task.
    fn draw_speed(&mut self) -> f64 {
        if self.jitter > 0.0 {
            self.rng_spec.lognormal(0.0, self.jitter)
        } else {
            1.0
        }
    }

    /// Append a spec, growing the parallel bookkeeping arrays.
    fn push_spec(&mut self, spec: TaskSpec, map_out: f64) -> usize {
        let logical = self.specs.len();
        self.specs.push(spec);
        self.map_out_of.push(map_out);
        self.done.push(false);
        self.attempts_of.push(0);
        logical
    }
}

/// Simulate one job end-to-end.
pub fn simulate(
    workload: &dyn Workload,
    config: &JobConfig,
    cluster: &ClusterConfig,
    noise: &NoiseModel,
    rng: &mut Rng,
) -> SimResult {
    simulate_inner(workload, config, cluster, noise, rng, None)
}

/// Simulate one job under a live controller: `ctl` is invoked whenever new
/// complete simulated seconds exist, sees the clean CPU prefix plus task
/// progress, and may return a new configuration to apply mid-run.
pub fn simulate_controlled(
    workload: &dyn Workload,
    config: &JobConfig,
    cluster: &ClusterConfig,
    noise: &NoiseModel,
    rng: &mut Rng,
    ctl: &mut dyn FnMut(&SimTick<'_>) -> Option<JobConfig>,
) -> SimResult {
    simulate_inner(workload, config, cluster, noise, rng, Some(ctl))
}

fn simulate_inner(
    workload: &dyn Workload,
    config: &JobConfig,
    cluster: &ClusterConfig,
    noise: &NoiseModel,
    rng: &mut Rng,
    mut ctl: Option<&mut dyn FnMut(&SimTick<'_>) -> Option<JobConfig>>,
) -> SimResult {
    let plan: JobPlan = plan_job(workload, config, cluster, rng);
    let num_maps = plan.maps.len();
    let num_reduces = plan.reduces.len();
    let JobPlan {
        maps,
        reduces,
        map_out_mb,
        weights,
    } = plan;
    let mut specs = maps;
    specs.extend(reduces);
    let map_out_of: Vec<f64> = (0..specs.len())
        .map(|i| if i < num_maps { map_out_mb } else { 0.0 })
        .collect();

    let mut st = EngineState {
        specs,
        tracker: JobTracker::new(num_maps, num_reduces, cluster.reduce_slowstart),
        running: Vec::new(),
        free_map: vec![cluster.map_slots_per_node; cluster.nodes],
        free_reduce: vec![cluster.reduce_slots_per_node; cluster.nodes],
        shuffle_avail: vec![0.0; num_reduces],
        shuffle_taken: vec![0.0; num_reduces],
        attempts_of: vec![0; num_maps + num_reduces],
        done: vec![false; num_maps + num_reduces],
        counters: SimCounters {
            map_tasks: num_maps,
            reduce_tasks: num_reduces,
            ..SimCounters::default()
        },
        rng_spec: rng.fork(),
        jitter: cluster.task_jitter,
        reduce_logical: (0..num_reduces).map(|r| num_maps + r).collect(),
        weights,
        map_out_of,
        completed_map_out: 0.0,
        next_map_index: num_maps,
    };

    let mut t = 0.0f64;
    let mut cpu_tl: Vec<Timeline> = (0..cluster.nodes).map(|_| Timeline::new()).collect();
    let mut disk_tl: Vec<Timeline> = (0..cluster.nodes).map(|_| Timeline::new()).collect();
    let mut mem_tl: Vec<Timeline> = (0..cluster.nodes).map(|_| Timeline::new()).collect();
    let cores = cluster.cores_per_node as f64;

    // Controlled-mode incremental sampling state (untouched when ctl is
    // None, so plain `simulate` pays nothing).
    let mut cur_cfg = *config;
    let mut sampled_upto = 0usize;
    let mut cursors = vec![0usize; cluster.nodes];

    let max_events = 50_000_000u64;
    loop {
        // 0. Controller tick: every second already fully in the past is
        //    final (the next timeline push happens at the current `t`), so
        //    sample the new complete seconds and let the controller react.
        if let Some(f) = ctl.as_mut() {
            let whole = t.floor() as usize;
            if whole > sampled_upto {
                let mut means = vec![0.0f64; whole - sampled_upto];
                for node in 0..cluster.nodes {
                    let vals = cpu_tl[node].sample_seconds(sampled_upto, whole, &mut cursors[node]);
                    for (k, v) in vals.iter().enumerate() {
                        means[k] += (v / cores).clamp(0.0, 1.0);
                    }
                }
                for m in &mut means {
                    *m /= cluster.nodes as f64;
                }
                sampled_upto = whole;
                let tick = SimTick {
                    t,
                    new_samples: &means,
                    maps_done: st.tracker.completed_maps,
                    maps_total: st.tracker.total_maps,
                    reduces_done: st.tracker.completed_reduces,
                    reduces_total: st.tracker.total_reduces,
                    config: cur_cfg,
                };
                if let Some(new_cfg) = (**f)(&tick) {
                    reconfigure(&mut st, workload, &new_cfg);
                    cur_cfg = new_cfg;
                }
            }
        }

        // 1. Schedule: fill free slots; then settle zero-work phases; repeat
        //    until stable (a settled completion may free a slot).
        loop {
            let scheduled = schedule(&mut st, cluster);
            let settled = settle(&mut st);
            if !scheduled && !settled {
                break;
            }
        }

        if st.tracker.all_done() {
            break;
        }
        st.counters.events += 1;
        assert!(st.counters.events < max_events, "simulation runaway");
        assert!(
            !st.running.is_empty(),
            "deadlock: nothing running but job incomplete"
        );

        // 2. Compute per-node rates.
        let mut n_cpu = vec![0usize; cluster.nodes];
        let mut n_io = vec![0usize; cluster.nodes];
        for a in &st.running {
            if a.cpu_rem > EPS {
                n_cpu[a.node] += 1;
            }
            if st.io_active(a) {
                n_io[a.node] += 1;
            }
        }
        let cpu_rate: Vec<f64> = n_cpu
            .iter()
            .map(|&n| {
                if n == 0 {
                    0.0
                } else {
                    (cluster.cores_per_node as f64 / n as f64).min(1.0)
                }
            })
            .collect();
        let io_rate: Vec<f64> = n_io
            .iter()
            .map(|&n| if n == 0 { 0.0 } else { cluster.disk_mb_s / n as f64 })
            .collect();

        // 3. Record resource usage for this interval.
        let mut cpu_used = vec![DAEMON_BASELINE * cluster.cores_per_node as f64; cluster.nodes];
        let mut mem_used = vec![300.0f64; cluster.nodes]; // daemons' RSS
        for a in &st.running {
            let ph = &st.spec(a.logical).phases[a.phase];
            cpu_used[a.node] += if a.cpu_rem > EPS {
                cpu_rate[a.node]
            } else if st.io_active(a) {
                ph.idle_cpu_frac
            } else if a.fixed_rem > EPS {
                0.5 * ph.idle_cpu_frac // waiting on the framework
            } else {
                0.02 // blocked on shuffle
            };
            mem_used[a.node] += phase_mem_mb(ph.kind, ph.io_mb.max(ph.cpu_secs));
        }
        for node in 0..cluster.nodes {
            cpu_tl[node].push(t, cpu_used[node].min(cluster.cores_per_node as f64));
            disk_tl[node].push(t, if n_io[node] > 0 { 1.0 } else { 0.0 });
            mem_tl[node].push(t, (mem_used[node] / cluster.mem_mb).min(1.0));
        }

        // 4. Time to next completion.
        let mut dt = f64::INFINITY;
        for a in &st.running {
            if a.cpu_rem > EPS {
                dt = dt.min(a.cpu_rem / cpu_rate[a.node]);
            }
            if a.fixed_rem > EPS {
                dt = dt.min(a.fixed_rem);
            }
            if st.io_active(a) {
                let doable = a.io_rem.min(st.shuffle_headroom(a));
                dt = dt.min(doable / io_rate[a.node]);
            }
        }
        assert!(
            dt.is_finite() && dt > 0.0,
            "no progress possible at t={t}: running={} ",
            st.running.len()
        );

        // 5. Advance.
        t += dt;
        let mut shuffle_deltas: Vec<(usize, f64)> = Vec::new();
        for a in &mut st.running {
            if a.cpu_rem > EPS {
                a.cpu_rem = (a.cpu_rem - dt * cpu_rate[a.node]).max(0.0);
            }
            if a.fixed_rem > EPS {
                a.fixed_rem = (a.fixed_rem - dt).max(0.0);
            }
            // Recompute io_active inline (borrow rules: use the headroom
            // captured before mutation — headroom only grows mid-interval
            // if a map completes, which cannot happen inside an interval).
            let spec = &st.specs[a.logical];
            let is_shuffle = matches!(spec.phases[a.phase].kind, PhaseKind::Shuffle);
            let slot = match spec.kind {
                TaskKind::Reduce { index } => index,
                TaskKind::Map { .. } => usize::MAX,
            };
            let headroom = if is_shuffle {
                (st.shuffle_avail[slot] - st.shuffle_taken[slot]).max(0.0)
            } else {
                f64::INFINITY
            };
            if a.io_rem > EPS && headroom > EPS {
                let consumed = (dt * io_rate[a.node]).min(a.io_rem).min(headroom);
                a.io_rem = (a.io_rem - consumed).max(0.0);
                if is_shuffle {
                    shuffle_deltas.push((slot, consumed));
                }
            }
        }
        for (r, c) in shuffle_deltas {
            st.shuffle_taken[r] += c;
            st.counters.shuffle_mb += c;
        }
    }

    // Close timelines and sample.
    let t_end = t.max(1.0);
    for node in 0..cluster.nodes {
        cpu_tl[node].push(t_end, 0.0);
        disk_tl[node].push(t_end, 0.0);
        mem_tl[node].push(t_end, 0.0);
    }
    let per_node: Vec<NodeSeries> = (0..cluster.nodes)
        .map(|node| NodeSeries {
            cpu: cpu_tl[node]
                .sample_per_second(t_end)
                .into_iter()
                .map(|v| (v / cores).clamp(0.0, 1.0))
                .collect(),
            disk: disk_tl[node].sample_per_second(t_end),
            mem: mem_tl[node].sample_per_second(t_end),
        })
        .collect();
    let len = per_node[0].cpu.len();
    let cpu_clean: Vec<f64> = (0..len)
        .map(|i| per_node.iter().map(|n| n.cpu[i]).sum::<f64>() / cluster.nodes as f64)
        .collect();
    let cpu_noisy = noise.apply(&cpu_clean, rng);

    SimResult {
        completion_secs: t,
        cpu_clean,
        cpu_noisy,
        per_node,
        counters: st.counters,
    }
}

/// Apply a mid-run configuration change: every not-yet-scheduled map is
/// re-planned under the new split size, and — while no reduce has made
/// any progress (no shuffle byte consumed, every running reducer still in
/// startup) — the reduce side is re-partitioned to the new reducer count.
fn reconfigure(st: &mut EngineState, workload: &dyn Workload, new_cfg: &JobConfig) {
    let costs = workload.default_costs();

    // Maps: drain the FIFO queue and re-split the remaining input.
    let drained = st.tracker.take_pending_maps();
    if !drained.is_empty() {
        let remaining_input: f64 = drained.iter().map(|&m| st.specs[m].phases[1].io_mb).sum();
        for &m in &drained {
            st.done[m] = true; // retired before ever running
        }
        st.counters.map_tasks -= drained.len();
        let target = (new_cfg.input_mb / new_cfg.num_map_tasks() as f64).max(1e-6);
        let n_new = ((remaining_input / target).round() as usize).max(1);
        let per_map = remaining_input / n_new as f64;
        let per_out = per_map * costs.map_selectivity;
        let mut ids = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            let index = st.next_map_index;
            st.next_map_index += 1;
            let speed = st.draw_speed();
            ids.push(st.push_spec(map_spec(index, per_map, per_out, &costs, speed), per_out));
        }
        st.counters.map_tasks += n_new;
        st.tracker.add_pending_maps(ids);
    }

    // Reduces: wholesale replacement, only while it cannot lose work.
    let r_new = new_cfg.reducers.max(1);
    let safe = st.tracker.completed_reduces == 0
        && st.shuffle_taken.iter().all(|&v| v <= EPS)
        && st
            .running
            .iter()
            .all(|a| st.is_map(a.logical) || a.phase == 0);
    if safe && r_new != st.reduce_logical.len() {
        // Kill startup-phase reduce attempts and return their slots.
        let mut i = 0;
        while i < st.running.len() {
            if st.is_map(st.running[i].logical) {
                i += 1;
                continue;
            }
            let a = st.running.swap_remove(i);
            st.attempts_of[a.logical] -= 1;
            st.done[a.logical] = true; // retired
            st.free_reduce[a.node] += 1;
        }
        // Retire the old generation's remaining (pending) slots wholesale.
        let old = std::mem::take(&mut st.reduce_logical);
        for logical in old {
            st.done[logical] = true;
        }
        let weights = workload.partition_weights(r_new, &mut st.rng_spec);
        // Map-output mass reducers will ever see: completed + live maps.
        let mut total_out = st.completed_map_out;
        for (m, out) in st.map_out_of.iter().enumerate() {
            if !st.done[m] && matches!(st.specs[m].kind, TaskKind::Map { .. }) {
                total_out += out;
            }
        }
        let mut logicals = Vec::with_capacity(r_new);
        for (slot, w) in weights.iter().enumerate() {
            let part_mb = total_out * w;
            let speed = st.draw_speed();
            logicals.push(st.push_spec(reduce_spec(slot, part_mb, 0.0, &costs, speed), 0.0));
        }
        st.reduce_logical = logicals;
        st.shuffle_avail = weights.iter().map(|w| st.completed_map_out * w).collect();
        st.shuffle_taken = vec![0.0; r_new];
        st.weights = weights;
        st.tracker.reset_reduces(r_new);
        st.counters.reduce_tasks = r_new;
    }
    st.counters.reconfigurations += 1;
}

/// Fill free slots from the pending queues (and speculatively re-execute
/// stragglers when enabled). Returns true if anything was scheduled.
fn schedule(st: &mut EngineState, cluster: &ClusterConfig) -> bool {
    let mut any = false;
    // Maps first (FIFO priority), round-robin over nodes with free slots.
    loop {
        let Some(node) = (0..cluster.nodes).find(|&n| st.free_map[n] > 0) else {
            break;
        };
        let Some(m) = st.tracker.next_map() else {
            break;
        };
        launch(st, m, node, false);
        st.free_map[node] -= 1;
        any = true;
    }
    loop {
        let Some(node) = (0..cluster.nodes).find(|&n| st.free_reduce[n] > 0) else {
            break;
        };
        let Some(r) = st.tracker.next_reduce() else {
            break;
        };
        let logical = st.reduce_logical[r];
        launch(st, logical, node, false);
        st.free_reduce[node] -= 1;
        any = true;
    }
    if cluster.speculative {
        any |= speculate(st, cluster, true);
        any |= speculate(st, cluster, false);
    }
    any
}

/// Launch one speculative duplicate of the slowest single-attempt task of
/// the given kind, if queues are empty and a slot is free.
fn speculate(st: &mut EngineState, cluster: &ClusterConfig, maps: bool) -> bool {
    if maps && st.tracker.has_pending_maps() {
        return false;
    }
    if !maps && st.tracker.has_pending_reduces() {
        return false;
    }
    let free = if maps { &st.free_map } else { &st.free_reduce };
    let Some(node) = (0..cluster.nodes).find(|&n| free[n] > 0) else {
        return false;
    };
    // Pick the running attempt with the most remaining work whose logical
    // task has a single attempt.
    let mut best: Option<(usize, f64)> = None;
    for a in &st.running {
        if st.is_map(a.logical) != maps || a.speculative {
            continue;
        }
        if st.attempts_of[a.logical] != 1 || st.done[a.logical] {
            continue;
        }
        let rem: f64 = a.cpu_rem
            + st.spec(a.logical).phases[a.phase + 1..]
                .iter()
                .map(|p| p.cpu_secs)
                .sum::<f64>();
        if rem > 2.0 * st.spec(a.logical).phases[0].cpu_secs.max(1.0)
            && best.map_or(true, |(_, b)| rem > b)
        {
            best = Some((a.logical, rem));
        }
    }
    let Some((logical, _)) = best else {
        return false;
    };
    launch(st, logical, node, true);
    if maps {
        st.free_map[node] -= 1;
    } else {
        st.free_reduce[node] -= 1;
    }
    st.counters.speculative_attempts += 1;
    true
}

fn launch(st: &mut EngineState, logical: usize, node: usize, speculative: bool) {
    let speed = if speculative && st.jitter > 0.0 {
        st.rng_spec.lognormal(0.0, st.jitter)
    } else {
        st.spec(logical).speed
    };
    let mut a = Attempt {
        logical,
        node,
        phase: 0,
        cpu_rem: 0.0,
        io_rem: 0.0,
        fixed_rem: 0.0,
        speed,
        speculative,
    };
    st.init_phase(&mut a);
    st.attempts_of[logical] += 1;
    st.running.push(a);
}

/// Advance attempts through zero-work phase boundaries and handle task
/// completions. Returns true if any state changed.
fn settle(st: &mut EngineState) -> bool {
    let mut changed = false;
    let mut i = 0;
    while i < st.running.len() {
        let a = &st.running[i];
        let phase_done = a.cpu_rem <= EPS
            && a.fixed_rem <= EPS
            && (a.io_rem <= EPS
                // A shuffle with all expected bytes consumed may carry float
                // dust in io_rem; treat as done when nothing more can come.
                || (matches!(
                    st.spec(a.logical).phases[a.phase].kind,
                    PhaseKind::Shuffle
                ) && shuffle_fully_fetched(st, a)));
        if !phase_done {
            i += 1;
            continue;
        }
        changed = true;
        let last_phase = a.phase + 1 == st.spec(a.logical).phases.len();
        if !last_phase {
            let (logical, next) = (a.logical, a.phase + 1);
            let (cpu, io, fixed) = {
                let ph = &st.specs[logical].phases[next];
                (ph.cpu_secs, ph.io_mb, ph.fixed_secs)
            };
            let a = &mut st.running[i];
            a.phase = next;
            a.cpu_rem = cpu * a.speed;
            a.io_rem = io;
            a.fixed_rem = fixed;
            i += 1;
            continue;
        }
        // Task attempt finished → logical completion (first wins).
        let logical = a.logical;
        let node = a.node;
        st.running.swap_remove(i);
        st.attempts_of[logical] -= 1;
        if st.is_map(logical) {
            st.free_map[node] += 1;
        } else {
            st.free_reduce[node] += 1;
        }
        if st.done[logical] {
            continue; // sibling already completed the logical task
        }
        st.done[logical] = true;
        // Kill sibling attempts.
        let mut k = 0;
        while k < st.running.len() {
            if st.running[k].logical == logical {
                let sib = st.running.swap_remove(k);
                st.attempts_of[logical] -= 1;
                if st.is_map(logical) {
                    st.free_map[sib.node] += 1;
                } else {
                    st.free_reduce[sib.node] += 1;
                }
            } else {
                k += 1;
            }
        }
        if st.is_map(logical) {
            st.tracker.on_map_complete();
            // Publish this map's partition bytes to every reducer.
            let out = st.map_out_of[logical];
            st.completed_map_out += out;
            for r in 0..st.shuffle_avail.len() {
                st.shuffle_avail[r] += out * st.weights[r];
            }
        } else {
            st.tracker.on_reduce_complete();
        }
    }
    changed
}

/// All maps done and this reducer consumed everything that will ever come.
fn shuffle_fully_fetched(st: &EngineState, a: &Attempt) -> bool {
    let Some(r) = st.reduce_slot(a.logical) else {
        return false;
    };
    st.tracker.completed_maps == st.tracker.total_maps
        && st.shuffle_avail[r] - st.shuffle_taken[r] <= 1e-6
        && a.io_rem <= 1e-3 // only float dust may remain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{workload_for, AppId};

    fn run(app: AppId, cfg: JobConfig, seed: u64) -> SimResult {
        let w = workload_for(app);
        let cluster = ClusterConfig::pseudo_distributed();
        simulate(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::none(),
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn completes_and_is_deterministic() {
        let cfg = JobConfig::new(4, 2, 10.0, 20.0);
        let a = run(AppId::WordCount, cfg, 42);
        let b = run(AppId::WordCount, cfg, 42);
        assert!(a.completion_secs > 0.0);
        assert_eq!(a.completion_secs, b.completion_secs);
        assert_eq!(a.cpu_clean, b.cpu_clean);
    }

    #[test]
    fn live_stream_replays_the_capture_exactly() {
        let r = run(AppId::WordCount, JobConfig::new(4, 2, 10.0, 20.0), 9);
        let mut stream = r.live_stream();
        assert_eq!(stream.final_len(), r.cpu_noisy.len());
        let mut replayed = Vec::new();
        while let Some(batch) = stream.next_batch(7) {
            assert!(batch.len() <= 7 && !batch.is_empty());
            replayed.extend_from_slice(batch);
        }
        assert!(stream.is_done());
        assert_eq!(stream.remaining(), 0);
        assert_eq!(replayed, r.cpu_noisy);
        assert!(stream.next_batch(7).is_none());
        assert!(LiveStream::new(Vec::new()).next_batch(4).is_none());
    }

    #[test]
    fn utilization_in_unit_range() {
        let r = run(AppId::TeraSort, JobConfig::new(6, 4, 10.0, 40.0), 1);
        assert!(!r.cpu_clean.is_empty());
        for &u in &r.cpu_clean {
            assert!((0.0..=1.0).contains(&u), "u={u}");
        }
    }

    #[test]
    fn series_length_matches_completion() {
        let r = run(AppId::Grep, JobConfig::new(3, 2, 10.0, 30.0), 2);
        assert_eq!(r.cpu_clean.len(), r.completion_secs.ceil() as usize);
        assert_eq!(r.cpu_noisy.len(), r.cpu_clean.len());
    }

    #[test]
    fn more_input_takes_longer() {
        let small = run(AppId::WordCount, JobConfig::new(4, 2, 10.0, 20.0), 3);
        let large = run(AppId::WordCount, JobConfig::new(4, 2, 10.0, 80.0), 3);
        assert!(
            large.completion_secs > 1.5 * small.completion_secs,
            "small={} large={}",
            small.completion_secs,
            large.completion_secs
        );
    }

    #[test]
    fn wordcount_is_map_heavy_terasort_reduce_heavy() {
        // Compare where the CPU mass sits in time: WordCount's centre of
        // mass should be earlier (map-dominated) than TeraSort's.
        let cfg = JobConfig::new(8, 4, 10.0, 60.0);
        let wc = run(AppId::WordCount, cfg, 4);
        let ts = run(AppId::TeraSort, cfg, 4);
        let centre = |s: &[f64]| {
            let total: f64 = s.iter().sum();
            let m: f64 = s.iter().enumerate().map(|(i, v)| i as f64 * v).sum();
            m / total / s.len() as f64
        };
        let cwc = centre(&wc.cpu_clean);
        let cts = centre(&ts.cpu_clean);
        assert!(cwc < cts, "wordcount centre {cwc} vs terasort {cts}");
    }

    #[test]
    fn shuffle_conservation() {
        // Total shuffled MB equals input × map selectivity.
        let cfg = JobConfig::new(5, 3, 10.0, 50.0);
        let w = workload_for(AppId::TeraSort);
        let cluster = ClusterConfig::pseudo_distributed();
        let r = simulate(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::none(),
            &mut Rng::new(5),
        );
        let expected = 50.0 * w.default_costs().map_selectivity;
        assert!(
            (r.counters.shuffle_mb - expected).abs() < 0.1,
            "{} vs {expected}",
            r.counters.shuffle_mb
        );
    }

    #[test]
    fn speculative_execution_launches_and_completes() {
        let w = workload_for(AppId::WordCount);
        let mut cluster = ClusterConfig::pseudo_distributed();
        cluster.speculative = true;
        cluster.task_jitter = 0.5; // aggressive stragglers
        let cfg = JobConfig::new(6, 2, 10.0, 30.0);
        // Whether the speculation window opens depends on how the final
        // wave's horse race falls; sweep seeds and require that it fires
        // for a solid majority.
        let mut fired = 0;
        for seed in 0..10u64 {
            let r = simulate(
                w.as_ref(),
                &cfg,
                &cluster,
                &NoiseModel::none(),
                &mut Rng::new(seed),
            );
            assert!(r.completion_secs > 0.0);
            if r.counters.speculative_attempts > 0 {
                fired += 1;
            }
        }
        assert!(fired >= 5, "speculation fired in only {fired}/10 runs");
    }

    #[test]
    fn multi_node_cluster_runs() {
        let w = workload_for(AppId::EximParse);
        let cluster = ClusterConfig::cluster(4);
        let cfg = JobConfig::new(16, 8, 10.0, 100.0);
        let r = simulate(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::none(),
            &mut Rng::new(7),
        );
        assert_eq!(r.per_node.len(), 4);
        for node in &r.per_node {
            assert_eq!(node.cpu.len(), r.cpu_clean.len());
            for &v in node.mem.iter().chain(node.disk.iter()).chain(node.cpu.iter()) {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn more_nodes_faster() {
        let w = workload_for(AppId::WordCount);
        let cfg = JobConfig::new(32, 8, 10.0, 200.0);
        let r1 = simulate(
            w.as_ref(),
            &cfg,
            &ClusterConfig::cluster(1),
            &NoiseModel::none(),
            &mut Rng::new(8),
        );
        let r4 = simulate(
            w.as_ref(),
            &cfg,
            &ClusterConfig::cluster(4),
            &NoiseModel::none(),
            &mut Rng::new(8),
        );
        assert!(r4.completion_secs < r1.completion_secs / 2.0);
    }

    #[test]
    fn null_controller_is_identical_to_plain_simulate() {
        let w = workload_for(AppId::TeraSort);
        let cluster = ClusterConfig::pseudo_distributed();
        let cfg = JobConfig::new(6, 3, 10.0, 40.0);
        let plain = simulate(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::default(),
            &mut Rng::new(11),
        );
        let mut ticks = 0usize;
        let controlled = simulate_controlled(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::default(),
            &mut Rng::new(11),
            &mut |_| {
                ticks += 1;
                None
            },
        );
        assert!(ticks > 0);
        assert_eq!(plain.completion_secs, controlled.completion_secs);
        assert_eq!(plain.cpu_clean, controlled.cpu_clean);
        assert_eq!(plain.cpu_noisy, controlled.cpu_noisy);
        assert_eq!(controlled.counters.reconfigurations, 0);
    }

    #[test]
    fn tick_samples_reproduce_the_clean_prefix() {
        let w = workload_for(AppId::WordCount);
        let cluster = ClusterConfig::pseudo_distributed();
        let cfg = JobConfig::new(4, 2, 10.0, 30.0);
        let mut seen: Vec<f64> = Vec::new();
        let r = simulate_controlled(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::none(),
            &mut Rng::new(12),
            &mut |tick| {
                seen.extend_from_slice(tick.new_samples);
                assert!((0.0..=1.0).contains(&tick.progress()));
                None
            },
        );
        // The last (partial) second is never ticked; everything else must
        // agree with the post-hoc clean series.
        assert!(seen.len() + 2 >= r.cpu_clean.len(), "{}", seen.len());
        for (i, (&a, &b)) in seen.iter().zip(r.cpu_clean.iter()).enumerate() {
            assert!((a - b).abs() < 1e-9, "sample {i}: {a} vs {b}");
        }
    }

    #[test]
    fn mid_run_reconfigure_changes_the_plan_and_conserves_shuffle() {
        let w = workload_for(AppId::TeraSort);
        let cluster = ClusterConfig::pseudo_distributed();
        // Many pending maps, one reducer: plenty of queued work to re-plan.
        let cfg = JobConfig::new(8, 1, 15.0, 120.0);
        let better = JobConfig::new(12, 4, 10.0, 120.0);
        let mut fired = false;
        let r = simulate_controlled(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::none(),
            &mut Rng::new(13),
            &mut |_tick| {
                // Fire on the very first tick: no map has finished yet, so
                // the queue is full and no reducer has launched (slow-start).
                if !fired {
                    fired = true;
                    return Some(better);
                }
                None
            },
        );
        assert!(fired);
        assert_eq!(r.counters.reconfigurations, 1);
        // The reduce side was replaced (no reduce progress that early)…
        assert_eq!(r.counters.reduce_tasks, 4);
        // …and the queued maps were re-split under the 10 MB target.
        assert_ne!(r.counters.map_tasks, 8, "maps={}", r.counters.map_tasks);
        // Shuffle conservation holds across the re-plan.
        let expected = 120.0 * w.default_costs().map_selectivity;
        assert!(
            (r.counters.shuffle_mb - expected).abs() < 0.1,
            "{} vs {expected}",
            r.counters.shuffle_mb
        );
        assert!(r.completion_secs > 0.0);
    }

    #[test]
    fn reconfigure_after_reduce_progress_keeps_reducers() {
        let w = workload_for(AppId::WordCount);
        let mut cluster = ClusterConfig::pseudo_distributed();
        cluster.reduce_slowstart = 0.0; // reducers launch immediately
        let cfg = JobConfig::new(4, 2, 10.0, 40.0);
        let mut fired = false;
        let r = simulate_controlled(
            w.as_ref(),
            &cfg,
            &cluster,
            &NoiseModel::none(),
            &mut Rng::new(14),
            &mut |tick| {
                // Fire late: once half the maps are done the running
                // reducers have long left their startup phase.
                if !fired && tick.maps_done * 2 >= tick.maps_total && tick.maps_done > 0 {
                    fired = true;
                    return Some(JobConfig::new(4, 8, 10.0, 40.0));
                }
                None
            },
        );
        assert!(fired);
        assert_eq!(r.counters.reconfigurations, 1);
        // Reduce replacement was vetoed — shuffle had already begun.
        assert_eq!(r.counters.reduce_tasks, 2);
        assert!(r.completion_secs > 0.0);
    }
}
