//! Perf bench (L1/L3): DTW similarity throughput across implementations —
//! pure-Rust full DTW, Sakoe–Chiba banded, FastDTW, and the PJRT-compiled
//! Pallas kernel (batched). Drives the §Perf iteration log.
//!
//! Run with: `cargo bench --bench dtw_perf`

#[path = "harness.rs"]
mod harness;

use harness::bench;
use mrtuner::coordinator::batcher::Batcher;
use mrtuner::dtw::{band_radius, banded::dtw_banded, fastdtw::fastdtw, full::dtw};
use mrtuner::runtime::RuntimeService;
use mrtuner::signal;
use mrtuner::util::rng::Rng;

fn series(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let f = 0.05 + rng.f64() * 0.1;
    signal::preprocess(
        &(0..len)
            .map(|i| (0.5 + 0.4 * ((i as f64) * f).sin() + rng.normal_ms(0.0, 0.05)).clamp(0.0, 1.0))
            .collect::<Vec<_>>(),
    )
}

fn main() {
    mrtuner::util::logging::init();
    println!("== DTW similarity throughput (per pair) ==");
    for len in [128usize, 256, 512] {
        let x = series(len, 1);
        let y = series(len.saturating_sub(30).max(16), 2);
        bench(&format!("rust full dtw        L={len}"), 3, 30, || dtw(&x, &y).distance);
        bench(&format!("rust banded dtw(10%) L={len}"), 3, 30, || {
            dtw_banded(&x, &y, band_radius(x.len(), y.len())).distance
        });
        bench(&format!("rust fastdtw(r=10)   L={len}"), 3, 30, || {
            fastdtw(&x, &y, 10).distance
        });
    }

    match RuntimeService::try_default() {
        None => println!("(PJRT artifacts missing — run `make artifacts` for kernel numbers)"),
        Some(svc) => {
            let rt = svc.handle();
            let b = rt.batch();
            println!("\n== PJRT pallas kernel (batch of {b}, per-pair cost shown) ==");
            for len in [128usize, 256, 512] {
                let raw = series(len, 3);
                let refs: Vec<Vec<f64>> =
                    (0..b as u64).map(|s| series(len - 10, 10 + s)).collect();
                let batcher = Batcher::new(rt.clone());
                let stats = bench(
                    &format!("pjrt match_one batch L={len}"),
                    2,
                    10,
                    || batcher.similarities(&raw, &refs).expect("pjrt"),
                );
                println!(
                    "    -> per-pair {:.3} ms (batch amortized)",
                    stats.mean_s * 1e3 / b as f64
                );
            }
        }
    }
}
