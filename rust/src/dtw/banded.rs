//! Sakoe–Chiba banded DTW.
//!
//! Restricts the warping path to a diagonal band of radius `r` (scaled for
//! unequal lengths), cutting work from O(N·M) to O(r·max(N,M)). Exact when
//! the optimal path stays inside the band — which holds for the CPU series
//! here, whose misalignment is bounded by a few map-wave lengths.
//!
//! Both kernels exist in a seed-signature form (buffers from the
//! thread-local arena) and a `*_with` form taking an explicit
//! [`DtwScratch`]; the latter is what the query engine threads through so
//! a candidate scan performs no per-call heap allocations.

use super::full::{backtrack, DtwResult};
use super::scratch::{with_thread_scratch, DtwScratch};
use super::{band_edges, band_slope, local_cost, CHOICE_DIAG, CHOICE_LEFT, CHOICE_UP};

/// Banded DTW with Sakoe–Chiba radius `r` (in samples, on the `y` axis after
/// slope correction). `r >= max(n,m)` degenerates to full DTW.
pub fn dtw_banded(x: &[f64], y: &[f64], r: usize) -> DtwResult {
    with_thread_scratch(|scratch| dtw_banded_with(scratch, x, y, r))
}

/// [`dtw_banded`] with caller-provided scratch buffers (bit-identical).
pub fn dtw_banded_with(scratch: &mut DtwScratch, x: &[f64], y: &[f64], r: usize) -> DtwResult {
    let (n, m) = (x.len(), y.len());
    assert!(n > 0 && m > 0, "dtw_banded: empty series");
    let slope = band_slope(n, m);
    let inf = f64::INFINITY;

    // Row j-ranges; forced to overlap between consecutive rows and to
    // include the corners so a connected path always exists.
    let mut bounds = scratch.range_buf();
    bounds.extend((0..n).map(|i| band_edges(i, slope, r, m)));

    let mut choices = scratch.choice_buf(n * m, CHOICE_DIAG);
    let mut prev = scratch.row(m, inf);
    let mut cur = scratch.row(m, inf);

    let (lo0, hi0) = bounds[0];
    debug_assert_eq!(lo0, 0);
    cur[0] = local_cost(x[0], y[0]);
    for j in lo0.max(1)..=hi0 {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
        choices[j] = CHOICE_LEFT;
    }
    std::mem::swap(&mut prev, &mut cur);

    for i in 1..n {
        let (lo, hi) = bounds[i];
        let row = i * m;
        cur.iter_mut().for_each(|v| *v = inf);
        for j in lo..=hi {
            let d = local_cost(x[i], y[j]);
            let diag = if j > 0 { prev[j - 1] } else { inf };
            let up = prev[j];
            let left = if j > lo { cur[j - 1] } else { inf };
            let (vg, vchoice) = if diag <= up { (diag, CHOICE_DIAG) } else { (up, CHOICE_UP) };
            if left < vg {
                cur[j] = left + d;
                choices[row + j] = CHOICE_LEFT;
            } else {
                cur[j] = vg + d;
                choices[row + j] = vchoice;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    let distance = prev[m - 1];
    assert!(
        distance.is_finite(),
        "band too narrow to connect corners (r={r}, n={n}, m={m})"
    );
    let path = backtrack(&choices, n, m);
    scratch.put_row(prev);
    scratch.put_row(cur);
    scratch.put_choice_buf(choices);
    scratch.put_range_buf(bounds);
    DtwResult {
        distance,
        normalized: distance / (n + m) as f64,
        path,
    }
}

/// Distance-only banded DTW with **early abandoning**: returns `None` as
/// soon as every cell of some row exceeds `cutoff` (any warping path must
/// cross every row inside the band, so no completion can come in below the
/// row minimum). When it completes, the result is the exact
/// [`dtw_banded`] distance — same band, same recurrence, same operation
/// order, hence bit-identical — which is what lets the similarity index
/// (`crate::index`) guarantee brute-force-identical k-NN results.
pub fn dtw_banded_distance_cutoff(x: &[f64], y: &[f64], r: usize, cutoff: f64) -> Option<f64> {
    with_thread_scratch(|scratch| dtw_banded_distance_cutoff_with(scratch, x, y, r, cutoff))
}

/// [`dtw_banded_distance_cutoff`] with caller-provided scratch buffers:
/// the query engine's steady-state **zero-allocation** kernel.
pub fn dtw_banded_distance_cutoff_with(
    scratch: &mut DtwScratch,
    x: &[f64],
    y: &[f64],
    r: usize,
    cutoff: f64,
) -> Option<f64> {
    let (n, m) = (x.len(), y.len());
    assert!(n > 0 && m > 0, "dtw_banded_distance_cutoff: empty series");
    let mut prev = scratch.row(m, f64::INFINITY);
    let mut cur = scratch.row(m, f64::INFINITY);
    let out = cutoff_dp(x, y, r, cutoff, &mut prev, &mut cur);
    scratch.put_row(prev);
    scratch.put_row(cur);
    out
}

/// The early-abandoning DP over caller-provided rows (both pre-filled with
/// `+inf`). Split out so every early `return None` still recycles the rows.
fn cutoff_dp(
    x: &[f64],
    y: &[f64],
    r: usize,
    cutoff: f64,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> Option<f64> {
    let (n, m) = (x.len(), y.len());
    let slope = band_slope(n, m);
    let inf = f64::INFINITY;

    let (lo0, hi0) = band_edges(0, slope, r, m);
    debug_assert_eq!(lo0, 0);
    cur[0] = local_cost(x[0], y[0]);
    let mut row_min = cur[0];
    for j in lo0.max(1)..=hi0 {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
        row_min = row_min.min(cur[j]);
    }
    if row_min > cutoff {
        return None;
    }
    std::mem::swap(prev, cur);

    for i in 1..n {
        let (lo, hi) = band_edges(i, slope, r, m);
        cur.iter_mut().for_each(|v| *v = inf);
        let mut row_min = inf;
        for j in lo..=hi {
            let d = local_cost(x[i], y[j]);
            let diag = if j > 0 { prev[j - 1] } else { inf };
            let up = prev[j];
            let left = if j > lo { cur[j - 1] } else { inf };
            // Same value selection as dtw_banded (vertical group then left).
            let vg = if diag <= up { diag } else { up };
            let best = if left < vg { left } else { vg };
            cur[j] = best + d;
            row_min = row_min.min(cur[j]);
        }
        if row_min > cutoff {
            return None;
        }
        std::mem::swap(prev, cur);
    }
    Some(prev[m - 1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::full::dtw;
    use crate::util::rng::Pcg32;

    fn rand_series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        (0..len).map(|_| g.f64()).collect()
    }

    #[test]
    fn wide_band_equals_full() {
        let mut g = Pcg32::new(10, 1);
        for _ in 0..15 {
            let lx = 2 + g.below(40) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 2 + g.below(40) as usize;
            let y = rand_series(&mut g, ly);
            let full = dtw(&x, &y).distance;
            let band = dtw_banded(&x, &y, x.len().max(y.len())).distance;
            assert!((full - band).abs() < 1e-12);
        }
    }

    #[test]
    fn band_is_lower_bounded_by_full() {
        // Constraining paths can only increase (or keep) the distance.
        let mut g = Pcg32::new(11, 2);
        for _ in 0..15 {
            let lx = 10 + g.below(50) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 10 + g.below(50) as usize;
            let y = rand_series(&mut g, ly);
            let full = dtw(&x, &y).distance;
            for r in [2usize, 5, 10] {
                let band = dtw_banded(&x, &y, r).distance;
                assert!(band >= full - 1e-12, "r={r}: band {band} < full {full}");
            }
        }
    }

    #[test]
    fn small_shift_recovered_with_small_band() {
        let x: Vec<f64> = (0..80).map(|i| ((i as f64) * 0.3).sin()).collect();
        let y: Vec<f64> = (0..80).map(|i| (((i + 3) as f64) * 0.3).sin()).collect();
        let full = dtw(&x, &y).distance;
        let band = dtw_banded(&x, &y, 6).distance;
        assert!((full - band).abs() < 1e-9, "full {full} band {band}");
    }

    #[test]
    fn unequal_lengths_band_follows_slope() {
        let x: Vec<f64> = (0..60).map(|i| (i as f64 * 0.2).sin()).collect();
        let y: Vec<f64> = (0..120).map(|i| (i as f64 * 0.1).sin()).collect();
        let r = dtw_banded(&x, &y, 8);
        assert!(r.distance.is_finite());
        assert_eq!(r.path.first(), Some(&(0, 0)));
        assert_eq!(r.path.last(), Some(&(59, 119)));
    }

    #[test]
    fn identical_series_zero_even_tight_band() {
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.17).cos()).collect();
        assert_eq!(dtw_banded(&x, &x, 1).distance, 0.0);
    }

    #[test]
    fn cutoff_infinite_is_bit_identical_to_banded() {
        let mut g = Pcg32::new(12, 3);
        for _ in 0..25 {
            let lx = 4 + g.below(80) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 4 + g.below(80) as usize;
            let y = rand_series(&mut g, ly);
            let r = crate::dtw::band_radius(x.len(), y.len());
            let exact = dtw_banded(&x, &y, r).distance;
            let ea = dtw_banded_distance_cutoff(&x, &y, r, f64::INFINITY)
                .expect("infinite cutoff never abandons");
            assert_eq!(exact.to_bits(), ea.to_bits(), "exact {exact} vs ea {ea}");
        }
    }

    #[test]
    fn cutoff_abandons_hopeless_pairs_and_keeps_close_ones() {
        let x: Vec<f64> = (0..120).map(|i| 0.5 + 0.4 * (i as f64 * 0.2).sin()).collect();
        let far: Vec<f64> = (0..120).map(|i| if i % 2 == 0 { 0.0 } else { 1.0 }).collect();
        let r = crate::dtw::band_radius(120, 120);
        let d_far = dtw_banded(&x, &far, r).distance;
        // Tight cutoff: the distant pair must abandon early.
        assert_eq!(dtw_banded_distance_cutoff(&x, &far, r, d_far / 10.0), None);
        // Loose cutoff: it completes with the exact distance.
        assert_eq!(
            dtw_banded_distance_cutoff(&x, &far, r, d_far * 2.0),
            Some(d_far)
        );
        // Self comparison never abandons for any nonnegative cutoff.
        assert_eq!(dtw_banded_distance_cutoff(&x, &x, r, 0.0), Some(0.0));
    }

    #[test]
    fn reused_scratch_matches_fresh_scratch() {
        let mut g = Pcg32::new(13, 4);
        let mut warm = DtwScratch::new();
        for _ in 0..20 {
            let lx = 2 + g.below(60) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 2 + g.below(60) as usize;
            let y = rand_series(&mut g, ly);
            let r = crate::dtw::band_radius(x.len(), y.len());
            let a = dtw_banded_with(&mut warm, &x, &y, r);
            let b = dtw_banded_with(&mut DtwScratch::new(), &x, &y, r);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
            assert_eq!(a.path, b.path);
            let ca = dtw_banded_distance_cutoff_with(&mut warm, &x, &y, r, a.distance * 0.8);
            let cb =
                dtw_banded_distance_cutoff_with(&mut DtwScratch::new(), &x, &y, r, a.distance * 0.8);
            assert_eq!(ca.map(f64::to_bits), cb.map(f64::to_bits));
        }
    }
}
