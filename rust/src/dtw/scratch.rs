//! Scratch arena for the DTW execution layer: grown-once, reused-forever
//! buffers behind every dynamic program in the crate.
//!
//! The seed kernels heap-allocated their DP rows (and, for the
//! path-producing variants, the traceback matrix and band bounds) on every
//! call. At query-engine rates — thousands of candidate comparisons per
//! k-NN search, one bound/DP refresh per live stream batch — those
//! allocations dominate the constant factor. A [`DtwScratch`] owns pools
//! of the buffer shapes the kernels need; a kernel *takes* buffers out,
//! runs, and *puts* them back, so the steady state performs **zero heap
//! allocations** for the distance-only kernels (pinned by
//! `benches/dtw_kernel_perf.rs`).
//!
//! Two ways to use it:
//!
//! * Explicit: hold a `DtwScratch` and call the `*_with` kernel variants
//!   ([`crate::dtw::banded::dtw_banded_with`],
//!   [`crate::dtw::banded::dtw_banded_distance_cutoff_with`],
//!   [`crate::dtw::full::dtw_with`], [`crate::dtw::fastdtw::fastdtw_with`],
//!   [`crate::streaming::anytime::prefix_dtw_with`]). This is what the
//!   k-NN engine and stream sessions do.
//! * Implicit: the seed-signature wrappers (`dtw_banded`, `fastdtw`, …)
//!   route through a thread-local arena via [`with_thread_scratch`], so
//!   legacy callers get the reuse for free.
//!
//! Buffer reuse never changes results: a taken buffer is cleared/refilled
//! to exactly the values a fresh allocation would hold, so every `*_with`
//! kernel is bit-identical to its seed counterpart (pinned by
//! `rust/tests/query_engine.rs`).

use std::cell::RefCell;

/// Pooled buffers for the DTW dynamic programs. Cheap to create (empty
/// pools); grows to the working-set high-water mark and stays there.
#[derive(Debug, Default, Clone)]
pub struct DtwScratch {
    /// f64 buffers: DP rows, FastDTW coarsened series.
    rows: Vec<Vec<f64>>,
    /// Traceback matrices (`n * m` choice bytes).
    choices: Vec<Vec<u8>>,
    /// `(lo, hi)` index ranges: band bounds, FastDTW windows.
    ranges: Vec<Vec<(usize, usize)>>,
    /// `(min, max)` value pairs: query block extrema, batched Keogh rows.
    extrema: Vec<Vec<(f64, f64)>>,
}

impl DtwScratch {
    pub fn new() -> DtwScratch {
        DtwScratch::default()
    }

    /// Take an f64 buffer of exactly `len` elements, each set to `fill` —
    /// value-identical to a fresh `vec![fill; len]`.
    pub(crate) fn row(&mut self, len: usize, fill: f64) -> Vec<f64> {
        let mut b = self.rows.pop().unwrap_or_default();
        b.clear();
        b.resize(len, fill);
        b
    }

    /// Take an empty f64 buffer (capacity retained from earlier use).
    pub(crate) fn raw_row(&mut self) -> Vec<f64> {
        let mut b = self.rows.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return an f64 buffer to the pool.
    pub(crate) fn put_row(&mut self, b: Vec<f64>) {
        self.rows.push(b);
    }

    /// Take a choice matrix of exactly `len` bytes, each set to `fill`.
    pub(crate) fn choice_buf(&mut self, len: usize, fill: u8) -> Vec<u8> {
        let mut b = self.choices.pop().unwrap_or_default();
        b.clear();
        b.resize(len, fill);
        b
    }

    /// Return a choice matrix to the pool.
    pub(crate) fn put_choice_buf(&mut self, b: Vec<u8>) {
        self.choices.push(b);
    }

    /// Take an empty `(lo, hi)` range buffer.
    pub(crate) fn range_buf(&mut self) -> Vec<(usize, usize)> {
        let mut b = self.ranges.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return a range buffer to the pool.
    pub(crate) fn put_range_buf(&mut self, b: Vec<(usize, usize)>) {
        self.ranges.push(b);
    }

    /// Take an empty `(min, max)` extrema buffer.
    pub(crate) fn extrema_buf(&mut self) -> Vec<(f64, f64)> {
        let mut b = self.extrema.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return an extrema buffer to the pool.
    pub(crate) fn put_extrema_buf(&mut self, b: Vec<(f64, f64)>) {
        self.extrema.push(b);
    }
}

thread_local! {
    static THREAD_SCRATCH: RefCell<DtwScratch> = RefCell::new(DtwScratch::new());
}

/// Run `f` against this thread's shared scratch arena — the reuse path
/// behind the seed-signature kernel wrappers. Re-entrant calls (a wrapper
/// invoked while the thread scratch is already borrowed) fall back to a
/// fresh arena instead of panicking; results are identical either way.
pub fn with_thread_scratch<T>(f: impl FnOnce(&mut DtwScratch) -> T) -> T {
    THREAD_SCRATCH.with(|s| match s.try_borrow_mut() {
        Ok(mut scratch) => f(&mut scratch),
        Err(_) => f(&mut DtwScratch::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_reused_and_refilled() {
        let mut s = DtwScratch::new();
        let mut a = s.row(8, f64::INFINITY);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|v| v.is_infinite()));
        a[3] = 1.5;
        let cap = a.capacity();
        s.put_row(a);
        // Same storage comes back, fully re-initialized.
        let b = s.row(4, 0.0);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(b.capacity(), cap);
        s.put_row(b);

        let c = s.choice_buf(10, 7);
        assert!(c.iter().all(|&v| v == 7));
        s.put_choice_buf(c);
        let mut r = s.range_buf();
        assert!(r.is_empty());
        r.push((1, 2));
        s.put_range_buf(r);
        assert!(s.range_buf().is_empty());
    }

    #[test]
    fn thread_scratch_is_reentrant_safe() {
        let out = with_thread_scratch(|outer| {
            let row = outer.row(4, 1.0);
            // Nested borrow must not panic: it gets a fresh arena.
            let inner = with_thread_scratch(|s| s.row(2, 2.0)[0]);
            let v = row[0] + inner;
            outer.put_row(row);
            v
        });
        assert_eq!(out, 3.0);
    }
}
