//! Structured per-request tracing: nested spans with typed events, behind
//! a pluggable [`Tracker`] sink.
//!
//! The serving stack (server dispatch, shard-router fan-out, the k-NN
//! cascade, streaming sessions) is instrumented with cheap [`Span`] guards
//! created through a [`TraceHandle`]. The handle bundles a tracker
//! implementation with a [`Clock`], so:
//!
//! * the **disabled** path (the default, [`NullTracker`]) never reads the
//!   clock and never allocates — `benches/trace_overhead.rs` pins it
//!   within noise of the untraced hot path;
//! * trackers themselves are clock-free: every `begin`/`end`/`event`
//!   takes the timestamp as a parameter, so tests drive the whole span
//!   tree from a deterministic [`VirtualClock`](clock::VirtualClock);
//! * pure compute layers stay clock-free (mrtuner-lint's `no-raw-clock`
//!   rule): they receive a parent `Span` and derive children from it.
//!
//! Backends: [`NullTracker`] (default), [`InMemoryTracker`] (queryable
//! span tree for tests/CI), [`TextTracker`] (indented log to any `Write`
//! sink), [`ChromeTracker`] (Chrome/Perfetto `trace_event` JSON — open
//! the file in `chrome://tracing` or <https://ui.perfetto.dev>).
//!
//! Trace identity crosses the wire through the optional `trace` field of
//! the v2 envelope: the router stamps each fan-out request with its
//! per-shard span id, and the shard's root span records it as
//! `remote_parent`, so both sides' trees merge into one timeline. See
//! `OBSERVABILITY.md` for the span taxonomy.

pub mod chrome;
pub mod clock;
pub mod memory;
pub mod multi;
pub mod recorder;
pub mod sampler;
pub mod text;

pub use chrome::ChromeTracker;
pub use clock::{Clock, MonotonicClock, VirtualClock};
pub use memory::{InMemoryTracker, SpanRecord};
pub use multi::MultiTracker;
pub use recorder::{FlightRecorder, FlightRotator};
pub use sampler::SamplingTracker;
pub use text::TextTracker;

use std::fmt;
use std::sync::Arc;

/// Identifier of one span within one tracker; `0` means "no span" (the
/// disabled tracker hands it out for every begin).
pub type SpanId = u64;

/// Wire sentinel for the v2 envelope `trace` field: "this request was
/// explicitly sampled *out* by the sender — record nothing, and do not
/// apply your own policy". Distinct from an absent/0 field, which means
/// "the sender had no opinion" and leaves the receiver free to sample
/// locally. The value is `2^53`: real span ids are small sequential
/// counters that never reach it, and `2^53` is the largest integer that
/// round-trips exactly through the f64-backed JSON layer
/// ([`crate::util::json::Json`] stores all numbers as `f64`).
pub const TRACE_SAMPLED_OUT: u64 = 1 << 53;

/// A span sink. Implementations are clock-free: timestamps arrive as
/// parameters (nanoseconds on the owning handle's [`Clock`]).
pub trait Tracker: Send + Sync {
    /// Whether spans should be recorded at all. `false` lets the handle
    /// skip clock reads and id allocation entirely.
    fn is_enabled(&self) -> bool;

    /// Open a span. `parent` is the enclosing local span (0 for roots);
    /// `remote_parent` is a span id received over the wire (0 if none).
    fn begin(&self, name: &'static str, parent: SpanId, remote_parent: SpanId, now_ns: u64)
        -> SpanId;

    /// Close a span previously returned by `begin`.
    fn end(&self, span: SpanId, now_ns: u64);

    /// Attach a typed counter observation to an open span.
    fn event(&self, span: SpanId, name: &'static str, value: u64, now_ns: u64);

    /// Attach a free-text annotation to an open span.
    fn note(&self, span: SpanId, key: &'static str, text: &str, now_ns: u64);

    /// Head-based sampling decision for a *root* span identified by `key`
    /// (request id, session id — whatever the caller derives identity
    /// from). Plain sinks record everything; [`SamplingTracker`]
    /// overrides this with a deterministic seeded 1-in-N policy. Only
    /// consulted by [`TraceHandle::root_sampled`] and only when no remote
    /// peer has already decided (see [`TRACE_SAMPLED_OUT`]).
    fn sample_root(&self, _key: u64) -> bool {
        true
    }
}

/// The zero-overhead default sink: reports itself disabled, so the
/// [`TraceHandle`] short-circuits before reading the clock.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullTracker;

impl Tracker for NullTracker {
    fn is_enabled(&self) -> bool {
        false
    }
    fn begin(&self, _: &'static str, _: SpanId, _: SpanId, _: u64) -> SpanId {
        0
    }
    fn end(&self, _: SpanId, _: u64) {}
    fn event(&self, _: SpanId, _: &'static str, _: u64, _: u64) {}
    fn note(&self, _: SpanId, _: &'static str, _: &str, _: u64) {}
}

/// Cloneable handle pairing a [`Tracker`] with the [`Clock`] that stamps
/// its spans. This is what `ServerState`, `ShardRouter`, `Profiler` and
/// the benches carry.
#[derive(Clone)]
pub struct TraceHandle {
    tracker: Arc<dyn Tracker>,
    clock: Arc<dyn Clock>,
    enabled: bool,
}

impl fmt::Debug for TraceHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TraceHandle").field("enabled", &self.enabled).finish()
    }
}

impl Default for TraceHandle {
    fn default() -> Self {
        TraceHandle::disabled()
    }
}

impl TraceHandle {
    /// The default handle: a [`NullTracker`] — span creation is a branch
    /// and nothing else.
    pub fn disabled() -> TraceHandle {
        TraceHandle::new(Arc::new(NullTracker))
    }

    /// A handle over `tracker` with the production [`MonotonicClock`].
    pub fn new(tracker: Arc<dyn Tracker>) -> TraceHandle {
        TraceHandle::with_clock(tracker, Arc::new(MonotonicClock::new()))
    }

    /// A handle with an explicit clock (tests use a
    /// [`VirtualClock`](clock::VirtualClock) for deterministic
    /// durations).
    pub fn with_clock(tracker: Arc<dyn Tracker>, clock: Arc<dyn Clock>) -> TraceHandle {
        let enabled = tracker.is_enabled();
        TraceHandle { tracker, clock, enabled }
    }

    /// Whether spans are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Read the handle's clock (always live, even when tracing is
    /// disabled) — the serving layers use this for metrics timing so raw
    /// `Instant::now()` stays out of them.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Seconds elapsed since a previous [`TraceHandle::now_ns`] reading.
    pub fn elapsed_secs(&self, start_ns: u64) -> f64 {
        self.now_ns().saturating_sub(start_ns) as f64 * 1e-9
    }

    /// A clock reading for span bookkeeping: 0 when tracing is disabled,
    /// so the hot path pays nothing.
    pub fn timestamp(&self) -> u64 {
        if self.enabled {
            self.clock.now_ns()
        } else {
            0
        }
    }

    /// Open a root span (no local parent).
    pub fn root(&self, name: &'static str) -> Span {
        self.span(name, 0, 0)
    }

    /// Open a root span whose parent lives on a remote peer (the `trace`
    /// id carried by the v2 envelope).
    pub fn root_linked(&self, name: &'static str, remote_parent: SpanId) -> Span {
        self.span(name, 0, remote_parent)
    }

    /// Open a root span subject to the sampling protocol. `remote_parent`
    /// is the envelope `trace` value (0 when absent) and `key` the local
    /// sampling identity (v2 request id, session id):
    ///
    /// * `remote_parent == `[`TRACE_SAMPLED_OUT`] — the sender explicitly
    ///   sampled this request out; honor it, record nothing.
    /// * `remote_parent != 0` — the sender sampled it *in*; record
    ///   unconditionally so the stitched tree is never half-missing.
    /// * `remote_parent == 0` — no upstream opinion; ask the tracker's
    ///   [`Tracker::sample_root`] policy with `key`.
    pub fn root_sampled(&self, name: &'static str, remote_parent: SpanId, key: u64) -> Span {
        if !self.enabled || remote_parent == TRACE_SAMPLED_OUT {
            return Span::none();
        }
        if remote_parent == 0 && !self.tracker.sample_root(key) {
            return Span::none();
        }
        self.span(name, 0, remote_parent)
    }

    /// The envelope `trace` value that propagates `span`'s sampling fate
    /// downstream: the span's id when it records, [`TRACE_SAMPLED_OUT`]
    /// when this handle is tracing but the span was sampled out (so the
    /// receiver must not record either), and 0 when tracing is off
    /// entirely (the receiver decides for itself).
    pub fn wire_trace(&self, span: &Span) -> u64 {
        if span.active() {
            span.id()
        } else if self.enabled {
            TRACE_SAMPLED_OUT
        } else {
            0
        }
    }

    /// Record an already-finished interval as a span (used to backdate
    /// work — e.g. request decode — that ran before its ids were known).
    pub fn span_at(&self, name: &'static str, parent: SpanId, start_ns: u64, end_ns: u64) {
        if self.enabled {
            let id = self.tracker.begin(name, parent, 0, start_ns);
            self.tracker.end(id, end_ns);
        }
    }

    fn span(&self, name: &'static str, parent: SpanId, remote_parent: SpanId) -> Span {
        if !self.enabled {
            return Span::none();
        }
        let now = self.clock.now_ns();
        let id = self.tracker.begin(name, parent, remote_parent, now);
        Span { id, handle: Some(self.clone()) }
    }
}

/// RAII guard for one span: closed (with an end timestamp) on drop.
/// Disabled spans carry no handle, so deriving children from them and
/// attaching events are branches over a `None`.
#[derive(Debug, Default)]
pub struct Span {
    id: SpanId,
    handle: Option<TraceHandle>,
}

impl Span {
    /// The inert span: everything derived from it is inert too.
    pub fn none() -> Span {
        Span { id: 0, handle: None }
    }

    /// This span's id — what the router sends as the envelope `trace`
    /// field so the shard's spans nest under it. 0 when disabled.
    pub fn id(&self) -> SpanId {
        self.id
    }

    /// Whether this span records anything.
    pub fn active(&self) -> bool {
        self.handle.is_some()
    }

    /// Open a child span.
    pub fn child(&self, name: &'static str) -> Span {
        match &self.handle {
            Some(h) => h.span(name, self.id, 0),
            None => Span::none(),
        }
    }

    /// Attach a typed counter observation.
    pub fn event(&self, name: &'static str, value: u64) {
        if let Some(h) = &self.handle {
            h.tracker.event(self.id, name, value, h.clock.now_ns());
        }
    }

    /// Attach a free-text annotation. The string is only materialized by
    /// enabled sinks; callers guard expensive formatting with
    /// [`Span::active`].
    pub fn note(&self, key: &'static str, text: &str) {
        if let Some(h) = &self.handle {
            h.tracker.note(self.id, key, text, h.clock.now_ns());
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(h) = &self.handle {
            h.tracker.end(self.id, h.clock.now_ns());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert_and_clock_free_for_spans() {
        let h = TraceHandle::disabled();
        assert!(!h.enabled());
        assert_eq!(h.timestamp(), 0);
        let root = h.root("request");
        assert!(!root.active());
        assert_eq!(root.id(), 0);
        let child = root.child("handle");
        assert!(!child.active());
        child.event("count", 3);
        child.note("key", "value");
    }

    #[test]
    fn disabled_handle_still_tells_time_for_metrics() {
        let h = TraceHandle::disabled();
        let t0 = h.now_ns();
        let dt = h.elapsed_secs(t0);
        assert!(dt >= 0.0);
    }

    #[test]
    fn spans_nest_and_close_in_drop_order() {
        let sink = Arc::new(InMemoryTracker::new());
        let h = TraceHandle::with_clock(sink.clone(), Arc::new(VirtualClock::new(5)));
        assert!(h.enabled());
        {
            let root = h.root_linked("request", 77);
            let handle = root.child("handle");
            handle.event("queries", 4);
            handle.note("config", "M=2,R=1");
            drop(handle);
            h.span_at("decode", root.id(), 1, 2);
        }
        let spans = sink.spans();
        assert_eq!(spans.len(), 3);
        let root = &spans[0];
        assert_eq!(root.name, "request");
        assert_eq!(root.remote_parent, 77);
        assert_eq!(root.parent, 0);
        let handle = &spans[1];
        assert_eq!(handle.name, "handle");
        assert_eq!(handle.parent, root.id);
        assert!(handle.end_ns > handle.start_ns, "virtual clock ticks");
        assert_eq!(handle.events, vec![("queries", 4)]);
        assert_eq!(handle.notes.len(), 1);
        let decode = &spans[2];
        assert_eq!((decode.start_ns, decode.end_ns), (1, 2));
        assert!(root.end_ns >= handle.end_ns, "root closes last");
    }

    #[test]
    fn root_sampled_follows_the_wire_protocol() {
        let sink = Arc::new(InMemoryTracker::new());
        let h = TraceHandle::with_clock(sink.clone(), Arc::new(VirtualClock::new(5)));

        // Sender sampled out: inert regardless of local policy.
        let out = h.root_sampled("request", TRACE_SAMPLED_OUT, 7);
        assert!(!out.active());
        assert_eq!(h.wire_trace(&out), TRACE_SAMPLED_OUT, "fate propagates downstream");
        drop(out);

        // Sender sampled in: recorded with the remote parent attached.
        let linked = h.root_sampled("request", 99, 7);
        assert!(linked.active());
        assert_eq!(h.wire_trace(&linked), linked.id());
        drop(linked);

        // No upstream opinion: the plain sink's default policy records all.
        let local = h.root_sampled("request", 0, 7);
        assert!(local.active());
        drop(local);

        let spans = sink.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].remote_parent, 99);
        assert_eq!(spans[1].remote_parent, 0);
    }

    #[test]
    fn wire_trace_is_zero_when_tracing_is_off() {
        let h = TraceHandle::disabled();
        let span = h.root_sampled("request", 0, 1);
        assert!(!span.active());
        assert_eq!(h.wire_trace(&span), 0, "untraced processes stay silent");
    }
}
