//! Streaming classification quickstart — now over the wire: profile a
//! reference database, serve it, then classify a *live* CPU stream
//! through [`MrtunerClient`] while the job is still running.
//!
//! The server side is the real `MatchServer` (the same thing
//! `mrtuner serve` runs), started in-process on an ephemeral port. The
//! client side talks protocol v2 only: `stream_open` registers the live
//! session, `stream_feed` ships SysStat-sized sample batches and reports
//! the anytime state (including the early decision the moment the
//! session's margin policy declares one), and `stream_close` answers with
//! the exact indexed search over the full capture for comparison.
//! Sessions are addressed by id, not by connection — a feeder may
//! reconnect mid-job and keep feeding the same session.
//!
//! Run with: `cargo run --release --example stream_classify`

use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::prelude::*;
use mrtuner::simulator::engine::simulate;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::workload_for;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::small(1);
    let sc = SystemConfig {
        use_runtime: false,
        ..SystemConfig::default()
    };

    // Reference database: WordCount and TeraSort profiled over the grid.
    let p = Profiler::new(&sc, None);
    let mut idx = IndexedDb::new();
    for app in [AppId::WordCount, AppId::TeraSort] {
        for entry in p.profile(app, &grid) {
            idx.insert(entry);
        }
    }
    println!("reference DB: {} entries over {} config sets", idx.len(), grid.len());

    // Serve it — the same server `mrtuner serve` runs, ephemeral port.
    let state = ServerState {
        db: idx,
        runtime: None,
        metrics: Metrics::new(),
        sessions: mrtuner::streaming::SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    };
    let server = MatchServer::bind("127.0.0.1:0", state).expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_flag();
    let server_thread =
        std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));
    println!("match service listening on {addr}");

    // A "new" job starts: WordCount under the first config set, fresh
    // noise seed. We only get to see its CPU samples as they happen.
    let cfg = grid.configs[0];
    let run = simulate(
        workload_for(AppId::WordCount).as_ref(),
        &cfg,
        &sc.cluster,
        &sc.noise,
        &mut Rng::new(2024),
    );
    let mut source = run.live_stream();
    let total = source.final_len();
    println!(
        "live job started under {} ({total} samples total, but nobody knows the pattern yet)",
        cfg.label(),
    );

    // The feeder is a protocol-v2 client; the session lives server-side.
    let mut client = MrtunerClient::connect(&addr.to_string()).expect("connect");
    let opened = client
        .stream_open(Some(&cfg), Some(total))
        .expect("stream_open");
    println!(
        "session {} open against {} candidate references",
        opened.session, opened.candidates
    );

    // Feed 10-second SysStat batches until the session declares.
    let mut early = None;
    while let Some(batch) = source.next_batch(10) {
        let fed = client.stream_feed(opened.session, batch).expect("stream_feed");
        if let Some(d) = fed.decision {
            println!(
                "EARLY DECISION after {} of {total} samples ({:.0}% observed): {} (similarity {:.1}%)",
                d.at_sample,
                d.fraction * 100.0,
                d.app,
                d.similarity,
            );
            early = Some(d);
            break;
        }
    }

    // Drain the rest of the run, then close: the exact offline answer.
    while let Some(batch) = source.next_batch(10) {
        client.stream_feed(opened.session, batch).expect("stream_feed");
    }
    let closed = client.stream_close(opened.session).expect("stream_close");
    let final_match = closed.final_match.expect("final answer over the capture");
    println!(
        "offline full-series answer: {} (distance {:.4}, similarity {:.1}%)",
        final_match.app, final_match.distance, final_match.similarity
    );
    match &early {
        Some(d) if d.app == final_match.app => {
            println!("early decision AGREES with the full series")
        }
        Some(d) => println!("early decision ({}) disagrees with the full series", d.app),
        None => println!("policy never fired; the exact finalize answered instead"),
    }

    stop.store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr); // unblock accept
    server_thread.join().expect("server thread").expect("serve");
}
