//! MapReduce workloads.
//!
//! The paper's three benchmark applications (§5) — WordCount, TeraSort and
//! Exim mainlog parsing — plus two extra reference applications (Grep,
//! InvertedIndex) that widen the reference database in the extended
//! experiments. Each workload is *really implemented*: it generates
//! realistic synthetic input and its map/reduce functions actually execute
//! over that input (see [`mapreduce`], the in-process execution engine used
//! for correctness tests and cost calibration). The discrete-event
//! simulator then scales the calibrated costs to full job sizes.

pub mod exim;
pub mod grep;
pub mod inverted_index;
pub mod mapreduce;
pub mod terasort;
pub mod traits;
pub mod wordcount;

pub use traits::{CostModel, Workload};

/// Identifier for every application known to the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AppId {
    WordCount,
    TeraSort,
    EximParse,
    Grep,
    InvertedIndex,
}

impl AppId {
    /// Stable lowercase name (database keys, CLI values).
    pub fn name(&self) -> &'static str {
        match self {
            AppId::WordCount => "wordcount",
            AppId::TeraSort => "terasort",
            AppId::EximParse => "exim",
            AppId::Grep => "grep",
            AppId::InvertedIndex => "invertedindex",
        }
    }

    /// Parse from the stable name.
    pub fn from_name(s: &str) -> Option<AppId> {
        match s {
            "wordcount" => Some(AppId::WordCount),
            "terasort" => Some(AppId::TeraSort),
            "exim" => Some(AppId::EximParse),
            "grep" => Some(AppId::Grep),
            "invertedindex" => Some(AppId::InvertedIndex),
            _ => None,
        }
    }

    /// All known applications.
    pub fn all() -> &'static [AppId] {
        &[
            AppId::WordCount,
            AppId::TeraSort,
            AppId::EximParse,
            AppId::Grep,
            AppId::InvertedIndex,
        ]
    }
}

/// Instantiate the workload implementation for an application.
pub fn workload_for(app: AppId) -> Box<dyn Workload> {
    match app {
        AppId::WordCount => Box::new(wordcount::WordCount::default()),
        AppId::TeraSort => Box::new(terasort::TeraSort::default()),
        AppId::EximParse => Box::new(exim::EximParse::default()),
        AppId::Grep => Box::new(grep::Grep::default()),
        AppId::InvertedIndex => Box::new(inverted_index::InvertedIndex::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for &app in AppId::all() {
            assert_eq!(AppId::from_name(app.name()), Some(app));
        }
        assert_eq!(AppId::from_name("nosuch"), None);
    }

    #[test]
    fn workloads_instantiate() {
        for &app in AppId::all() {
            assert_eq!(workload_for(app).id(), app);
        }
    }
}
