//! FastDTW (Salvador & Chan 2007) — the paper's reference [20], cited as the
//! answer to DTW's quadratic cost in the cluster-scale future-work section.
//!
//! Multiresolution scheme: coarsen both series by 2, solve recursively,
//! project the coarse path onto the finer grid, and re-solve inside a
//! window of the projection expanded by `radius`.

use super::full::{dtw, DtwResult};
use super::{local_cost, CHOICE_DIAG, CHOICE_LEFT, CHOICE_UP};

/// FastDTW with the given radius. Larger radius → closer to exact, slower.
pub fn fastdtw(x: &[f64], y: &[f64], radius: usize) -> DtwResult {
    let min_size = radius + 2;
    if x.len() <= min_size || y.len() <= min_size {
        return dtw(x, y);
    }
    let xs = coarsen(x);
    let ys = coarsen(y);
    let coarse = fastdtw(&xs, &ys, radius);
    let window = expand_window(&coarse.path, x.len(), y.len(), radius);
    windowed_dtw(x, y, &window)
}

/// Halve resolution by averaging adjacent pairs (odd tail carried over).
fn coarsen(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len().div_ceil(2));
    let mut i = 0;
    while i + 1 < xs.len() {
        out.push(0.5 * (xs[i] + xs[i + 1]));
        i += 2;
    }
    if i < xs.len() {
        out.push(xs[i]);
    }
    out
}

/// Project a coarse path to the finer grid and expand by `radius`;
/// returns per-row inclusive `(lo, hi)` j-ranges, made monotone/connected.
fn expand_window(
    coarse_path: &[(usize, usize)],
    n: usize,
    m: usize,
    radius: usize,
) -> Vec<(usize, usize)> {
    let mut lo = vec![usize::MAX; n];
    let mut hi = vec![0usize; n];
    let mut mark = |i: usize, j: usize| {
        if i < n {
            let jlo = j.saturating_sub(radius);
            let jhi = (j + radius).min(m - 1);
            lo[i] = lo[i].min(jlo);
            hi[i] = hi[i].max(jhi);
        }
    };
    for &(ci, cj) in coarse_path {
        // Each coarse cell covers a 2×2 block of fine cells.
        for di in 0..2 {
            for dj in 0..2 {
                let i = 2 * ci + di;
                let j = (2 * cj + dj).min(m - 1);
                // Expand by radius in i as well by marking neighbours.
                let ilo = i.saturating_sub(radius);
                let ihi = (i + radius).min(n - 1);
                for ii in ilo..=ihi {
                    mark(ii, j);
                }
            }
        }
    }
    // Fill any unreached rows (possible with degenerate coarse paths) and
    // enforce per-row connectivity with the previous row.
    let mut prev_hi = 0usize;
    for i in 0..n {
        if lo[i] == usize::MAX {
            lo[i] = prev_hi;
            hi[i] = prev_hi;
        }
        // A legal step needs overlap or adjacency with the previous row.
        if lo[i] > prev_hi {
            lo[i] = prev_hi;
        }
        if hi[i] < lo[i] {
            hi[i] = lo[i];
        }
        prev_hi = hi[i];
    }
    lo[0] = 0;
    hi[n - 1] = m - 1;
    lo.into_iter().zip(hi).collect()
}

/// DTW restricted to per-row `(lo, hi)` windows.
fn windowed_dtw(x: &[f64], y: &[f64], window: &[(usize, usize)]) -> DtwResult {
    let (n, m) = (x.len(), y.len());
    let inf = f64::INFINITY;
    let mut choices = vec![CHOICE_DIAG; n * m];
    let mut prev = vec![inf; m];
    let mut cur = vec![inf; m];

    let (lo0, hi0) = window[0];
    cur[lo0] = local_cost(x[0], y[lo0]);
    for j in (lo0 + 1)..=hi0 {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
        choices[j] = CHOICE_LEFT;
    }
    std::mem::swap(&mut prev, &mut cur);

    for i in 1..n {
        let (lo, hi) = window[i];
        let row = i * m;
        cur.iter_mut().for_each(|v| *v = inf);
        for j in lo..=hi {
            let d = local_cost(x[i], y[j]);
            let diag = if j > 0 { prev[j - 1] } else { inf };
            let up = prev[j];
            let left = if j > lo { cur[j - 1] } else { inf };
            let (vg, vchoice) = if diag <= up { (diag, CHOICE_DIAG) } else { (up, CHOICE_UP) };
            if left < vg {
                cur[j] = left + d;
                choices[row + j] = CHOICE_LEFT;
            } else {
                cur[j] = vg + d;
                choices[row + j] = vchoice;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    let distance = prev[m - 1];
    assert!(distance.is_finite(), "window disconnected");
    let path = super::full::backtrack(&choices, n, m);
    DtwResult {
        distance,
        normalized: distance / (n + m) as f64,
        path,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::full::dtw_distance;
    use crate::util::rng::Pcg32;

    fn rand_walk(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.1).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn small_inputs_are_exact() {
        let mut g = Pcg32::new(20, 1);
        for _ in 0..10 {
            let lx = 2 + g.below(10) as usize;
            let x = rand_walk(&mut g, lx);
            let ly = 2 + g.below(10) as usize;
            let y = rand_walk(&mut g, ly);
            let exact = dtw_distance(&x, &y);
            let fast = fastdtw(&x, &y, 8).distance;
            assert!((exact - fast).abs() < 1e-12);
        }
    }

    #[test]
    fn approximation_error_small_on_smooth_series() {
        let mut g = Pcg32::new(21, 2);
        let mut errs = Vec::new();
        for _ in 0..10 {
            let lx = 200 + g.below(100) as usize;
            let x = rand_walk(&mut g, lx);
            let ly = 200 + g.below(100) as usize;
            let y = rand_walk(&mut g, ly);
            let exact = dtw_distance(&x, &y);
            let fast = fastdtw(&x, &y, 10).distance;
            assert!(fast >= exact - 1e-9, "fastdtw below exact");
            let rel = if exact > 1e-9 { (fast - exact) / exact } else { 0.0 };
            errs.push(rel);
        }
        let mean_err = crate::util::stats::mean(&errs);
        assert!(mean_err < 0.05, "mean relative error {mean_err}");
    }

    #[test]
    fn identical_series_zero() {
        let x: Vec<f64> = (0..500).map(|i| ((i as f64) * 0.05).sin()).collect();
        let r = fastdtw(&x, &x, 3);
        assert!(r.distance.abs() < 1e-12);
    }

    #[test]
    fn path_endpoints_valid() {
        let mut g = Pcg32::new(22, 3);
        let x = rand_walk(&mut g, 333);
        let y = rand_walk(&mut g, 257);
        let r = fastdtw(&x, &y, 5);
        assert_eq!(r.path.first(), Some(&(0, 0)));
        assert_eq!(r.path.last(), Some(&(332, 256)));
        for w in r.path.windows(2) {
            let (i0, j0) = w[0];
            let (i1, j1) = w[1];
            assert!(i1 - i0 <= 1 && j1 - j0 <= 1 && (i1 - i0) + (j1 - j0) >= 1);
        }
    }

    #[test]
    fn larger_radius_is_no_worse() {
        let mut g = Pcg32::new(23, 4);
        let x = rand_walk(&mut g, 400);
        let y = rand_walk(&mut g, 380);
        let d1 = fastdtw(&x, &y, 1).distance;
        let d20 = fastdtw(&x, &y, 20).distance;
        assert!(d20 <= d1 + 1e-9, "r=20 {d20} > r=1 {d1}");
    }

    #[test]
    fn coarsen_halves_and_averages() {
        assert_eq!(coarsen(&[1.0, 3.0, 5.0, 7.0]), vec![2.0, 6.0]);
        assert_eq!(coarsen(&[1.0, 3.0, 9.0]), vec![2.0, 9.0]);
    }
}
