//! Perf bench (DTW execution layer): scratch-arena kernels and the
//! parallel/batched k-NN engine vs the seed-grade path.
//!
//! Part 1 — kernel microbenchmarks: ns/call *and heap allocations per
//! call* (counted by a wrapping global allocator) for the banded
//! path-producing DP, the early-abandoning distance-only DP and the
//! streaming prefix DP, each through (a) a warm reused [`DtwScratch`],
//! (b) the seed-signature wrapper (thread-local arena) and (c) a fresh
//! arena per call — the seed's allocation behaviour. The acceptance bar:
//! **zero** allocations per call for the warm distance-only kernel.
//!
//! Part 2 — k-NN scaling at DB sizes {50, 500, 5000}: the seed-grade
//! search loop (serial, fresh rows per DTW call) vs today's serial engine
//! vs the cutoff-sharing parallel engine. The acceptance bar at DB=5000:
//! parallel + scratch >= 2x over the seed-grade path, with results proven
//! identical.
//!
//! Part 3 — batched multi-query search at batch sizes {1, 8, 64}:
//! `IndexedDb::knn_batch` (one envelope pass per entry per length group)
//! vs one `knn` call per query.
//!
//! Results go to stdout and `BENCH_dtw.json`. `MRTUNER_BENCH_SMOKE=1`
//! shrinks the sweep for CI.
//!
//! Run with: `cargo bench --bench dtw_kernel_perf`

#[path = "harness.rs"]
mod harness;

use harness::bench;
use mrtuner::coordinator::batcher::prepare_query;
use mrtuner::database::profile::ProfileEntry;
use mrtuner::database::store::ReferenceDb;
use mrtuner::dtw::banded::{dtw_banded_distance_cutoff, dtw_banded_distance_cutoff_with, dtw_banded_with};
use mrtuner::dtw::{band_radius, DtwScratch};
use mrtuner::index::{lb, IndexedDb, Neighbor, DEFAULT_BLOCK};
use mrtuner::simulator::job::JobConfig;
use mrtuner::streaming::anytime::prefix_dtw_with;
use mrtuner::util::json::Json;
use mrtuner::util::pool::default_workers;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::AppId;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counting wrapper around the system allocator: lets the bench report
/// heap allocations per kernel call, not just wall-clock.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to the system allocator; the counter is the
// only addition and touches no allocator state.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds the GlobalAlloc contract for `layout`.
        unsafe { System.alloc(layout) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds the GlobalAlloc contract for `layout`.
        unsafe { System.alloc_zeroed(layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: `ptr`/`layout` come from this allocator per the contract.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` come from this allocator per the contract.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Time `f` over `iters` calls after a short warmup, also reporting the
/// mean number of heap allocations per call.
fn measure<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, f64) {
    for _ in 0..iters.min(5) {
        std::hint::black_box(f());
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let dt = t0.elapsed().as_secs_f64();
    let da = ALLOCS.load(Ordering::Relaxed) - a0;
    (dt / iters as f64 * 1e9, da as f64 / iters as f64)
}

/// Synthetic CPU-like pattern, preprocessed exactly like stored profiles.
fn wave(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let f = 0.04 + rng.f64() * 0.12;
    let phase = rng.f64() * 6.28;
    prepare_query(
        &(0..len)
            .map(|i| {
                (0.55 + 0.35 * ((i as f64) * f + phase).sin() + rng.normal_ms(0.0, 0.04))
                    .clamp(0.0, 1.0)
            })
            .collect::<Vec<_>>(),
    )
}

fn synthetic_db(n: usize) -> IndexedDb {
    let mut db = ReferenceDb::new();
    for i in 0..n {
        // Unique (M, R, FS) triple for every i < 42*40*50.
        let cfg = JobConfig::new(
            i % 42 + 1,
            (i / 42) % 40 + 1,
            (i / (42 * 40) + 1) as f64,
            100.0,
        );
        let len = 64 + (i * 37) % 256;
        db.insert(ProfileEntry {
            app: AppId::all()[i % AppId::all().len()],
            config: cfg,
            series: wave(len, i as u64),
            raw_len: len,
            completion_secs: 100.0,
        });
    }
    IndexedDb::from_db(db)
}

/// The seed-grade search loop: identical cascade and tie-breaks, but
/// serial and with fresh DP rows allocated for every DTW call — what
/// `index::knn` cost before the scratch/parallel engine.
fn knn_seed_grade(query: &[f64], idx: &IndexedDb, k: usize) -> Vec<Neighbor> {
    let n = query.len();
    let qext = lb::query_extrema(query, DEFAULT_BLOCK);
    let mut best: Vec<Neighbor> = Vec::new();
    for i in 0..idx.len() {
        let series = idx.entries()[i].series.as_slice();
        if series.is_empty() {
            continue;
        }
        let env = idx.envelope(i);
        let bsf = if best.len() == k {
            best[k - 1].distance
        } else {
            f64::INFINITY
        };
        let cut = if bsf.is_finite() {
            bsf + 1e-9 * (1.0 + bsf.abs())
        } else {
            bsf
        };
        if lb::lb_kim(query, series) > cut {
            continue;
        }
        let r = band_radius(n, series.len());
        if n >= 64 && lb::lb_paa(&qext, n, DEFAULT_BLOCK, env, r) > cut {
            continue;
        }
        if lb::lb_keogh(query, env, r) > cut {
            continue;
        }
        // Fresh arena per call == seed allocation behaviour.
        if let Some(distance) =
            dtw_banded_distance_cutoff_with(&mut DtwScratch::new(), query, series, r, cut)
        {
            let pos = best.partition_point(|b| (b.distance, b.index) <= (distance, i));
            if pos < k {
                best.insert(pos, Neighbor { index: i, distance });
                best.truncate(k);
            }
        }
    }
    best
}

fn kernel_micro(smoke: bool) -> Vec<Json> {
    println!("== kernel microbenchmarks (256 x 256, ns/call and allocs/call) ==");
    let x = wave(256, 1);
    let y = wave(256, 2);
    let r = band_radius(x.len(), y.len());
    let iters = if smoke { 200 } else { 2000 };
    let mut rows = Vec::new();
    let mut emit = |name: &str, ns: f64, allocs: f64| {
        println!("    {name:44} {ns:>12.0} ns/call  {allocs:>6.2} allocs/call");
        rows.push(Json::obj(vec![
            ("kernel", Json::Str(name.to_string())),
            ("ns_per_call", Json::Num(ns)),
            ("allocs_per_call", Json::Num(allocs)),
        ]));
    };

    let mut warm = DtwScratch::new();
    // Grow the arena once before measuring the steady state.
    std::hint::black_box(dtw_banded_distance_cutoff_with(&mut warm, &x, &y, r, f64::INFINITY));

    let (ns, al) = measure(iters, || {
        dtw_banded_distance_cutoff_with(&mut warm, &x, &y, r, f64::INFINITY)
    });
    let zero_alloc_cutoff = al == 0.0;
    emit("banded cutoff DP, warm scratch", ns, al);
    let (ns, al) = measure(iters, || dtw_banded_distance_cutoff(&x, &y, r, f64::INFINITY));
    emit("banded cutoff DP, thread-local wrapper", ns, al);
    let (ns, al) = measure(iters, || {
        dtw_banded_distance_cutoff_with(&mut DtwScratch::new(), &x, &y, r, f64::INFINITY)
    });
    emit("banded cutoff DP, fresh arena (seed)", ns, al);

    let (ns, al) = measure(iters, || prefix_dtw_with(&mut warm, &x[..128], &y, 256, f64::INFINITY));
    emit("prefix DP (128/256), warm scratch", ns, al);
    let (ns, al) = measure(iters, || {
        prefix_dtw_with(&mut DtwScratch::new(), &x[..128], &y, 256, f64::INFINITY)
    });
    emit("prefix DP (128/256), fresh arena (seed)", ns, al);

    // The path-producing kernel's result allocates by contract (the path
    // itself); the interesting delta is DP-buffer reuse.
    let (ns, al) = measure(iters / 2, || dtw_banded_with(&mut warm, &x, &y, r));
    emit("banded full DP + path, warm scratch", ns, al);
    let (ns, al) = measure(iters / 2, || dtw_banded_with(&mut DtwScratch::new(), &x, &y, r));
    emit("banded full DP + path, fresh arena (seed)", ns, al);

    println!(
        "    steady-state banded cutoff kernel zero-alloc: {}",
        if zero_alloc_cutoff { "PASS" } else { "FAIL" }
    );
    rows.push(Json::obj(vec![
        ("kernel", Json::Str("zero_alloc_acceptance".into())),
        ("pass", Json::Bool(zero_alloc_cutoff)),
    ]));
    rows
}

fn knn_scaling(smoke: bool) -> (Vec<Json>, Option<Json>) {
    println!("\n== k-NN scaling: seed-grade vs serial engine vs parallel engine ==");
    let sizes: &[usize] = if smoke { &[50, 200] } else { &[50, 500, 5000] };
    let workers = default_workers();
    let mut rows = Vec::new();
    let mut acceptance = None;
    for &n in sizes {
        let idx = synthetic_db(n);
        let queries: Vec<Vec<f64>> = (0..5)
            .map(|qi| wave(96 + qi * 40, (qi * 7 + 3) as u64))
            .collect();
        // Exactness first: parallel == serial == seed-grade, bit for bit.
        for q in &queries {
            let (serial, _) = idx.knn(q, 1);
            let (par, _) = idx.knn_parallel(q, 1, workers);
            let seed = knn_seed_grade(q, &idx, 1);
            assert_eq!(serial[0].index, par[0].index, "parallel winner mismatch");
            assert_eq!(serial[0].distance.to_bits(), par[0].distance.to_bits());
            assert_eq!(serial[0].index, seed[0].index, "seed-grade winner mismatch");
            assert_eq!(serial[0].distance.to_bits(), seed[0].distance.to_bits());
        }
        let samples = if n >= 5000 { 3 } else { 8 };
        let seed = bench(&format!("seed-grade serial top-1   DB={n}"), 1, samples, || {
            queries.iter().map(|q| knn_seed_grade(q, &idx, 1)).collect::<Vec<_>>()
        });
        let serial = bench(&format!("scratch serial top-1      DB={n}"), 1, samples, || {
            queries.iter().map(|q| idx.knn(q, 1)).collect::<Vec<_>>()
        });
        let par = bench(
            &format!("scratch parallel top-1    DB={n} (w={workers})"),
            1,
            samples,
            || queries.iter().map(|q| idx.knn_parallel(q, 1, workers)).collect::<Vec<_>>(),
        );
        let speedup = seed.mean_s / par.mean_s;
        println!(
            "    DB={n}: parallel+scratch vs seed-grade speedup {speedup:.2}x (serial-only {:.2}x)",
            seed.mean_s / serial.mean_s
        );
        if n == 5000 {
            let pass = speedup >= 2.0;
            println!(
                "    acceptance (DB=5000): parallel+scratch >= 2x seed path: {}",
                if pass { "PASS" } else { "FAIL" }
            );
            acceptance = Some(Json::obj(vec![
                ("db", Json::Num(5000.0)),
                ("speedup_parallel_vs_seed", Json::Num(speedup)),
                ("pass", Json::Bool(pass)),
            ]));
        }
        rows.push(Json::obj(vec![
            ("db", Json::Num(n as f64)),
            ("workers", Json::Num(workers as f64)),
            ("seed_ms", Json::Num(seed.mean_s * 1e3)),
            ("serial_ms", Json::Num(serial.mean_s * 1e3)),
            ("parallel_ms", Json::Num(par.mean_s * 1e3)),
            ("speedup_parallel_vs_seed", Json::Num(speedup)),
            ("speedup_serial_vs_seed", Json::Num(seed.mean_s / serial.mean_s)),
        ]));
    }
    (rows, acceptance)
}

fn batch_scaling(smoke: bool) -> Vec<Json> {
    println!("\n== batched multi-query search: knn_batch vs one knn per query ==");
    let db_size = if smoke { 200 } else { 500 };
    let idx = synthetic_db(db_size);
    let mut rows = Vec::new();
    for &b in &[1usize, 8, 64] {
        // Four distinct lengths: realistic concurrency (same resample cap
        // buckets) and enough duplication for the shared envelope pass.
        let queries: Vec<Vec<f64>> = (0..b)
            .map(|i| wave(96 + (i % 4) * 40, 100 + i as u64))
            .collect();
        let qrefs: Vec<&[f64]> = queries.iter().map(Vec::as_slice).collect();
        // Exactness: every batched row equals its per-query search.
        let got = idx.knn_batch(&qrefs, 1);
        for (qi, q) in qrefs.iter().enumerate() {
            let (want, _) = idx.knn(q, 1);
            assert_eq!(got[qi].0[0].index, want[0].index, "batch mismatch at {qi}");
            assert_eq!(got[qi].0[0].distance.to_bits(), want[0].distance.to_bits());
        }
        let samples = if smoke { 3 } else { 8 };
        let batched = bench(&format!("knn_batch  DB={db_size} batch={b:>2}"), 1, samples, || {
            idx.knn_batch(&qrefs, 1)
        });
        let one_by_one = bench(&format!("knn x{b:<3}   DB={db_size} batch={b:>2}"), 1, samples, || {
            qrefs.iter().map(|q| idx.knn(q, 1)).collect::<Vec<_>>()
        });
        let speedup = one_by_one.mean_s / batched.mean_s;
        println!(
            "    batch={b}: {:.3} ms/query batched vs {:.3} ms/query serial ({speedup:.2}x)",
            batched.mean_s / b as f64 * 1e3,
            one_by_one.mean_s / b as f64 * 1e3
        );
        rows.push(Json::obj(vec![
            ("db", Json::Num(db_size as f64)),
            ("batch", Json::Num(b as f64)),
            ("batched_ms_per_query", Json::Num(batched.mean_s / b as f64 * 1e3)),
            ("serial_ms_per_query", Json::Num(one_by_one.mean_s / b as f64 * 1e3)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    rows
}

fn main() {
    mrtuner::util::logging::init();
    let smoke = std::env::var("MRTUNER_BENCH_SMOKE").is_ok();

    let kernels = kernel_micro(smoke);
    let (knn_rows, acceptance) = knn_scaling(smoke);
    let batch_rows = batch_scaling(smoke);

    let report = Json::obj(vec![
        ("bench", Json::Str("dtw_kernel_perf".into())),
        ("smoke", Json::Bool(smoke)),
        ("workers", Json::Num(default_workers() as f64)),
        ("kernels", Json::arr(kernels)),
        ("knn", Json::arr(knn_rows)),
        ("batch", Json::arr(batch_rows)),
        ("acceptance", acceptance.unwrap_or(Json::Null)),
    ]);
    std::fs::write("BENCH_dtw.json", report.to_pretty()).expect("write BENCH_dtw.json");
    println!("wrote BENCH_dtw.json");
}
