//! Inverted index — extra reference application (the classic search-engine
//! indexing workload the paper's introduction motivates: "indexing the
//! documents and returning appropriate information to incoming queries").
//! Maps `docid \t text` documents to `(word, docid)` postings; the reducer
//! merges postings lists. Map-heavy like WordCount but with high shuffle
//! selectivity (postings are not collapsible by a combiner), so its series
//! sits between WordCount's and TeraSort's.

use super::traits::{CostModel, Emit, Workload};
use super::AppId;
use crate::util::rng::{Rng, Zipf};

pub struct InvertedIndex {
    vocab: Vec<String>,
    zipf: Zipf,
}

const VOCAB: usize = 3_000;

impl Default for InvertedIndex {
    fn default() -> Self {
        let mut rng = Rng::new(0x1d0c_5ee0_91ab_cdef);
        let mut seen = std::collections::BTreeSet::new();
        let mut vocab = Vec::with_capacity(VOCAB);
        while vocab.len() < VOCAB {
            let n = 3 + rng.below(7) as usize;
            let w: String = (0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            if seen.insert(w.clone()) {
                vocab.push(w);
            }
        }
        InvertedIndex {
            vocab,
            zipf: Zipf::new(VOCAB, 1.05),
        }
    }
}

impl Workload for InvertedIndex {
    fn id(&self) -> AppId {
        AppId::InvertedIndex
    }

    fn generate(&self, bytes: usize, rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::with_capacity(bytes + 256);
        let mut doc = 0u64;
        while out.len() < bytes {
            doc += 1;
            out.extend_from_slice(format!("d{doc:07}\t").as_bytes());
            let words = rng.range_u64(20, 80);
            for i in 0..words {
                if i > 0 {
                    out.push(b' ');
                }
                out.extend_from_slice(self.vocab[self.zipf.sample(rng)].as_bytes());
            }
            out.push(b'\n');
        }
        out
    }

    fn map(&self, split: &[u8], emit: &mut Emit) {
        for line in split.split(|&b| b == b'\n') {
            let mut it = line.splitn(2, |&b| b == b'\t');
            let (Some(docid), Some(text)) = (it.next(), it.next()) else {
                continue;
            };
            // Unique words per document (set semantics for postings).
            let mut words: Vec<&[u8]> = text
                .split(|&b| b == b' ')
                .filter(|w| !w.is_empty())
                .collect();
            words.sort_unstable();
            words.dedup();
            for w in words {
                emit(w, docid);
            }
        }
    }

    fn reduce(&self, key: &[u8], values: &[Vec<u8>], out: &mut Vec<u8>) {
        out.extend_from_slice(key);
        out.push(b'\t');
        let mut docs: Vec<&Vec<u8>> = values.iter().collect();
        docs.sort_unstable();
        docs.dedup();
        for (i, d) in docs.iter().enumerate() {
            if i > 0 {
                out.push(b',');
            }
            out.extend_from_slice(d);
        }
        out.push(b'\n');
    }

    fn default_costs(&self) -> CostModel {
        CostModel {
            map_cpu_s_per_mb: 6.5,
            map_selectivity: 0.85,
            sort_cpu_s_per_mb: 1.0,
            reduce_cpu_s_per_mb: 1.5,
            reduce_selectivity: 0.8,
            startup_cpu_s: 1.2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mapreduce::run_job;

    #[test]
    fn postings_contain_document() {
        let ii = InvertedIndex::default();
        let input = b"d1\tapple banana\nd2\tbanana cherry\n".to_vec();
        let out = run_job(&ii, &input, 1, 1);
        let text = String::from_utf8(out.reducer_outputs[0].clone()).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        lines.sort();
        assert_eq!(lines, vec!["apple\td1", "banana\td1,d2", "cherry\td2"]);
    }

    #[test]
    fn duplicate_words_deduplicated() {
        let ii = InvertedIndex::default();
        let input = b"d9\tfoo foo foo bar\n".to_vec();
        let mut pairs = Vec::new();
        ii.map(&input, &mut |k, v| {
            pairs.push((k.to_vec(), v.to_vec()));
        });
        assert_eq!(pairs.len(), 2);
    }

    #[test]
    fn generated_docs_have_ids() {
        let ii = InvertedIndex::default();
        let mut rng = Rng::new(1);
        let data = ii.generate(16 * 1024, &mut rng);
        for line in std::str::from_utf8(&data).unwrap().lines().take(20) {
            assert!(line.starts_with('d'));
            assert!(line.contains('\t'));
        }
    }

    #[test]
    fn shuffle_is_large_fraction() {
        let ii = InvertedIndex::default();
        let mut rng = Rng::new(2);
        let data = ii.generate(32 * 1024, &mut rng);
        let out = run_job(&ii, &data, 2, 2);
        let ratio = out.counters.combine_output_bytes as f64 / data.len() as f64;
        assert!(ratio > 0.3, "ratio={ratio}");
    }

    #[test]
    fn cost_model_plausible() {
        assert!(InvertedIndex::default().default_costs().is_plausible());
    }
}
