//! Sakoe–Chiba banded DTW.
//!
//! Restricts the warping path to a diagonal band of radius `r` (scaled for
//! unequal lengths), cutting work from O(N·M) to O(r·max(N,M)). Exact when
//! the optimal path stays inside the band — which holds for the CPU series
//! here, whose misalignment is bounded by a few map-wave lengths.

use super::full::{backtrack, DtwResult};
use super::{local_cost, CHOICE_DIAG, CHOICE_LEFT, CHOICE_UP};

/// Banded DTW with Sakoe–Chiba radius `r` (in samples, on the `y` axis after
/// slope correction). `r >= max(n,m)` degenerates to full DTW.
pub fn dtw_banded(x: &[f64], y: &[f64], r: usize) -> DtwResult {
    let (n, m) = (x.len(), y.len());
    assert!(n > 0 && m > 0, "dtw_banded: empty series");
    let slope = (m.max(2) - 1) as f64 / (n.max(2) - 1) as f64;
    let inf = f64::INFINITY;

    // Row j-ranges; forced to overlap between consecutive rows and to
    // include the corners so a connected path always exists.
    let bounds: Vec<(usize, usize)> = (0..n)
        .map(|i| {
            let c = i as f64 * slope;
            let lo = (c - r as f64).floor().max(0.0) as usize;
            let hi = ((c + r as f64).ceil() as usize).min(m - 1);
            (lo, hi)
        })
        .collect();

    let mut choices = vec![CHOICE_DIAG; n * m];
    let mut prev = vec![inf; m];
    let mut cur = vec![inf; m];

    let (lo0, hi0) = bounds[0];
    debug_assert_eq!(lo0, 0);
    cur[0] = local_cost(x[0], y[0]);
    for j in lo0.max(1)..=hi0 {
        cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
        choices[j] = CHOICE_LEFT;
    }
    std::mem::swap(&mut prev, &mut cur);

    for i in 1..n {
        let (lo, hi) = bounds[i];
        let row = i * m;
        cur.iter_mut().for_each(|v| *v = inf);
        for j in lo..=hi {
            let d = local_cost(x[i], y[j]);
            let diag = if j > 0 { prev[j - 1] } else { inf };
            let up = prev[j];
            let left = if j > lo { cur[j - 1] } else { inf };
            let (vg, vchoice) = if diag <= up { (diag, CHOICE_DIAG) } else { (up, CHOICE_UP) };
            if left < vg {
                cur[j] = left + d;
                choices[row + j] = CHOICE_LEFT;
            } else {
                cur[j] = vg + d;
                choices[row + j] = vchoice;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    let distance = prev[m - 1];
    assert!(
        distance.is_finite(),
        "band too narrow to connect corners (r={r}, n={n}, m={m})"
    );
    let path = backtrack(&choices, n, m);
    DtwResult {
        distance,
        normalized: distance / (n + m) as f64,
        path,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::full::dtw;
    use crate::util::rng::Pcg32;

    fn rand_series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        (0..len).map(|_| g.f64()).collect()
    }

    #[test]
    fn wide_band_equals_full() {
        let mut g = Pcg32::new(10, 1);
        for _ in 0..15 {
            let lx = 2 + g.below(40) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 2 + g.below(40) as usize;
            let y = rand_series(&mut g, ly);
            let full = dtw(&x, &y).distance;
            let band = dtw_banded(&x, &y, x.len().max(y.len())).distance;
            assert!((full - band).abs() < 1e-12);
        }
    }

    #[test]
    fn band_is_lower_bounded_by_full() {
        // Constraining paths can only increase (or keep) the distance.
        let mut g = Pcg32::new(11, 2);
        for _ in 0..15 {
            let lx = 10 + g.below(50) as usize;
            let x = rand_series(&mut g, lx);
            let ly = 10 + g.below(50) as usize;
            let y = rand_series(&mut g, ly);
            let full = dtw(&x, &y).distance;
            for r in [2usize, 5, 10] {
                let band = dtw_banded(&x, &y, r).distance;
                assert!(band >= full - 1e-12, "r={r}: band {band} < full {full}");
            }
        }
    }

    #[test]
    fn small_shift_recovered_with_small_band() {
        let x: Vec<f64> = (0..80).map(|i| ((i as f64) * 0.3).sin()).collect();
        let y: Vec<f64> = (0..80).map(|i| (((i + 3) as f64) * 0.3).sin()).collect();
        let full = dtw(&x, &y).distance;
        let band = dtw_banded(&x, &y, 6).distance;
        assert!((full - band).abs() < 1e-9, "full {full} band {band}");
    }

    #[test]
    fn unequal_lengths_band_follows_slope() {
        let x: Vec<f64> = (0..60).map(|i| (i as f64 * 0.2).sin()).collect();
        let y: Vec<f64> = (0..120).map(|i| (i as f64 * 0.1).sin()).collect();
        let r = dtw_banded(&x, &y, 8);
        assert!(r.distance.is_finite());
        assert_eq!(r.path.first(), Some(&(0, 0)));
        assert_eq!(r.path.last(), Some(&(59, 119)));
    }

    #[test]
    fn identical_series_zero_even_tight_band() {
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.17).cos()).collect();
        assert_eq!(dtw_banded(&x, &x, 1).distance, 0.0);
    }
}
