//! Repo-native static analysis for the mrtuner tree.
//!
//! rustc and clippy cannot see repo-level policy: which modules must answer
//! with typed `ErrorCode` replies instead of panicking, which atomics may be
//! `Relaxed` without an explanation, which kernels must not allocate. This
//! crate is a small, dependency-free lexer plus rule engine that encodes
//! those invariants. It runs offline as part of tier-1 (the
//! `rust/tests/repolint.rs` integration test links it as a dev-dependency)
//! and as a CLI: `cargo run -p mrtuner-lint -- rust/src`.
//!
//! The lexer is comment/string/char-literal aware (line and nested block
//! comments, escapes, raw strings, byte strings, lifetimes vs char
//! literals) but deliberately not a parser: rules are token scans over
//! masked source, with `#[cfg(test)]` items and non-kernel functions
//! excluded by brace matching.
//!
//! Rules (paths are relative to the linted root, normally `rust/src`):
//!
//! - `no-panic` — no `.unwrap()` / `.expect(` / `panic!` in non-test code
//!   under `protocol/`, `client/`, `tuning/`, `coordinator/server.rs`,
//!   `coordinator/router.rs`. Those layers answer malformed input with
//!   typed `ErrorCode` replies (and the tuning controller sits on the live
//!   control loop); a panic there tears down a connection (or poisons a
//!   lock) instead of reporting the error.
//! - `relaxed-comment` — every `Ordering::Relaxed` outside `metrics.rs`
//!   must carry a `// relaxed:` justification on the same line or in the
//!   contiguous comment block directly above (a code line in between
//!   breaks the block). Relaxed is correct in this codebase exactly when
//!   the value is a monotone counter or an advisory cutoff; the comment
//!   forces the author to say which.
//! - `kernel-alloc` — no allocation constructs (`Vec::new`, `vec![`,
//!   `.to_vec(`, `.collect`, `Box::new`, `.clone()`) inside the zero-alloc
//!   `*_with` kernel functions of `dtw/`. Those functions are the
//!   scratch-arena hot path; an allocation there silently reintroduces the
//!   per-call cost the arenas removed.
//! - `no-io` — no `std::time` / `println!` / `eprintln!` in `dtw/`,
//!   `signal/`, `index/`, `tuning/` library code. Kernels stay
//!   deterministic and side-effect free; timing and reporting belong to
//!   the coordinator.
//! - `no-raw-clock` — no direct `Instant::now()` outside `trace/clock.rs`
//!   and `metrics.rs`. Time is injected through the `Clock` trait (carried
//!   by `TraceHandle`) so tests can drive servers and spans with a virtual
//!   clock; a raw `Instant::now()` silently escapes that control. Even the
//!   other `trace/` files (sinks, samplers, recorders) are held to it —
//!   they take timestamps as parameters.
//! - `bounded-retry` — a loop in `client/` or `coordinator/router.rs`
//!   whose body connects (`connect(` / `connect_timeout(` /
//!   `ensure_connected(` / `reconnect(`) must reference a retry bound — a
//!   `backoff` or `attempt` token somewhere in the loop. An unbounded
//!   reconnect loop turns one dead backend into a live-locked caller; the
//!   bound (or an explicit pragma) forces the author to say why the loop
//!   terminates.
//!
//! Any finding can be silenced with an inline pragma on the same or the
//! preceding line: `// lint: allow(<rule>)`.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Rule id: panics banned in typed-error zones.
pub const NO_PANIC: &str = "no-panic";
/// Rule id: `Ordering::Relaxed` needs a `// relaxed:` justification.
pub const RELAXED_COMMENT: &str = "relaxed-comment";
/// Rule id: no allocation constructs in `*_with` kernels under `dtw/`.
pub const KERNEL_ALLOC: &str = "kernel-alloc";
/// Rule id: no time/printing in kernel library code.
pub const NO_IO: &str = "no-io";
/// Rule id: `Instant::now()` only in `trace/clock.rs` and `metrics.rs` —
/// everyone else reads time through the injected `Clock`.
pub const NO_RAW_CLOCK: &str = "no-raw-clock";
/// Rule id: connect/reconnect loops in `client/` and
/// `coordinator/router.rs` must reference a backoff/attempt bound.
pub const BOUNDED_RETRY: &str = "bounded-retry";

/// One finding, ready to print as `file:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Source split into two equal-shape ASCII masks: `code` keeps only bytes
/// outside comments and literals, `comment` keeps only comment text.
/// Newlines survive in both, so line numbers align with the input; every
/// masked or non-ASCII byte becomes a space.
pub struct Masked {
    pub code: String,
    pub comment: String,
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

fn put(src: &[u8], mask: &mut [u8], i: usize) {
    if i < src.len() && src[i].is_ascii() && src[i] != b'\n' {
        mask[i] = src[i];
    }
}

fn skip_string(s: &[u8], open: usize) -> usize {
    let n = s.len();
    let mut i = open + 1;
    while i < n {
        match s[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    n
}

fn skip_raw_string(s: &[u8], content_start: usize, hashes: usize) -> usize {
    let n = s.len();
    let mut i = content_start;
    while i < n {
        if s[i] == b'"' {
            let tail = &s[i + 1..];
            if tail.len() >= hashes && tail[..hashes].iter().all(|&b| b == b'#') {
                return i + 1 + hashes;
            }
        }
        i += 1;
    }
    n
}

fn skip_char(s: &[u8], open: usize) -> usize {
    let n = s.len();
    let mut i = open + 1;
    if i < n && s[i] == b'\\' {
        i += 2;
    }
    while i < n && s[i] != b'\'' {
        i += 1;
    }
    (i + 1).min(n)
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >= 0xf0 {
        4
    } else if b >= 0xe0 {
        3
    } else {
        2
    }
}

/// Lex `src` into code and comment masks. Never fails: unterminated
/// literals or comments simply mask through to the end of input.
pub fn mask(src: &str) -> Masked {
    let s = src.as_bytes();
    let n = s.len();
    let mut code = vec![b' '; n];
    let mut comment = vec![b' '; n];
    for (i, &b) in s.iter().enumerate() {
        if b == b'\n' {
            code[i] = b'\n';
            comment[i] = b'\n';
        }
    }
    let mut i = 0;
    while i < n {
        let b = s[i];
        if b == b'/' && i + 1 < n && s[i + 1] == b'/' {
            while i < n && s[i] != b'\n' {
                put(s, &mut comment, i);
                i += 1;
            }
        } else if b == b'/' && i + 1 < n && s[i + 1] == b'*' {
            put(s, &mut comment, i);
            put(s, &mut comment, i + 1);
            i += 2;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if s[i] == b'/' && i + 1 < n && s[i + 1] == b'*' {
                    depth += 1;
                    put(s, &mut comment, i);
                    put(s, &mut comment, i + 1);
                    i += 2;
                } else if s[i] == b'*' && i + 1 < n && s[i + 1] == b'/' {
                    depth -= 1;
                    put(s, &mut comment, i);
                    put(s, &mut comment, i + 1);
                    i += 2;
                } else {
                    put(s, &mut comment, i);
                    i += 1;
                }
            }
        } else if b == b'"' {
            i = skip_string(s, i);
        } else if (b == b'r' || b == b'b') && (i == 0 || !is_ident_byte(s[i - 1])) {
            // Possible literal prefix: r", r#", b", br", br#", b'x'.
            let mut j = i + 1;
            let mut raw = b == b'r';
            if b == b'b' && j < n && s[j] == b'r' {
                raw = true;
                j += 1;
            }
            if raw {
                let mut hashes = 0usize;
                while j < n && s[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && s[j] == b'"' {
                    i = skip_raw_string(s, j + 1, hashes);
                } else {
                    // `r#ident` raw identifier or a plain identifier.
                    put(s, &mut code, i);
                    i += 1;
                }
            } else if j < n && s[j] == b'"' {
                i = skip_string(s, j);
            } else if j < n && s[j] == b'\'' {
                i = skip_char(s, j);
            } else {
                put(s, &mut code, i);
                i += 1;
            }
        } else if b == b'\'' {
            // Char literal ('x', '\n', possibly multi-byte) vs lifetime
            // ('a in types, loop labels): a literal has a closing quote
            // right after one escaped or plain character.
            if i + 1 < n && s[i + 1] == b'\\' {
                i = skip_char(s, i);
            } else {
                let mut j = i + 1;
                if j < n {
                    j += utf8_len(s[j]);
                }
                if i + 1 < n && j < n && s[j] == b'\'' {
                    i = j + 1;
                } else {
                    put(s, &mut code, i);
                    i += 1;
                }
            }
        } else {
            put(s, &mut code, i);
            i += 1;
        }
    }
    Masked {
        code: String::from_utf8(code).expect("code mask is ascii"),
        comment: String::from_utf8(comment).expect("comment mask is ascii"),
    }
}

fn match_brace(b: &[u8], open: usize) -> usize {
    let mut depth = 0i64;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    b.len()
}

/// Byte ranges of `#[cfg(test)]` items (attribute through the matching
/// closing brace, or through `;` for brace-less items).
fn test_ranges(code: &str) -> Vec<(usize, usize)> {
    const ATTR: &str = "#[cfg(test)]";
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find(ATTR) {
        let start = from + p;
        let mut j = start + ATTR.len();
        let mut end = b.len();
        while j < b.len() {
            if b[j] == b';' {
                end = j + 1;
                break;
            }
            if b[j] == b'{' {
                end = match_brace(b, j);
                break;
            }
            j += 1;
        }
        out.push((start, end));
        from = end.max(start + ATTR.len());
    }
    out
}

/// Byte ranges of the bodies of functions whose name ends in `_with` —
/// the zero-alloc kernel convention established by the scratch arenas.
fn kernel_fn_ranges(code: &str) -> Vec<(usize, usize)> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find("fn ") {
        let at = from + p;
        from = at + 3;
        if at > 0 && is_ident_byte(b[at - 1]) {
            continue;
        }
        let mut j = at + 3;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
        let name_start = j;
        while j < b.len() && is_ident_byte(b[j]) {
            j += 1;
        }
        if !code[name_start..j].ends_with("_with") {
            continue;
        }
        let mut k = j;
        while k < b.len() && b[k] != b'{' && b[k] != b';' {
            k += 1;
        }
        if k < b.len() && b[k] == b'{' {
            out.push((k, match_brace(b, k)));
        }
    }
    out
}

/// Byte ranges of loop constructs (`loop` / `while` / `for` keyword
/// through the matching close brace, header included) — the scan behind
/// `bounded-retry`. Like `kernel_fn_ranges` this is a keyword heuristic,
/// not a parse: the first `{` after the keyword is taken as the body.
fn loop_ranges(code: &str) -> Vec<(usize, usize)> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    for kw in ["loop", "while", "for"] {
        let mut from = 0;
        while let Some(p) = code[from..].find(kw) {
            let at = from + p;
            from = at + kw.len();
            let before_ok = at == 0 || !is_ident_byte(b[at - 1]);
            let after = at + kw.len();
            let after_ok = after >= b.len() || !is_ident_byte(b[after]);
            if !before_ok || !after_ok {
                continue;
            }
            let mut k = after;
            while k < b.len() && b[k] != b'{' && b[k] != b';' {
                k += 1;
            }
            if k < b.len() && b[k] == b'{' {
                out.push((at, match_brace(b, k)));
            }
        }
    }
    out
}

/// Byte span of each line (newline included), for mapping byte ranges to
/// per-line flags.
fn line_spans(text: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut start = 0;
    for (i, c) in text.char_indices() {
        if c == '\n' {
            out.push((start, i + 1));
            start = i + 1;
        }
    }
    if start < text.len() {
        out.push((start, text.len()));
    }
    out
}

fn span_flags(spans: &[(usize, usize)], ranges: &[(usize, usize)]) -> Vec<bool> {
    spans
        .iter()
        .map(|&(a, b)| ranges.iter().any(|&(x, y)| a < y && b > x))
        .collect()
}

/// Substring search with an identifier boundary before the match (so
/// `println!` does not fire inside `eprintln!`). Tokens starting with `.`
/// skip the boundary check.
fn has_token(line: &str, token: &str) -> bool {
    let lb = line.as_bytes();
    let boundary = !token.starts_with('.');
    let mut from = 0;
    while let Some(p) = line[from..].find(token) {
        let at = from + p;
        if !boundary || at == 0 || !is_ident_byte(lb[at - 1]) {
            return true;
        }
        from = at + 1;
    }
    false
}

fn allows(comment: &str, rule: &str) -> bool {
    let needle = format!("lint: allow({rule})");
    comment.contains(&needle)
}

fn violation(path: &str, ln: usize, rule: &'static str, message: String) -> Violation {
    Violation {
        file: path.to_string(),
        line: ln + 1,
        rule,
        message,
    }
}

/// Lint one file's source. `rel_path` is the path relative to the linted
/// root (normally `rust/src`) and selects which rules apply.
pub fn lint_str(rel_path: &str, src: &str) -> Vec<Violation> {
    let path = rel_path.replace('\\', "/");
    let masked = mask(src);
    let spans = line_spans(&masked.code);
    let is_test = span_flags(&spans, &test_ranges(&masked.code));
    let in_kernel = span_flags(&spans, &kernel_fn_ranges(&masked.code));
    let code_lines: Vec<&str> = masked.code.lines().collect();
    let comment_lines: Vec<&str> = masked.comment.lines().collect();

    let no_panic_zone = path.starts_with("protocol/")
        || path.starts_with("client/")
        || path.starts_with("tuning/")
        || path == "coordinator/server.rs"
        || path == "coordinator/router.rs";
    let relaxed_zone = !(path.ends_with("/metrics.rs") || path == "metrics.rs");
    let kernel_zone = path.starts_with("dtw/");
    let io_zone = path.starts_with("dtw/")
        || path.starts_with("signal/")
        || path.starts_with("index/")
        || path.starts_with("tuning/");
    // Only the clock abstraction itself may read real time — the rest of
    // `trace/` (sinks, samplers, recorders) takes timestamps as
    // parameters, and gets no blanket exemption for it.
    let clock_zone =
        !(path == "trace/clock.rs" || path.ends_with("/metrics.rs") || path == "metrics.rs");

    let mut out = Vec::new();
    for (ln, code_line) in code_lines.iter().enumerate() {
        if is_test.get(ln).copied().unwrap_or(false) {
            continue;
        }
        let comment_line = comment_lines.get(ln).copied().unwrap_or("");
        let prev_comment = ln
            .checked_sub(1)
            .and_then(|p| comment_lines.get(p))
            .copied()
            .unwrap_or("");
        let allowed = |rule: &str| allows(comment_line, rule) || allows(prev_comment, rule);

        if no_panic_zone && !allowed(NO_PANIC) {
            for tok in [".unwrap()", ".expect(", "panic!"] {
                if has_token(code_line, tok) {
                    let msg = format!("`{tok}` in a no-panic zone: reply with ErrorCode");
                    out.push(violation(&path, ln, NO_PANIC, msg));
                    break;
                }
            }
        }
        // A `relaxed:` justification may sit on the same line or anywhere
        // in the contiguous comment block directly above it (multi-line
        // explanations are encouraged, not penalized).
        let relaxed_justified = || {
            if comment_line.contains("relaxed:") {
                return true;
            }
            let mut i = ln;
            while i > 0 {
                i -= 1;
                let code_above = code_lines.get(i).copied().unwrap_or("");
                let comment_above = comment_lines.get(i).copied().unwrap_or("");
                if !code_above.trim().is_empty() || comment_above.trim().is_empty() {
                    return false;
                }
                if comment_above.contains("relaxed:") {
                    return true;
                }
            }
            false
        };
        if relaxed_zone
            && has_token(code_line, "Ordering::Relaxed")
            && !relaxed_justified()
            && !allowed(RELAXED_COMMENT)
        {
            let msg = "Ordering::Relaxed without a `// relaxed:` justification".to_string();
            out.push(violation(&path, ln, RELAXED_COMMENT, msg));
        }
        if kernel_zone && in_kernel.get(ln).copied().unwrap_or(false) && !allowed(KERNEL_ALLOC) {
            for tok in ["Vec::new", "vec![", ".to_vec(", ".collect", "Box::new", ".clone()"] {
                if has_token(code_line, tok) {
                    let msg = format!("`{tok}` inside a zero-alloc `*_with` kernel");
                    out.push(violation(&path, ln, KERNEL_ALLOC, msg));
                    break;
                }
            }
        }
        if io_zone && !allowed(NO_IO) {
            for tok in ["std::time", "println!", "eprintln!"] {
                if has_token(code_line, tok) {
                    let msg = format!("`{tok}` in kernel library code");
                    out.push(violation(&path, ln, NO_IO, msg));
                    break;
                }
            }
        }
        if clock_zone && !allowed(NO_RAW_CLOCK) && has_token(code_line, "Instant::now") {
            let msg =
                "`Instant::now()` outside trace/: read time through the injected `Clock`"
                    .to_string();
            out.push(violation(&path, ln, NO_RAW_CLOCK, msg));
        }
    }

    // bounded-retry is a loop-shaped rule, not a line-shaped one: the
    // connect call and its bound usually sit on different lines, so the
    // scan runs over whole loop bodies. The finding (and its pragma)
    // anchor on the loop's own line.
    let retry_zone = path.starts_with("client/") || path == "coordinator/router.rs";
    if retry_zone {
        const CONNECT_TOKENS: [&str; 4] =
            ["connect(", "connect_timeout(", "ensure_connected(", "reconnect("];
        for (start, end) in loop_ranges(&masked.code) {
            let ln = masked.code[..start].matches('\n').count();
            if is_test.get(ln).copied().unwrap_or(false) {
                continue;
            }
            let body = &masked.code[start..end];
            if !CONNECT_TOKENS.iter().any(|t| has_token(body, t)) {
                continue;
            }
            if has_token(body, "backoff") || has_token(body, "attempt") {
                continue;
            }
            let comment_line = comment_lines.get(ln).copied().unwrap_or("");
            let prev_comment = ln
                .checked_sub(1)
                .and_then(|p| comment_lines.get(p))
                .copied()
                .unwrap_or("");
            if allows(comment_line, BOUNDED_RETRY) || allows(prev_comment, BOUNDED_RETRY) {
                continue;
            }
            let msg = "reconnect loop without a backoff/attempt bound".to_string();
            out.push(violation(&path, ln, BOUNDED_RETRY, msg));
        }
    }
    out
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if dir.is_file() {
        if dir.extension().is_some_and(|e| e == "rs") {
            out.push(dir.to_path_buf());
        }
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (recursively, deterministic order).
/// Violations report the on-disk path; rule selection uses the path
/// relative to `root`.
pub fn lint_dir(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for file in &files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(file)?;
        for mut v in lint_str(&rel, &src) {
            v.file = file.display().to_string();
            out.push(v);
        }
    }
    Ok(out)
}

/// Render violations one per line (for test failure messages).
pub fn render(violations: &[Violation]) -> String {
    let mut s = String::new();
    for v in violations {
        s.push_str(&v.to_string());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    // ---------- lexer ----------

    #[test]
    fn mask_blanks_strings_comments_and_chars() {
        let src = "let s = \".unwrap()\"; // .unwrap() here\nlet c = '{';\n";
        let m = mask(src);
        assert!(!m.code.contains(".unwrap()"));
        assert!(!m.code.contains('{'));
        assert!(m.comment.contains(".unwrap() here"));
        assert_eq!(m.code.len(), src.len());
        assert_eq!(m.code.matches('\n').count(), 2);
    }

    #[test]
    fn mask_handles_raw_strings_and_nested_block_comments() {
        let src = "let r = r#\"panic!(\"x\")\"#;\n/* a /* panic!(x) */ .unwrap() */\nfn f() {}\n";
        let m = mask(src);
        assert!(!m.code.contains("panic!"));
        assert!(!m.code.contains(".unwrap()"));
        assert!(m.code.contains("fn f()"));
        assert!(m.comment.contains(".unwrap()"));
    }

    #[test]
    fn mask_keeps_lifetimes_as_code() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\n";
        let m = mask(src);
        assert!(m.code.contains("<'a>"));
        assert!(m.code.contains("&'a str"));
    }

    #[test]
    fn mask_blanks_escaped_char_literals() {
        let src = "let a = '\\n'; let b = '\\''; let c = 'x';\n";
        let m = mask(src);
        assert!(!m.code.contains("\\n"));
        assert!(!m.code.contains('x'));
        assert!(m.code.contains("let a ="));
    }

    #[test]
    fn mask_handles_byte_literals() {
        let src = "let a = b\"panic!\"; let b = b'x'; let c = br#\"vec![\"#;\n";
        let m = mask(src);
        assert!(!m.code.contains("panic!"));
        assert!(!m.code.contains("vec!["));
        assert!(m.code.contains("let a ="));
    }

    // ---------- no-panic ----------

    #[test]
    fn no_panic_fires_in_zone_files() {
        let bad = "fn f() -> u32 {\n    x.unwrap()\n}\n";
        for path in [
            "protocol/mod.rs",
            "client/mod.rs",
            "coordinator/server.rs",
            "tuning/controller.rs",
        ] {
            let vs = lint_str(path, bad);
            assert_eq!(rules_of(&vs), vec![NO_PANIC], "{path}");
            assert_eq!(vs[0].line, 2, "{path}");
        }
        // Outside the zones the same source is fine.
        assert!(lint_str("streaming/session.rs", bad).is_empty());
        assert!(lint_str("coordinator/matcher.rs", bad).is_empty());
    }

    #[test]
    fn no_panic_covers_expect_and_panic_tokens() {
        let expect = "fn f() -> u32 {\n    x.expect(\"set\")\n}\n";
        assert_eq!(rules_of(&lint_str("protocol/request.rs", expect)), vec![NO_PANIC]);
        let panics = "fn f() {\n    panic!(\"boom\");\n}\n";
        assert_eq!(rules_of(&lint_str("coordinator/router.rs", panics)), vec![NO_PANIC]);
        // Non-panicking relatives stay legal.
        let ok = "fn f() -> u32 {\n    x.unwrap_or(0)\n}\n";
        assert!(lint_str("protocol/mod.rs", ok).is_empty());
    }

    #[test]
    fn no_panic_pragma_silences_same_and_previous_line() {
        let prev = "fn f() {\n    // lint: allow(no-panic)\n    x.unwrap()\n}\n";
        assert!(lint_str("protocol/mod.rs", prev).is_empty());
        let same = "fn f() {\n    x.unwrap() // lint: allow(no-panic)\n}\n";
        assert!(lint_str("protocol/mod.rs", same).is_empty());
    }

    #[test]
    fn no_panic_skips_test_modules_and_literals() {
        let src = concat!(
            "pub fn f() {}\n\n",
            "#[cfg(test)]\nmod tests {\n",
            "    fn t() {\n        x.unwrap();\n    }\n}\n"
        );
        assert!(lint_str("protocol/mod.rs", src).is_empty());
        let in_str = "fn f() -> &'static str {\n    \".unwrap() and panic!\"\n}\n";
        assert!(lint_str("protocol/mod.rs", in_str).is_empty());
    }

    // ---------- relaxed-comment ----------

    #[test]
    fn relaxed_requires_justification_comment() {
        let bad = "fn f() -> u64 {\n    c.load(Ordering::Relaxed)\n}\n";
        let vs = lint_str("util/pool.rs", bad);
        assert_eq!(rules_of(&vs), vec![RELAXED_COMMENT]);
        assert_eq!(vs[0].line, 2);

        let prev = "fn f() -> u64 {\n    // relaxed: monotone\n    c.load(Ordering::Relaxed)\n}\n";
        assert!(lint_str("util/pool.rs", prev).is_empty());
        let same = "fn f() -> u64 {\n    c.load(Ordering::Relaxed) // relaxed: monotone\n}\n";
        assert!(lint_str("util/pool.rs", same).is_empty());
    }

    #[test]
    fn relaxed_justification_may_open_a_comment_block() {
        // The marker sits two comment lines above the atomic op — still
        // the same contiguous block, so it counts.
        let block = concat!(
            "fn f() -> u64 {\n",
            "    // relaxed: monotone counter, and\n",
            "    // nothing else rides on it.\n",
            "    c.load(Ordering::Relaxed)\n}\n"
        );
        assert!(lint_str("util/pool.rs", block).is_empty());
        // A code line between the marker and the op breaks the block.
        let broken = concat!(
            "fn f() -> u64 {\n",
            "    // relaxed: monotone\n    let x = 1;\n",
            "    c.load(Ordering::Relaxed) + x\n}\n"
        );
        assert_eq!(rules_of(&lint_str("util/pool.rs", broken)), vec![RELAXED_COMMENT]);
    }

    #[test]
    fn relaxed_exempts_metrics_and_accepts_pragma() {
        let bad = "fn f() -> u64 {\n    c.load(Ordering::Relaxed)\n}\n";
        assert!(lint_str("coordinator/metrics.rs", bad).is_empty());
        assert_eq!(rules_of(&lint_str("coordinator/server.rs", bad)), vec![RELAXED_COMMENT]);
        let ok = concat!(
            "fn f() {\n    // lint: allow(relaxed-comment)\n",
            "    c.load(Ordering::Relaxed);\n}\n"
        );
        assert!(lint_str("util/pool.rs", ok).is_empty());
    }

    // ---------- kernel-alloc ----------

    #[test]
    fn kernel_alloc_fires_only_inside_with_kernels_under_dtw() {
        let bad = concat!(
            "pub fn dtw_with(s: &mut S) -> f64 {\n",
            "    let v = xs.iter().collect();\n    v\n}\n"
        );
        let vs = lint_str("dtw/banded.rs", bad);
        assert_eq!(rules_of(&vs), vec![KERNEL_ALLOC]);
        assert_eq!(vs[0].line, 2);
        // Same construct outside dtw/ or outside a kernel fn is fine.
        assert!(lint_str("streaming/session.rs", bad).is_empty());
        let non_kernel = "pub fn dtw(xs: &[f64]) -> Vec<f64> {\n    xs.to_vec()\n}\n";
        assert!(lint_str("dtw/full.rs", non_kernel).is_empty());
    }

    #[test]
    fn kernel_alloc_catches_each_construct_and_pragma_silences() {
        for line in [
            "let a = Vec::new();",
            "let b = vec![0.0; 4];",
            "let c = xs.to_vec();",
            "let d = Box::new(0.0);",
            "let e = xs.clone();",
        ] {
            let bad = format!("pub fn k_with(xs: &[f64]) -> f64 {{\n    {line}\n    0.0\n}}\n");
            assert_eq!(rules_of(&lint_str("dtw/full.rs", &bad)), vec![KERNEL_ALLOC], "{line}");
            let pragma = bad.replace(line, &format!("{line} // lint: allow(kernel-alloc)"));
            assert!(lint_str("dtw/full.rs", &pragma).is_empty(), "{line}");
        }
    }

    #[test]
    fn kernel_alloc_brace_matching_survives_char_literals() {
        let src = concat!(
            "fn open_with(c: char) -> bool {\n    c == '{'\n}\n\n",
            "fn after() {\n    vec![1];\n}\n"
        );
        assert!(lint_str("dtw/full.rs", src).is_empty());
    }

    // ---------- no-io ----------

    #[test]
    fn no_io_fires_in_kernel_dirs_only() {
        let bad = "pub fn trace(x: f64) {\n    println!(\"{x}\");\n}\n";
        for path in [
            "dtw/mod.rs",
            "signal/noise.rs",
            "index/knn.rs",
            "tuning/predictor.rs",
        ] {
            assert_eq!(rules_of(&lint_str(path, bad)), vec![NO_IO], "{path}");
        }
        // The coordinator may print.
        assert!(lint_str("coordinator/server.rs", bad).is_empty());
        // Raw clock reads trip both rules in kernel dirs (no-io for the
        // `std::time` path, no-raw-clock for the construct itself).
        let timed = "pub fn slow() {\n    let t = std::time::Instant::now();\n    drop(t);\n}\n";
        assert_eq!(rules_of(&lint_str("index/db.rs", timed)), vec![NO_IO, NO_RAW_CLOCK]);
        assert_eq!(rules_of(&lint_str("coordinator/profiler.rs", timed)), vec![NO_RAW_CLOCK]);
    }

    // ---------- no-raw-clock ----------

    #[test]
    fn raw_clock_banned_outside_trace_and_metrics() {
        let bad = "pub fn f() -> Instant {\n    Instant::now()\n}\n";
        // Trace *sinks* get no blanket exemption: they receive timestamps
        // as parameters, so a raw read there is as suspect as anywhere.
        for path in [
            "coordinator/server.rs",
            "streaming/manager.rs",
            "util/logging.rs",
            "trace/recorder.rs",
            "trace/sampler.rs",
            "trace/multi.rs",
        ] {
            let vs = lint_str(path, bad);
            assert_eq!(rules_of(&vs), vec![NO_RAW_CLOCK], "{path}");
            assert_eq!(vs[0].line, 2, "{path}");
        }
        // The clock abstraction itself and the metrics registry are the
        // two places allowed to read real time.
        assert!(lint_str("trace/clock.rs", bad).is_empty());
        assert!(lint_str("coordinator/metrics.rs", bad).is_empty());
    }

    #[test]
    fn raw_clock_pragma_and_tests_are_exempt() {
        let pragma = concat!(
            "pub fn f() -> Instant {\n",
            "    // lint: allow(no-raw-clock) startup anchor, never compared\n",
            "    Instant::now()\n}\n"
        );
        assert!(lint_str("util/logging.rs", pragma).is_empty());
        let in_test = concat!(
            "pub fn f() {}\n\n",
            "#[cfg(test)]\nmod tests {\n",
            "    fn t() {\n        let _ = Instant::now();\n    }\n}\n"
        );
        assert!(lint_str("util/pool.rs", in_test).is_empty());
        // Mentions in strings or comments never fire.
        let in_str = "pub fn f() -> &'static str {\n    \"Instant::now\"\n}\n";
        assert!(lint_str("coordinator/server.rs", in_str).is_empty());
    }

    #[test]
    fn no_io_eprintln_boundary_and_pragma() {
        let e = "pub fn warn() {\n    eprintln!(\"x\");\n}\n";
        let vs = lint_str("dtw/mod.rs", e);
        assert_eq!(rules_of(&vs), vec![NO_IO]);
        assert!(vs[0].message.contains("eprintln!"), "{}", vs[0].message);
        let ok = "pub fn warn() {\n    eprintln!(\"x\"); // lint: allow(no-io)\n}\n";
        assert!(lint_str("dtw/mod.rs", ok).is_empty());
    }

    // ---------- bounded-retry ----------

    #[test]
    fn bounded_retry_fires_on_unbounded_connect_loops_in_zone() {
        let bad = concat!(
            "fn f(addr: &str) {\n",
            "    loop {\n",
            "        if TcpStream::connect(addr).is_ok() {\n",
            "            break;\n        }\n    }\n}\n"
        );
        for path in ["client/mod.rs", "coordinator/router.rs"] {
            let vs = lint_str(path, bad);
            assert_eq!(rules_of(&vs), vec![BOUNDED_RETRY], "{path}");
            assert_eq!(vs[0].line, 2, "{path}");
        }
        // The same loop elsewhere is someone else's policy.
        assert!(lint_str("coordinator/matcher.rs", bad).is_empty());
        assert!(lint_str("faultproxy/mod.rs", bad).is_empty());
    }

    #[test]
    fn bounded_retry_covers_each_connect_spelling() {
        for call in [
            "TcpStream::connect(addr)",
            "MrtunerClient::connect_timeout(addr, t)",
            "self.ensure_connected()",
            "self.reconnect()",
        ] {
            let bad = format!("fn f() {{\n    while alive {{\n        let _ = {call};\n    }}\n}}\n");
            assert_eq!(rules_of(&lint_str("client/mod.rs", &bad)), vec![BOUNDED_RETRY], "{call}");
        }
        // `connection(`-shaped names are not connect calls.
        let ok = "fn f() {\n    loop {\n        route_connection(s);\n    }\n}\n";
        assert!(lint_str("coordinator/router.rs", ok).is_empty());
    }

    #[test]
    fn bounded_retry_accepts_backoff_or_attempt_bounds() {
        let attempts = concat!(
            "fn f() {\n",
            "    for attempt in 0..3 {\n",
            "        let _ = TcpStream::connect(addr);\n    }\n}\n"
        );
        assert!(lint_str("client/mod.rs", attempts).is_empty());
        let backoff = concat!(
            "fn f() {\n",
            "    loop {\n",
            "        let _ = self.ensure_connected();\n",
            "        std::thread::sleep(self.backoff.delay(n));\n    }\n}\n"
        );
        assert!(lint_str("client/mod.rs", backoff).is_empty());
    }

    #[test]
    fn bounded_retry_pragma_and_tests_are_exempt() {
        let pragma = concat!(
            "fn f(group: &[String]) {\n",
            "    // each replica is tried exactly once\n",
            "    // lint: allow(bounded-retry)\n",
            "    for addr in group {\n",
            "        let _ = TcpStream::connect(addr);\n    }\n}\n"
        );
        assert!(lint_str("coordinator/router.rs", pragma).is_empty());
        let in_test = concat!(
            "pub fn f() {}\n\n",
            "#[cfg(test)]\nmod tests {\n",
            "    fn t() {\n",
            "        loop {\n",
            "            let _ = TcpStream::connect(addr);\n        }\n    }\n}\n"
        );
        assert!(lint_str("client/mod.rs", in_test).is_empty());
        // Connect mentions inside strings or comments never make a loop
        // a reconnect loop.
        let in_str = concat!(
            "fn f() {\n",
            "    loop {\n",
            "        // connect(addr) would be wrong here\n",
            "        log(\"connect(later)\");\n        break;\n    }\n}\n"
        );
        assert!(lint_str("client/mod.rs", in_str).is_empty());
    }

    // ---------- engine plumbing ----------

    #[test]
    fn one_violation_per_rule_per_line() {
        let bad = "fn f() -> u32 {\n    x.unwrap(); x.expect(\"two\")\n}\n";
        assert_eq!(lint_str("protocol/mod.rs", bad).len(), 1);
    }

    #[test]
    fn display_format_is_file_line_rule() {
        let bad = "fn f() -> u32 {\n    x.unwrap()\n}\n";
        let vs = lint_str("protocol/mod.rs", bad);
        let line = vs[0].to_string();
        assert!(line.starts_with("protocol/mod.rs:2: [no-panic]"), "{line}");
    }

    #[test]
    fn pragma_for_one_rule_does_not_silence_another() {
        let src = "fn f() {\n    // lint: allow(no-panic)\n    c.load(Ordering::Relaxed);\n}\n";
        assert_eq!(rules_of(&lint_str("util/pool.rs", src)), vec![RELAXED_COMMENT]);
    }
}
