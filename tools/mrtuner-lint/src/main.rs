//! CLI driver: `mrtuner-lint [DIR ...]` — lint the given roots (default
//! `rust/src`), print violations to stderr, exit nonzero if any.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut roots: Vec<String> = std::env::args().skip(1).collect();
    if roots.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("usage: mrtuner-lint [DIR ...]   (default: rust/src)");
        return ExitCode::SUCCESS;
    }
    if roots.is_empty() {
        roots.push("rust/src".to_string());
    }
    let mut total = 0usize;
    for root in &roots {
        match mrtuner_lint::lint_dir(Path::new(root)) {
            Ok(violations) => {
                for v in &violations {
                    eprintln!("{v}");
                }
                total += violations.len();
            }
            Err(e) => {
                eprintln!("mrtuner-lint: {root}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    if total > 0 {
        eprintln!("mrtuner-lint: {total} violation(s)");
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}
