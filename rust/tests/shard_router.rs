//! Multi-node integration: N in-process shard servers behind a
//! [`ShardRouter`] must answer k-NN **bit-identically** to a single-node
//! `IndexedDb` over the union database (distances, indices, order), and
//! routed matching must equal single-node dispatch. Also pins the
//! `shard_unavailable` failure surface.

use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::router::{dispatch_routed, route_line, RouterServer, ShardRouter};
use mrtuner::coordinator::server::{dispatch, MatchServer, ServerState};
use mrtuner::database::profile::ProfileEntry;
use mrtuner::index::IndexedDb;
use mrtuner::protocol::{Request, Response};
use mrtuner::simulator::job::JobConfig;
use mrtuner::streaming::SessionManager;
use mrtuner::util::json::Json;
use mrtuner::workloads::AppId;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn raw_wave(freq: f64, len: usize) -> Vec<f64> {
    (0..len)
        .map(|i| (0.5 + 0.4 * ((i as f64) * freq).sin()).clamp(0.0, 1.0))
        .collect()
}

fn entry(app: AppId, cfg: JobConfig, freq: f64, len: usize) -> ProfileEntry {
    ProfileEntry {
        app,
        config: cfg,
        series: mrtuner::signal::preprocess(&raw_wave(freq, len)),
        raw_len: len,
        completion_secs: 100.0,
    }
}

/// Three config sets, two apps each, distinct shapes per entry. Returns
/// (per-shard databases in shard order, the union in the same order).
fn partitioned_dbs() -> (Vec<IndexedDb>, IndexedDb, Vec<JobConfig>) {
    let configs = vec![
        JobConfig::new(4, 2, 10.0, 20.0),
        JobConfig::new(8, 4, 20.0, 40.0),
        JobConfig::new(16, 8, 30.0, 80.0),
    ];
    let mut shards: Vec<IndexedDb> = Vec::new();
    for (ci, cfg) in configs.iter().enumerate() {
        let mut db = IndexedDb::new();
        for (ai, app) in [AppId::WordCount, AppId::TeraSort].into_iter().enumerate() {
            // Distinct frequency and length per (app, config).
            let freq = 0.15 + 0.11 * (ci * 2 + ai) as f64;
            let len = 48 + 16 * ci;
            db.insert(entry(app, *cfg, freq, len));
        }
        shards.push(db);
    }
    let mut union = IndexedDb::new();
    for shard in &shards {
        for e in shard.entries() {
            union.insert(e.clone());
        }
    }
    (shards, union, configs)
}

fn state_over(db: IndexedDb) -> ServerState {
    ServerState {
        db,
        runtime: None,
        metrics: Metrics::new(),
        sessions: SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    }
}

struct Fleet {
    addrs: Vec<String>,
    stops: Vec<Arc<AtomicBool>>,
    joins: Vec<std::thread::JoinHandle<anyhow::Result<()>>>,
}

fn spawn_fleet(shards: Vec<IndexedDb>) -> Fleet {
    let mut fleet = Fleet {
        addrs: Vec::new(),
        stops: Vec::new(),
        joins: Vec::new(),
    };
    for db in shards {
        let server = MatchServer::bind("127.0.0.1:0", state_over(db)).unwrap();
        fleet.addrs.push(server.local_addr().unwrap().to_string());
        fleet.stops.push(server.stop_flag());
        fleet
            .joins
            .push(std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50))));
    }
    fleet
}

impl Fleet {
    fn shutdown(self) {
        for (stop, addr) in self.stops.iter().zip(&self.addrs) {
            stop.store(true, Ordering::SeqCst);
            let _ = std::net::TcpStream::connect(addr);
        }
        for j in self.joins {
            j.join().unwrap().unwrap();
        }
    }
}

#[test]
fn routed_knn_is_bit_identical_to_single_node() {
    let (shards, union, configs) = partitioned_dbs();
    let fleet = spawn_fleet(shards);
    let metrics = Arc::new(Metrics::new());
    let mut router = ShardRouter::connect(&fleet.addrs, Arc::clone(&metrics)).unwrap();
    assert_eq!(router.total_entries(), union.len());
    assert_eq!(router.shards().len(), 3);
    for (si, shard) in router.shards().iter().enumerate() {
        assert_eq!(shard.base, si * 2, "bases are running entry sums");
        assert_eq!(shard.entries, 2);
        assert_eq!(shard.configs, vec![configs[si].label()]);
    }

    // A batch of queries of assorted lengths and shapes, including one
    // that exactly matches a stored entry (distance 0 through the stack).
    let queries: Vec<Vec<f64>> = vec![
        raw_wave(0.15, 48),
        raw_wave(0.7, 100),
        raw_wave(0.3, 64),
        raw_wave(0.48, 80),
    ];
    for k in [1usize, 3, 6, 10] {
        let routed = router.knn_batch(&queries, k, None).unwrap();
        let prepared: Vec<Vec<f64>> =
            queries.iter().map(|q| mrtuner::coordinator::batcher::prepare_query(q)).collect();
        let qrefs: Vec<&[f64]> = prepared.iter().map(Vec::as_slice).collect();
        let local = union.knn_batch(&qrefs, k);
        assert_eq!(routed.results.len(), local.len());
        for (qi, (routed_body, (local_nbs, local_stats))) in
            routed.results.iter().zip(&local).enumerate()
        {
            assert_eq!(
                routed_body.neighbors.len(),
                local_nbs.len(),
                "query {qi} k={k}: row count"
            );
            for (r, l) in routed_body.neighbors.iter().zip(local_nbs) {
                assert_eq!(r.index, l.index, "query {qi} k={k}: neighbour index");
                assert_eq!(
                    r.distance.to_bits(),
                    l.distance.to_bits(),
                    "query {qi} k={k}: distance bits ({} vs {})",
                    r.distance,
                    l.distance
                );
                // The row's app/config must name the union entry it claims.
                let e = &union.entries()[r.index];
                assert_eq!(r.app, e.app.name());
                assert_eq!(r.config, e.config_key());
            }
            // Candidate coverage matches the union scan (the per-stage
            // pruning split legitimately differs across shard cutoffs).
            assert_eq!(routed_body.stats.candidates, local_stats.candidates);
        }
    }

    // The self-query finds its own entry at distance zero.
    let routed = router.knn(&raw_wave(0.15, 48), 1, None).unwrap();
    assert_eq!(routed.neighbors[0].distance, 0.0);
    assert_eq!(routed.neighbors[0].index, 0);

    // Config-scoped routing consults only the owning shard.
    let scoped = router.knn(&raw_wave(0.3, 64), 4, Some(&configs[1])).unwrap();
    assert_eq!(scoped.stats.candidates, 2, "one shard's bucket only");
    for r in &scoped.neighbors {
        assert_eq!(r.config, configs[1].label());
        assert!(r.index >= 2 && r.index < 4, "global index in shard 1's range");
    }
    // Unknown config: empty, not an error.
    let none = router
        .knn(&raw_wave(0.3, 64), 4, Some(&JobConfig::new(99, 9, 1.0, 1.0)))
        .unwrap();
    assert!(none.neighbors.is_empty());

    // Per-shard fan-out latency was recorded for every shard.
    let fanout = metrics.shard_fanout_summary();
    assert_eq!(fanout.len(), 3, "{fanout:?}");
    assert!(fanout.iter().all(|&(_, n, _, _)| n > 0));

    fleet.shutdown();
}

#[test]
fn routed_match_equals_single_node_dispatch() {
    let (shards, union, configs) = partitioned_dbs();
    let fleet = spawn_fleet(shards);
    let metrics = Arc::new(Metrics::new());
    let mut router = ShardRouter::connect(&fleet.addrs, metrics).unwrap();

    let union_state = state_over(union);
    let series = raw_wave(0.15, 48);
    let req = Request::Match {
        series: series.clone(),
        config: configs[0],
    };
    let local = match dispatch(&req, &union_state).unwrap() {
        Response::Match(b) => b,
        other => panic!("{other:?}"),
    };
    let routed = router.match_config(&series, &configs[0]).unwrap();
    assert_eq!(routed, local, "routed match diverged from single node");
    assert_eq!(routed.matched.as_deref(), Some("wordcount"));

    fleet.shutdown();
}

#[test]
fn router_server_front_end_speaks_both_envelopes() {
    let (shards, union, _configs) = partitioned_dbs();
    let fleet = spawn_fleet(shards);
    let metrics = Arc::new(Metrics::new());
    let router = ShardRouter::connect(&fleet.addrs, metrics).unwrap();
    let front = RouterServer::bind("127.0.0.1:0", router).unwrap();
    let addr = front.local_addr().unwrap();
    let stop = front.stop_flag();
    let join = std::thread::spawn(move || front.serve_with(2, Duration::from_millis(50)));

    // Typed v2 client against the router front-end.
    let mut client = mrtuner::client::MrtunerClient::connect(&addr.to_string()).unwrap();
    client.ping().unwrap();
    let info = client.shard_info().unwrap();
    assert_eq!(info.entries, union.len());
    assert_eq!(info.configs.len(), 3);
    let knn = client.knn(&raw_wave(0.15, 48), 2, None).unwrap();
    assert_eq!(knn.neighbors.len(), 2);
    assert_eq!(knn.neighbors[0].distance, 0.0);
    // Stream commands are not routed: typed bad_request.
    let err = client.stream_poll(1, 1).unwrap_err();
    assert_eq!(err.code(), Some(mrtuner::protocol::ErrorCode::BadRequest));

    // Legacy v1 framing works against the router too.
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    raw.write_all(b"{\"cmd\":\"apps\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(
        resp.get("apps").and_then(Json::as_arr).map(|a| a.len()),
        Some(2)
    );

    drop(reader);
    drop(raw);
    stop.store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
    join.join().unwrap().unwrap();
    fleet.shutdown();
}

#[test]
fn dead_shard_surfaces_as_shard_unavailable() {
    let (shards, _union, _configs) = partitioned_dbs();
    let fleet = spawn_fleet(shards);
    let metrics = Arc::new(Metrics::new());
    let mut router = ShardRouter::connect(&fleet.addrs, Arc::clone(&metrics)).unwrap();

    // Warm fan-out: every shard answers and records a latency sample.
    router.knn(&raw_wave(0.3, 64), 1, None).unwrap();
    assert_eq!(metrics.shard_fanout_summary().len(), 3);

    // Kill shard 1 out from under the router.
    fleet.stops[1].store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(&fleet.addrs[1]);
    // Wait for the listener to actually close.
    std::thread::sleep(Duration::from_millis(150));

    let router = Mutex::new(router);
    let req = Request::Knn {
        series: raw_wave(0.3, 64),
        k: 1,
        config: None,
        allow_partial: false,
    };
    let err = dispatch_routed(&req, &router).unwrap_err();
    assert_eq!(err.code, mrtuner::protocol::ErrorCode::ShardUnavailable, "{err}");

    // The routed line path renders it as a typed v2 error.
    let m = Metrics::new();
    let tracer = mrtuner::trace::TraceHandle::disabled();
    let resp = route_line(&req.to_v2(5).to_string(), &router, &m, &tracer);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(
        resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("shard_unavailable")
    );
    assert_eq!(m.proto_error_count(mrtuner::protocol::ErrorCode::ShardUnavailable), 1);

    // Strict mode never degrades, and a single-replica slot has nowhere
    // to fail over to — the fault counters stay at their pre-kill state.
    let (_retries, failovers, _opens, _probes, degraded) = metrics.fault_summary();
    assert_eq!(degraded, 0, "strict mode never degrades");
    assert_eq!(failovers, 0, "single-replica slots have no standby");

    // Shards 0 and 2 still need a clean shutdown.
    for i in [0usize, 2] {
        fleet.stops[i].store(true, Ordering::SeqCst);
        let _ = std::net::TcpStream::connect(&fleet.addrs[i]);
    }
    for j in fleet.joins {
        j.join().unwrap().unwrap();
    }
}

#[test]
fn shard_refusals_pass_through_untranslated() {
    let (shards, _union, _configs) = partitioned_dbs();
    let fleet = spawn_fleet(shards);
    let metrics = Arc::new(Metrics::new());
    let router = ShardRouter::connect(&fleet.addrs, Arc::clone(&metrics)).unwrap();
    let router = Mutex::new(router);

    // A three-sample query passes the router (typed request, no wire
    // decode) but every shard refuses it: shorter than the protocol's
    // four-sample minimum. A refusal is a healthy shard answering — its
    // own code must come back untranslated, with `shard_unavailable`
    // reserved for transport failures.
    let req = Request::Knn {
        series: vec![0.1, 0.2, 0.3],
        k: 1,
        config: None,
        allow_partial: false,
    };
    let err = dispatch_routed(&req, &router).unwrap_err();
    assert_eq!(err.code, mrtuner::protocol::ErrorCode::BadRequest, "{err}");

    // And no transport fault was recorded: nothing retried, nothing
    // failed over, no circuit moved, nothing degraded.
    assert_eq!(metrics.fault_summary(), (0, 0, 0, 0, 0));

    drop(router);
    fleet.shutdown();
}
