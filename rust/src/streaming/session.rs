//! One live classification session: a growing CPU capture, its online
//! preprocessing state, and the anytime top-k over a candidate set of the
//! reference database.
//!
//! Lifecycle: [`StreamSession::open`] resolves the candidate set (one
//! configuration bucket, or the whole database), [`StreamSession::push`]
//! ingests sample batches and refreshes bounds / rankings / the early-exit
//! check, and [`StreamSession::finalize`] runs the exact indexed search on
//! the full capture — identical to `Matcher::match_app_indexed`'s per
//! config search, which is what makes a completed session agree with the
//! offline pipeline no matter what was culled along the way.

use super::anytime::prefix_dtw_with;
use super::prefix_lb::{prefix_lb, FinalLen};
use super::StreamStats;
use crate::dtw::scratch::DtwScratch;
use crate::dtw::corr::similarity_percent_banded;
use crate::index::knn::{knn, Neighbor};
use crate::index::{IndexedDb, SearchStats};
use crate::signal::chebyshev::{Sos, SosState};
use crate::signal::normalize::OnlineMinMax;
use crate::simulator::job::JobConfig;
use crate::workloads::AppId;

/// Length budget for the *decimated* query the incremental machinery
/// operates on. The matching pipeline linearly resamples raw captures
/// above 512 samples (`coordinator::batcher::prepare_query`), so per-row
/// prefix geometry is only meaningful up to this length. When the raw
/// capture outgrows the budget the session doubles its decimation factor
/// and rebuilds the online state from every `decim`-th raw sample —
/// streams of any length stay incremental. Decimation approximates the
/// pipeline's linear resample, so past the first doubling the anytime
/// ranking is heuristic; [`StreamSession::finalize`] stays exact on the
/// full retained capture.
pub const MAX_STREAM_LEN: usize = 512;

/// Hard cap on retained raw samples per session (18 hours at the 1 Hz
/// SysStat rate, ~512 KB): a client cannot grow server memory without
/// bound through `stream_feed`. Samples past the cap are counted but
/// dropped (that is the only condition that flags
/// [`StreamSession::overflowed`]); `finalize` then answers from the
/// retained capture.
pub const MAX_RETAINED: usize = 1 << 16;

/// Minimum number of candidates (ranked by lower bound) whose exact
/// prefix DP is refreshed per batch. Beyond this, candidates are probed
/// only while their bound is still inside the decision margin bar — an
/// unprobed candidate is then provably irrelevant to both the anytime
/// top-1 and the exit check.
const PROBE_WIDTH: usize = 4;

/// When to declare an early decision.
#[derive(Debug, Clone, Copy)]
pub struct DecisionPolicy {
    /// Minimum fraction of the expected final length that must be observed.
    pub min_fraction: f64,
    /// The runner-up's final-distance lower bound must exceed the best
    /// candidate's current distance by this factor.
    pub margin: f64,
    /// Absolute floor on observed samples.
    pub min_samples: usize,
}

impl Default for DecisionPolicy {
    fn default() -> Self {
        DecisionPolicy {
            min_fraction: 0.25,
            margin: 1.2,
            min_samples: 24,
        }
    }
}

impl DecisionPolicy {
    /// A policy that never declares early — sessions then behave exactly
    /// like the offline pipeline (used by the equivalence tests).
    pub fn never() -> DecisionPolicy {
        DecisionPolicy {
            min_fraction: 2.0,
            ..DecisionPolicy::default()
        }
    }
}

/// An early classification declared mid-stream.
#[derive(Debug, Clone)]
pub struct StreamDecision {
    /// Application of the winning reference entry.
    pub app: AppId,
    /// Configuration set of the winning reference entry.
    pub config: JobConfig,
    /// Position of the winning entry in the database.
    pub entry: usize,
    /// Anytime prefix distance of the winner at declaration time.
    pub distance: f64,
    /// Correlation similarity (%) of the normalized prefix vs the winner.
    pub similarity: f64,
    /// Samples observed when the decision was declared.
    pub at_sample: usize,
    /// `at_sample / expected final length`.
    pub fraction: f64,
}

/// One candidate's live state.
#[derive(Debug, Clone)]
struct Candidate {
    /// Entry position in the database.
    pos: usize,
    /// Monotone lower bound on the final banded distance.
    lb: f64,
    /// Anytime prefix distance (None when not probed or abandoned).
    dist: Option<f64>,
    /// This round's best floor on the candidate's distance for the exit
    /// check: `max(lb, dp row-min)` when probed, `max(lb, abandon
    /// cutoff)` when the DP provably cleared the margin bar, plain `lb`
    /// when it never needed probing.
    floor: f64,
    /// Permanently out of the anytime race (never out of `finalize`).
    culled: bool,
}

/// A ranked row of the anytime top-k.
#[derive(Debug, Clone)]
pub struct TopEntry {
    pub entry: usize,
    pub app: AppId,
    pub config: JobConfig,
    /// Anytime prefix distance, if this candidate was probed.
    pub distance: Option<f64>,
    /// Monotone lower bound on its final distance.
    pub lower_bound: f64,
}

/// One live stream's classification state.
#[derive(Debug, Clone)]
pub struct StreamSession {
    /// Candidate scope: a config label, or the whole database.
    bucket: Option<String>,
    final_len: FinalLen,
    policy: DecisionPolicy,
    /// The filter design, kept so decimation rebuilds can restart it.
    sos: Sos,
    /// Value domain of the filtered signal (`Sos::output_bounds`).
    domain: (f64, f64),
    raw: Vec<f64>,
    /// Every `decim`-th raw sample feeds the online pipeline; doubles
    /// whenever the decimated length would exceed [`MAX_STREAM_LEN`].
    decim: usize,
    /// Raw samples already consumed into the decimated pipeline.
    next_raw: usize,
    filt: SosState,
    filtered: Vec<f64>,
    norm: OnlineMinMax,
    cands: Vec<Candidate>,
    decision: Option<StreamDecision>,
    stats: StreamStats,
    overflow: bool,
    /// DP buffer arena reused across every probe this session ever runs.
    scratch: DtwScratch,
}

impl StreamSession {
    /// Open a session over one configuration bucket (`Some(config)`) or the
    /// whole database (`None`). The candidate set is resolved once; later
    /// database inserts are not observed (sessions are short-lived).
    pub fn open(
        idx: &IndexedDb,
        config: Option<&JobConfig>,
        final_len: FinalLen,
        policy: DecisionPolicy,
    ) -> StreamSession {
        let bucket = config.map(|c| c.label());
        let positions: Vec<usize> = match &bucket {
            Some(label) => idx.config_positions(label).to_vec(),
            None => (0..idx.len()).collect(),
        };
        let sos = Sos::lowpass_default();
        // Raw CPU utilization is confined to [0,1] by the samplers.
        let domain = sos.output_bounds(0.0, 1.0, 1024);
        StreamSession {
            bucket,
            final_len,
            policy,
            domain,
            raw: Vec::new(),
            decim: 1,
            next_raw: 0,
            filt: sos.stream(),
            filtered: Vec::new(),
            norm: OnlineMinMax::new(),
            sos,
            cands: positions
                .into_iter()
                .map(|pos| Candidate {
                    pos,
                    lb: 0.0,
                    dist: None,
                    floor: 0.0,
                    culled: false,
                })
                .collect(),
            decision: None,
            stats: StreamStats::default(),
            overflow: false,
            scratch: DtwScratch::new(),
        }
    }

    /// Ingest one batch of raw CPU samples and refresh the anytime state.
    /// Returns the (frozen) early decision, if one has been declared.
    pub fn push(&mut self, idx: &IndexedDb, samples: &[f64]) -> Option<&StreamDecision> {
        self.stats.batches += 1;
        self.stats.samples += samples.len() as u64;
        let room = MAX_RETAINED.saturating_sub(self.raw.len());
        if samples.len() > room {
            self.overflow = true; // retention exhausted: extra samples drop
        }
        self.raw.extend_from_slice(&samples[..samples.len().min(room)]);
        let mut rebuilt = false;
        while self.raw.len().div_ceil(self.decim) > MAX_STREAM_LEN {
            self.decim *= 2;
            rebuilt = true;
        }
        if rebuilt {
            self.reset_derived();
        }
        let grew = self.ingest_pending();
        if grew || rebuilt {
            self.update(idx);
        }
        self.decision.as_ref()
    }

    /// Feed not-yet-consumed raw samples through the decimated pipeline.
    /// Returns whether the filtered series grew (at `decim == 1` this is
    /// sample-for-sample identical to filtering the batch directly).
    fn ingest_pending(&mut self) -> bool {
        let before = self.filtered.len();
        while self.next_raw < self.raw.len() {
            if self.next_raw % self.decim == 0 {
                let y = self.filt.push(self.raw[self.next_raw]);
                self.filtered.push(y);
                self.norm.push(y);
            }
            self.next_raw += 1;
        }
        self.filtered.len() != before
    }

    /// Drop every derived online structure (filter state, extrema, bounds,
    /// cull flags) so the retained raw capture can be re-consumed under a
    /// new decimation factor. The frozen decision, if any, survives — it
    /// was declared under a then-valid policy.
    fn reset_derived(&mut self) {
        self.filt = self.sos.stream();
        self.filtered.clear();
        self.norm = OnlineMinMax::new();
        self.next_raw = 0;
        self.reset_bounds();
    }

    /// Reset every candidate's bound state: bounds computed under an older
    /// band geometry (different decimation or final-length hint) are not
    /// comparable, and a cull is only as trustworthy as the bound behind
    /// it.
    fn reset_bounds(&mut self) {
        for c in self.cands.iter_mut() {
            c.lb = 0.0;
            c.dist = None;
            c.floor = 0.0;
            c.culled = false;
        }
    }

    /// Install a refined final-length hint mid-stream (e.g. from the
    /// online length predictor). Candidate bounds were computed under the
    /// old band geometry, so they reset — culled candidates re-enter the
    /// race — and the anytime state is recomputed immediately. An
    /// already-frozen decision is never revisited.
    pub fn set_final_len(&mut self, idx: &IndexedDb, final_len: FinalLen) {
        if final_len == self.final_len {
            return;
        }
        self.final_len = final_len;
        self.reset_bounds();
        self.update(idx);
    }

    /// Refresh bounds, probe finalists, cull, and check the exit policy.
    fn update(&mut self, idx: &IndexedDb) {
        let p = self.filtered.len();
        if p < 4 || self.cands.is_empty() {
            return;
        }
        // Band geometry runs on the decimated scale the filtered series
        // lives on (identity at `decim == 1`).
        let flen = match self.final_len {
            FinalLen::Known(n) => FinalLen::Known(n.div_ceil(self.decim)),
            FinalLen::AtMost(n) => FinalLen::AtMost(n.div_ceil(self.decim)),
        };
        let domain = self.domain;

        // 1. Monotone lower bounds for every live candidate. Prefix
        //    distances from earlier rounds were computed under an older
        //    normalization, so drop them; only this round's probes count.
        //    Under `--features audit`, the documented monotonicity (the
        //    bound never decreases as the stream grows) is asserted on
        //    every live candidate — but only while the band geometry is
        //    stable: a `Known`/`AtMost` hint the prefix has outgrown
        //    self-corrects and legitimately resets the bound.
        #[cfg(feature = "audit")]
        let geometry_stable = match flen {
            FinalLen::Known(n) | FinalLen::AtMost(n) => p <= n,
        };
        for c in self.cands.iter_mut().filter(|c| !c.culled) {
            let lb = prefix_lb(&self.filtered, &self.norm, domain, flen, idx.envelope(c.pos));
            #[cfg(feature = "audit")]
            debug_assert!(
                !geometry_stable || lb >= c.lb - 1e-9 * (1.0 + c.lb.abs()),
                "audit: prefix_lb regressed from {} to {lb} at p={p}",
                c.lb
            );
            c.lb = lb;
            c.dist = None;
            c.floor = c.lb;
            self.stats.lb_evals += 1;
        }

        // 2. Exact prefix DP in ascending-bound order: always the first
        //    PROBE_WIDTH finalists, then only candidates whose bound is
        //    still inside the margin bar (everyone past that point has an
        //    even larger bound and can affect neither the anytime top-1
        //    nor the exit check). The DP abandons at the margin bar, which
        //    still proves a floor above it.
        let qp = self.norm.normalize(&self.filtered);
        let dp_len = flen.expected(p);
        let mut order: Vec<usize> = (0..self.cands.len())
            .filter(|&i| !self.cands[i].culled)
            .collect();
        order.sort_by(|&a, &b| {
            self.cands[a]
                .lb
                .partial_cmp(&self.cands[b].lb)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let entries = idx.entries();
        let margin = self.policy.margin.max(1.0);
        let mut bsf = f64::INFINITY;
        let mut best_ci: Option<usize> = None;
        let mut probed = 0usize;
        for &ci in &order {
            let lb = self.cands[ci].lb;
            if probed >= PROBE_WIDTH && lb > bsf * margin {
                break; // order is ascending: nobody later matters either
            }
            let series = entries[self.cands[ci].pos].series.as_slice();
            if series.is_empty() {
                continue;
            }
            let cut = if bsf.is_finite() {
                bsf * margin + 1e-9 * (1.0 + bsf)
            } else {
                bsf
            };
            match prefix_dtw_with(&mut self.scratch, &qp, series, dp_len, cut) {
                None => {
                    // Abandoned above the bar: final-for-this-round floor.
                    self.cands[ci].floor = lb.max(cut);
                    self.stats.dp_abandoned += 1;
                }
                Some(dp) => {
                    self.cands[ci].dist = Some(dp.row_min);
                    self.cands[ci].floor = lb.max(dp.row_min);
                    self.stats.dp_evals += 1;
                    if dp.row_min < bsf {
                        bsf = dp.row_min;
                        best_ci = Some(ci);
                    }
                }
            }
            probed += 1;
        }

        // 3. Cull candidates whose guaranteed-minimum final cost already
        //    exceeds the best candidate's current prefix distance. This is
        //    the anytime race only — finalize() always re-scans everyone.
        if let Some(best) = best_ci {
            let cut = bsf + 1e-9 * (1.0 + bsf);
            for (i, c) in self.cands.iter_mut().enumerate() {
                if i != best && !c.culled && c.lb > cut {
                    c.culled = true;
                    self.stats.culled += 1;
                }
            }
            if self.decision.is_none() {
                self.maybe_decide(entries, &qp, bsf, best);
            }
        }
    }

    /// Declare an early decision when the margin policy is satisfied.
    fn maybe_decide(
        &mut self,
        entries: &[crate::database::profile::ProfileEntry],
        qp: &[f64],
        best_dist: f64,
        best_ci: usize,
    ) {
        // Policy thresholds are on the raw-sample scale the caller set
        // them in, independent of the current decimation factor.
        let observed = self.raw.len();
        let expected = self.final_len.expected(observed);
        let fraction = observed as f64 / expected as f64;
        if observed < self.policy.min_samples || fraction < self.policy.min_fraction {
            return;
        }
        let best_pos = self.cands[best_ci].pos;
        let best_app = entries[best_pos].app;
        // Tightest available floor on any differently-classified
        // candidate's distance. Culled candidates still contest through
        // their frozen envelope bound: it was admissible for their final
        // distance when computed, and the best's distance may have *risen*
        // since they were culled — only the bound-vs-margin comparison
        // below decides, never the cull itself.
        let mut runner = f64::INFINITY;
        for c in &self.cands {
            if entries[c.pos].app != best_app {
                runner = runner.min(if c.culled { c.lb } else { c.floor });
            }
        }
        if runner > best_dist * self.policy.margin + 1e-12 {
            let series = &entries[best_pos].series;
            self.decision = Some(StreamDecision {
                app: best_app,
                config: entries[best_pos].config,
                entry: best_pos,
                distance: best_dist,
                similarity: similarity_percent_banded(qp, series),
                at_sample: observed,
                fraction,
            });
        }
    }

    /// Exact top-`k` over the session's candidate set using the *full*
    /// capture and the offline preprocessing path — byte-for-byte the
    /// search `Matcher::match_app_indexed` runs for this bucket.
    pub fn finalize(&self, idx: &IndexedDb, k: usize) -> (Vec<Neighbor>, SearchStats) {
        let q = crate::coordinator::batcher::prepare_query(&self.raw);
        let entries = idx.entries();
        knn(
            &q,
            self.cands
                .iter()
                .map(|c| (c.pos, entries[c.pos].series.as_slice(), idx.envelope(c.pos))),
            k,
        )
    }

    /// Current anytime ranking of the live candidates: probed candidates
    /// by prefix distance, then unprobed ones by lower bound.
    pub fn top(&self, idx: &IndexedDb, k: usize) -> Vec<TopEntry> {
        let entries = idx.entries();
        let mut rows: Vec<TopEntry> = self
            .cands
            .iter()
            .filter(|c| !c.culled)
            .map(|c| TopEntry {
                entry: c.pos,
                app: entries[c.pos].app,
                config: entries[c.pos].config,
                distance: c.dist,
                lower_bound: c.lb,
            })
            .collect();
        rows.sort_by(|a, b| {
            let ka = (a.distance.is_none(), a.distance.unwrap_or(a.lower_bound));
            let kb = (b.distance.is_none(), b.distance.unwrap_or(b.lower_bound));
            ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal)
        });
        rows.truncate(k);
        rows
    }

    /// The early decision, if one has been declared.
    pub fn decision(&self) -> Option<&StreamDecision> {
        self.decision.as_ref()
    }

    /// Raw samples observed so far.
    pub fn observed(&self) -> usize {
        self.raw.len()
    }

    /// The raw capture accumulated so far.
    pub fn raw(&self) -> &[f64] {
        &self.raw
    }

    /// Fraction of the expected final length observed so far.
    pub fn fraction_observed(&self) -> f64 {
        let p = self.raw.len();
        if p == 0 {
            0.0
        } else {
            p as f64 / self.final_len.expected(p) as f64
        }
    }

    /// Total candidates in scope.
    pub fn candidates(&self) -> usize {
        self.cands.len()
    }

    /// Candidates still in the anytime race.
    pub fn live_candidates(&self) -> usize {
        self.cands.iter().filter(|c| !c.culled).count()
    }

    /// Whether raw samples were dropped at the retention cap (see
    /// [`MAX_RETAINED`]). Long streams no longer overflow the incremental
    /// regime — they decimate (see [`MAX_STREAM_LEN`]).
    pub fn overflowed(&self) -> bool {
        self.overflow
    }

    /// Current decimation factor (1 while the capture fits the
    /// incremental budget; doubles past each multiple of
    /// [`MAX_STREAM_LEN`]).
    pub fn decimation(&self) -> usize {
        self.decim
    }

    /// The final-length hint currently in force.
    pub fn final_len(&self) -> FinalLen {
        self.final_len
    }

    /// The config bucket this session is scoped to, if any.
    pub fn bucket(&self) -> Option<&str> {
        self.bucket.as_deref()
    }

    /// Work counters.
    pub fn stats(&self) -> StreamStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::database::profile::ProfileEntry;
    use crate::signal;
    use crate::util::rng::Rng;

    /// Two distinguishable pattern families under one config set — the
    /// frequencies differ enough that the Sakoe–Chiba band cannot absorb
    /// one into the other.
    fn sine_raw(len: usize, freq: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..len)
            .map(|i| {
                (0.5 + 0.4 * ((i as f64) * freq).sin() + rng.normal_ms(0.0, 0.02))
                    .clamp(0.0, 1.0)
            })
            .collect()
    }

    const WC_FREQ: f64 = 0.05;
    const TS_FREQ: f64 = 0.3;

    fn test_db() -> IndexedDb {
        let mut idx = IndexedDb::new();
        let cfg = JobConfig::new(4, 2, 10.0, 20.0);
        for (app, freq) in [(AppId::WordCount, WC_FREQ), (AppId::TeraSort, TS_FREQ)] {
            let raw = sine_raw(200, freq, 7);
            idx.insert(ProfileEntry {
                app,
                config: cfg,
                series: signal::preprocess(&raw),
                raw_len: 200,
                completion_secs: 200.0,
            });
        }
        idx
    }

    fn cfg() -> JobConfig {
        JobConfig::new(4, 2, 10.0, 20.0)
    }

    #[test]
    fn fed_to_completion_matches_offline_search() {
        let idx = test_db();
        let raw = sine_raw(200, WC_FREQ, 99); // wordcount-shaped, new noise
        let mut s = StreamSession::open(
            &idx,
            Some(&cfg()),
            FinalLen::Known(raw.len()),
            DecisionPolicy::never(),
        );
        for chunk in raw.chunks(17) {
            s.push(&idx, chunk);
        }
        assert!(s.decision().is_none(), "never-policy must not declare");
        let (top, _) = s.finalize(&idx, 1);
        // Offline reference: the indexed search over the same bucket.
        let q = crate::coordinator::batcher::prepare_query(&raw);
        let (want, _) = idx.knn_in_config(&q, &cfg().label(), 1);
        assert_eq!(top[0].index, want[0].index);
        assert_eq!(top[0].distance.to_bits(), want[0].distance.to_bits());
        assert_eq!(idx.entries()[top[0].index].app, AppId::WordCount);
    }

    #[test]
    fn early_decision_finds_the_right_app_and_fraction() {
        let idx = test_db();
        let raw = sine_raw(200, WC_FREQ, 41);
        let mut s = StreamSession::open(
            &idx,
            Some(&cfg()),
            FinalLen::Known(raw.len()),
            DecisionPolicy::default(),
        );
        let mut decided_at = None;
        for (bi, chunk) in raw.chunks(10).enumerate() {
            if s.push(&idx, chunk).is_some() && decided_at.is_none() {
                decided_at = Some(bi);
            }
        }
        let d = s.decision().expect("clearly-separated patterns must decide");
        assert_eq!(d.app, AppId::WordCount);
        assert!(d.fraction < 1.0, "decided only at the very end: {}", d.fraction);
        assert!(d.at_sample <= raw.len());
        assert!((0.0..=100.0).contains(&d.similarity));
        assert!(s.stats().dp_evals > 0 && s.stats().lb_evals > 0);
    }

    #[test]
    fn anytime_top_ranks_the_matching_pattern_first() {
        let idx = test_db();
        let raw = sine_raw(200, TS_FREQ, 55); // terasort-shaped
        let mut s = StreamSession::open(
            &idx,
            Some(&cfg()),
            FinalLen::Known(raw.len()),
            DecisionPolicy::never(),
        );
        for chunk in raw.chunks(25) {
            s.push(&idx, chunk);
        }
        let top = s.top(&idx, 2);
        assert!(!top.is_empty());
        assert_eq!(top[0].app, AppId::TeraSort);
        assert_eq!(s.observed(), 200);
        assert!((s.fraction_observed() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn whole_db_scope_and_decimation() {
        let idx = test_db();
        let mut s = StreamSession::open(
            &idx,
            None,
            FinalLen::AtMost(MAX_STREAM_LEN),
            DecisionPolicy::default(),
        );
        assert_eq!(s.candidates(), idx.len());
        assert!(s.bucket().is_none());
        assert_eq!(s.decimation(), 1);
        // Outgrow the incremental budget: the session doubles its
        // decimation factor instead of overflowing, and finalize still
        // answers from the full capture via the resampling offline path.
        let long = sine_raw(MAX_STREAM_LEN + 100, WC_FREQ, 3);
        for chunk in long.chunks(64) {
            s.push(&idx, chunk);
        }
        assert!(!s.overflowed(), "decimation keeps long streams incremental");
        assert_eq!(s.decimation(), 2);
        assert_eq!(s.observed(), long.len());
        let (top, _) = s.finalize(&idx, 1);
        assert_eq!(top.len(), 1);
        let q = crate::coordinator::batcher::prepare_query(&long);
        let (want, _) = idx.knn(&q, 1);
        assert_eq!(top[0].index, want[0].index);
    }

    #[test]
    fn decimated_sessions_keep_updating_bounds() {
        let idx = test_db();
        let mut s = StreamSession::open(
            &idx,
            None,
            FinalLen::AtMost(4 * MAX_STREAM_LEN),
            DecisionPolicy::never(),
        );
        let long = sine_raw(3 * MAX_STREAM_LEN, WC_FREQ, 8);
        let mut mid = StreamStats::default();
        for (i, chunk) in long.chunks(128).enumerate() {
            s.push(&idx, chunk);
            if i == 5 {
                mid = s.stats(); // past the first doubling (768 samples)
            }
        }
        assert_eq!(s.decimation(), 4); // 1536 raw / 4 = 384 <= 512
        assert!(
            s.stats().lb_evals > mid.lb_evals,
            "bounds must keep refreshing after decimation: {} then {}",
            mid.lb_evals,
            s.stats().lb_evals
        );
        assert!(!s.overflowed());
        assert!(!s.top(&idx, 1).is_empty());
    }

    #[test]
    fn refined_length_hint_resets_and_redecides() {
        let idx = test_db();
        let raw = sine_raw(200, WC_FREQ, 41);
        // Open with only the loose cap; install the exact length
        // mid-stream, as the online length predictor would.
        let mut s = StreamSession::open(
            &idx,
            Some(&cfg()),
            FinalLen::AtMost(MAX_STREAM_LEN),
            DecisionPolicy::default(),
        );
        for chunk in raw[..100].chunks(10) {
            s.push(&idx, chunk);
        }
        assert_eq!(s.final_len(), FinalLen::AtMost(MAX_STREAM_LEN));
        s.set_final_len(&idx, FinalLen::Known(200));
        assert_eq!(s.final_len(), FinalLen::Known(200));
        for chunk in raw[100..].chunks(10) {
            s.push(&idx, chunk);
        }
        let d = s.decision().expect("known length must let the session decide");
        assert_eq!(d.app, AppId::WordCount);
        assert!(d.at_sample <= 200);
        // The geometry reset never disturbs the exact final answer.
        let (top, _) = s.finalize(&idx, 1);
        let q = crate::coordinator::batcher::prepare_query(&raw);
        let (want, _) = idx.knn_in_config(&q, &cfg().label(), 1);
        assert_eq!(top[0].index, want[0].index);
    }

    #[test]
    fn retention_cap_bounds_memory() {
        let idx = test_db();
        let mut s = StreamSession::open(
            &idx,
            None,
            FinalLen::AtMost(MAX_STREAM_LEN),
            DecisionPolicy::default(),
        );
        let chunk = vec![0.5; 4096];
        for _ in 0..20 {
            s.push(&idx, &chunk); // 81920 samples offered
        }
        assert_eq!(s.observed(), MAX_RETAINED, "retention must cap at MAX_RETAINED");
        assert_eq!(s.stats().samples, 20 * 4096, "all offered samples are counted");
        assert!(s.overflowed());
    }

    #[test]
    fn empty_bucket_is_harmless() {
        let idx = test_db();
        let other = JobConfig::new(9, 9, 9.0, 9.0);
        let mut s = StreamSession::open(
            &idx,
            Some(&other),
            FinalLen::AtMost(MAX_STREAM_LEN),
            DecisionPolicy::default(),
        );
        assert_eq!(s.candidates(), 0);
        s.push(&idx, &[0.1, 0.2, 0.3, 0.4, 0.5]);
        assert!(s.decision().is_none());
        let (top, _) = s.finalize(&idx, 3);
        assert!(top.is_empty());
    }
}
