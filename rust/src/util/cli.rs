//! Small subcommand/flag argument parser (clap is not vendorable offline).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value]... [positional]...`
//! Flags may be given as `--key=value` or `--key value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.options.insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn opt_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; panics with a helpful message on bad input.
    pub fn opt<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.options.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("invalid value for --{key}: {v:?}")),
        }
    }

    /// True if `--flag` was given (value-less).
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("profile --app wordcount --seed 42 --verbose");
        assert_eq!(a.command.as_deref(), Some("profile"));
        assert_eq!(a.opt_str("app", ""), "wordcount");
        assert_eq!(a.opt::<u64>("seed", 0), 42);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("match --db=/tmp/db.json --topk=3");
        assert_eq!(a.opt_str("db", ""), "/tmp/db.json");
        assert_eq!(a.opt::<usize>("topk", 1), 3);
    }

    #[test]
    fn positionals_after_command() {
        let a = parse("tune exim wordcount --grid small");
        assert_eq!(a.command.as_deref(), Some("tune"));
        assert_eq!(a.positional, vec!["exim", "wordcount"]);
        assert_eq!(a.opt_str("grid", ""), "small");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("serve");
        assert_eq!(a.opt::<u16>("port", 7070), 7070);
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    #[should_panic(expected = "invalid value for --seed")]
    fn bad_typed_option_panics() {
        let a = parse("profile --seed notanumber");
        let _: u64 = a.opt("seed", 0);
    }
}
