//! Time sources for the tracing layer.
//!
//! All clock reads in the serving stack go through the [`Clock`] trait so
//! tests (and loom models) can substitute a deterministic [`VirtualClock`]
//! and the mrtuner-lint `no-raw-clock` rule can confine raw
//! `Instant::now()` to this module plus `coordinator/metrics.rs`. Pure
//! compute layers (`dtw/`, `signal/`, `index/`) never see a clock at all:
//! spans are created by their callers and timestamps are read by the
//! [`TraceHandle`](super::TraceHandle) that owns the clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotone nanosecond counter. Implementations must never go backwards;
/// the zero point is arbitrary (only differences are meaningful).
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;
}

/// Production clock: monotone wall time anchored at construction, so the
/// emitted nanosecond values stay small enough to survive the `f64` path
/// through the hand-rolled JSON layer (2^53 ns ≈ 104 days of uptime).
#[derive(Debug)]
pub struct MonotonicClock {
    start: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { start: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        // u64 truncation is safe for ~584 years of elapsed time.
        self.start.elapsed().as_nanos() as u64
    }
}

/// Deterministic test clock: every read advances time by a fixed tick, so
/// any two reads observe strictly increasing values and every span gets a
/// non-zero duration without sleeping. [`VirtualClock::advance`] injects
/// larger jumps (e.g. to trigger idle deadlines).
#[derive(Debug)]
pub struct VirtualClock {
    now: AtomicU64,
    tick: u64,
}

impl VirtualClock {
    /// A clock starting at zero that advances `tick_ns` per read.
    pub fn new(tick_ns: u64) -> VirtualClock {
        VirtualClock {
            now: AtomicU64::new(0),
            tick: tick_ns.max(1),
        }
    }

    /// Jump the clock forward by `ns` without counting as a read.
    pub fn advance(&self, ns: u64) {
        // relaxed: monotone test-clock counter; readers only need *some*
        // strictly increasing value, no other memory is published with it.
        self.now.fetch_add(ns, Ordering::Relaxed);
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        // relaxed: monotone test-clock counter (see advance); fetch_add
        // keeps concurrent readers strictly ordered among themselves.
        self.now.fetch_add(self.tick, Ordering::Relaxed) + self.tick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_never_goes_backwards() {
        let c = MonotonicClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_ticks_per_read_and_advances() {
        let c = VirtualClock::new(10);
        assert_eq!(c.now_ns(), 10);
        assert_eq!(c.now_ns(), 20);
        c.advance(1_000);
        assert_eq!(c.now_ns(), 1_030);
    }

    #[test]
    fn virtual_clock_zero_tick_is_clamped() {
        let c = VirtualClock::new(0);
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b > a, "reads must remain strictly increasing");
    }
}
