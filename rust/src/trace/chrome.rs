//! [`ChromeTracker`]: emits the Chrome/Perfetto `trace_event` JSON format
//! (an array of complete `"ph":"X"` events), so a captured request opens
//! directly in `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! Spans sharing one local root are grouped on one track (`tid` = the
//! root span's id), so concurrent requests render as parallel rows of one
//! process. Events and notes become the span's `args`.

use super::{SpanId, Tracker};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

struct Open {
    name: &'static str,
    parent: SpanId,
    remote_parent: SpanId,
    start_ns: u64,
    /// Track id: the id of this span's local root.
    tid: u64,
    args: Vec<(String, Json)>,
}

#[derive(Default)]
struct Inner {
    open: HashMap<SpanId, Open>,
    done: Vec<Json>,
}

/// Span sink accumulating finished `trace_event` records; drain with
/// [`ChromeTracker::to_json`] or [`ChromeTracker::write_to`].
#[derive(Default)]
pub struct ChromeTracker {
    next: AtomicU64,
    inner: Mutex<Inner>,
}

impl ChromeTracker {
    pub fn new() -> ChromeTracker {
        ChromeTracker::default()
    }

    /// The complete trace document (finished spans only, begin order).
    pub fn to_json(&self) -> Json {
        let inner = self.guard();
        Json::obj(vec![
            ("displayTimeUnit", Json::Str("ms".to_string())),
            ("traceEvents", Json::arr(inner.done.clone())),
        ])
    }

    /// Number of finished spans captured so far.
    pub fn len(&self) -> usize {
        self.guard().done.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write the trace document to `path` (pretty-printed; open the file
    /// in a trace viewer).
    pub fn write_to(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl std::fmt::Debug for ChromeTracker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChromeTracker").field("finished", &self.len()).finish()
    }
}

impl Tracker for ChromeTracker {
    fn is_enabled(&self) -> bool {
        true
    }

    fn begin(
        &self,
        name: &'static str,
        parent: SpanId,
        remote_parent: SpanId,
        now_ns: u64,
    ) -> SpanId {
        // relaxed: monotone id counter — uniqueness is all that matters.
        let id = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        let mut inner = self.guard();
        let tid = inner.open.get(&parent).map(|p| p.tid).unwrap_or(id);
        inner.open.insert(
            id,
            Open { name, parent, remote_parent, start_ns: now_ns, tid, args: Vec::new() },
        );
        id
    }

    fn end(&self, span: SpanId, now_ns: u64) {
        let mut inner = self.guard();
        if let Some(s) = inner.open.remove(&span) {
            let mut args = vec![
                ("span".to_string(), Json::Num(span as f64)),
                ("parent".to_string(), Json::Num(s.parent as f64)),
            ];
            if s.remote_parent != 0 {
                args.push(("remote_parent".to_string(), Json::Num(s.remote_parent as f64)));
            }
            args.extend(s.args);
            let args_obj =
                Json::obj(args.iter().map(|(k, v)| (k.as_str(), v.clone())).collect());
            inner.done.push(Json::obj(vec![
                ("name", Json::Str(s.name.to_string())),
                ("cat", Json::Str("mrtuner".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(s.start_ns as f64 / 1e3)),
                ("dur", Json::Num(now_ns.saturating_sub(s.start_ns) as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.tid as f64)),
                ("args", args_obj),
            ]));
        }
    }

    fn event(&self, span: SpanId, name: &'static str, value: u64, _now_ns: u64) {
        let mut inner = self.guard();
        if let Some(s) = inner.open.get_mut(&span) {
            s.args.push((name.to_string(), Json::Num(value as f64)));
        }
    }

    fn note(&self, span: SpanId, key: &'static str, text: &str, _now_ns: u64) {
        let mut inner = self.guard();
        if let Some(s) = inner.open.get_mut(&span) {
            s.args.push((key.to_string(), Json::Str(text.to_string())));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_complete_events_with_nested_track_ids() {
        let t = ChromeTracker::new();
        let root = t.begin("request", 0, 0, 2_000);
        let child = t.begin("cascade", root, 0, 3_000);
        t.event(child, "candidates", 24, 3_100);
        t.note(child, "config", "M=2", 3_200);
        t.end(child, 5_000);
        t.end(root, 6_000);
        assert_eq!(t.len(), 2);

        let doc = t.to_json();
        let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(events.len(), 2);
        // `cascade` finished first, so it is events[0].
        let cascade = &events[0];
        assert_eq!(cascade.get("name").and_then(Json::as_str), Some("cascade"));
        assert_eq!(cascade.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(cascade.get("ts").and_then(Json::as_f64), Some(3.0));
        assert_eq!(cascade.get("dur").and_then(Json::as_f64), Some(2.0));
        // Child inherits the root's track id.
        let request = &events[1];
        assert_eq!(cascade.get("tid").and_then(Json::as_f64), request.get("tid").and_then(Json::as_f64));
        let args = cascade.get("args").expect("args");
        assert_eq!(args.get("candidates").and_then(Json::as_f64), Some(24.0));
        assert_eq!(args.get("config").and_then(Json::as_str), Some("M=2"));
    }

    #[test]
    fn remote_parent_appears_in_args() {
        let t = ChromeTracker::new();
        let id = t.begin("request", 0, 41, 0);
        t.end(id, 100);
        let doc = t.to_json();
        let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(
            events[0].get("args").and_then(|a| a.get("remote_parent")).and_then(Json::as_f64),
            Some(41.0)
        );
    }

    #[test]
    fn writes_a_parseable_file() {
        let t = ChromeTracker::new();
        let id = t.begin("request", 0, 0, 0);
        t.end(id, 1_000);
        let path = std::env::temp_dir().join("mrtuner_chrome_trace_test.json");
        t.write_to(&path).expect("write trace");
        let text = std::fs::read_to_string(&path).expect("read back");
        let doc = Json::parse(&text).expect("valid json");
        assert!(doc.get("traceEvents").and_then(Json::as_arr).is_some());
        std::fs::remove_file(&path).ok();
    }
}
