//! Profile entries: one captured CPU-utilization pattern per
//! (application, configuration-set) pair — the rows of the paper's
//! reference database (Figure 3a, step 6).

use crate::simulator::job::JobConfig;
use crate::util::json::Json;
use crate::workloads::AppId;
use anyhow::{anyhow, Result};

/// One profiled run.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileEntry {
    pub app: AppId,
    pub config: JobConfig,
    /// De-noised, normalized CPU series (the paper stores post-filter).
    pub series: Vec<f64>,
    /// Length of the raw 1 Hz capture before any resampling.
    pub raw_len: usize,
    /// Simulated job completion time (used by the tuner).
    pub completion_secs: f64,
}

impl ProfileEntry {
    /// Key used to pair entries across applications: the config label.
    pub fn config_key(&self) -> String {
        self.config.label()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app.name().to_string())),
            ("mappers", Json::Num(self.config.mappers as f64)),
            ("reducers", Json::Num(self.config.reducers as f64)),
            ("split_mb", Json::Num(self.config.split_mb)),
            ("input_mb", Json::Num(self.config.input_mb)),
            ("raw_len", Json::Num(self.raw_len as f64)),
            ("completion_secs", Json::Num(self.completion_secs)),
            ("series", Json::nums(&self.series)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ProfileEntry> {
        let app = v
            .get("app")
            .and_then(Json::as_str)
            .and_then(AppId::from_name)
            .ok_or_else(|| anyhow!("profile entry: bad app"))?;
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("profile entry: missing {k}"))
        };
        let series = v
            .get("series")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("profile entry: missing series"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect::<Vec<_>>();
        Ok(ProfileEntry {
            app,
            config: JobConfig::new(
                num("mappers")? as usize,
                num("reducers")? as usize,
                num("split_mb")?,
                num("input_mb")?,
            ),
            series,
            raw_len: num("raw_len")? as usize,
            completion_secs: num("completion_secs")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ProfileEntry {
        ProfileEntry {
            app: AppId::WordCount,
            config: JobConfig::new(11, 6, 20.0, 30.0),
            series: vec![0.1, 0.9, 0.5],
            raw_len: 3,
            completion_secs: 123.5,
        }
    }

    #[test]
    fn json_roundtrip() {
        let e = sample();
        let back = ProfileEntry::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn config_key_is_label() {
        assert_eq!(sample().config_key(), "M=11,R=6,FS=20M,I=30M");
    }

    #[test]
    fn rejects_malformed() {
        let v = Json::parse(r#"{"app":"nosuch","series":[]}"#).unwrap();
        assert!(ProfileEntry::from_json(&v).is_err());
    }
}
