//! [`TextTracker`]: human-readable indented span log to any `Write` sink
//! (stderr, a file, a `Vec<u8>` in tests).

use super::{SpanId, Tracker};
use std::collections::HashMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

struct OpenSpan {
    name: &'static str,
    depth: usize,
    start_ns: u64,
}

struct Inner {
    sink: Box<dyn Write + Send>,
    open: HashMap<SpanId, OpenSpan>,
}

/// Streams an indented begin/end line per span plus one line per
/// event/note. Output is best-effort: a full or broken sink never panics
/// the traced request.
pub struct TextTracker {
    next: AtomicU64,
    inner: Mutex<Inner>,
}

impl TextTracker {
    pub fn new(sink: Box<dyn Write + Send>) -> TextTracker {
        TextTracker {
            next: AtomicU64::new(0),
            inner: Mutex::new(Inner { sink, open: HashMap::new() }),
        }
    }

    /// Convenience: log to stderr.
    pub fn stderr() -> TextTracker {
        TextTracker::new(Box::new(std::io::stderr()))
    }

    fn with_inner(&self, f: impl FnOnce(&mut Inner)) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut g);
    }
}

impl std::fmt::Debug for TextTracker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TextTracker").finish_non_exhaustive()
    }
}

fn indent(depth: usize) -> String {
    "  ".repeat(depth)
}

impl Tracker for TextTracker {
    fn is_enabled(&self) -> bool {
        true
    }

    fn begin(
        &self,
        name: &'static str,
        parent: SpanId,
        remote_parent: SpanId,
        now_ns: u64,
    ) -> SpanId {
        // relaxed: monotone id counter — uniqueness is all that matters.
        let id = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        self.with_inner(|inner| {
            let depth = inner.open.get(&parent).map(|p| p.depth + 1).unwrap_or(0);
            let link = if remote_parent != 0 {
                format!(" remote_parent={remote_parent}")
            } else {
                String::new()
            };
            let _ = writeln!(inner.sink, "{}> {name} [{id}]{link}", indent(depth));
            inner.open.insert(id, OpenSpan { name, depth, start_ns: now_ns });
        });
        id
    }

    fn end(&self, span: SpanId, now_ns: u64) {
        self.with_inner(|inner| {
            if let Some(s) = inner.open.remove(&span) {
                let us = now_ns.saturating_sub(s.start_ns) as f64 / 1e3;
                let _ =
                    writeln!(inner.sink, "{}< {} [{span}] {us:.1}us", indent(s.depth), s.name);
                let _ = inner.sink.flush();
            }
        });
    }

    fn event(&self, span: SpanId, name: &'static str, value: u64, _now_ns: u64) {
        self.with_inner(|inner| {
            if let Some(s) = inner.open.get(&span) {
                let _ = writeln!(inner.sink, "{}* {name}={value}", indent(s.depth + 1));
            }
        });
    }

    fn note(&self, span: SpanId, key: &'static str, text: &str, _now_ns: u64) {
        self.with_inner(|inner| {
            if let Some(s) = inner.open.get(&span) {
                let _ = writeln!(inner.sink, "{}* {key}={text:?}", indent(s.depth + 1));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// A `Write` sink the test can read back after the tracker took it.
    #[derive(Clone, Default)]
    struct Shared(Arc<Mutex<Vec<u8>>>);

    impl Write for Shared {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().expect("test sink").extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn renders_indented_tree_with_events() {
        let sink = Shared::default();
        let t = TextTracker::new(Box::new(sink.clone()));
        let root = t.begin("request", 0, 0, 0);
        let child = t.begin("cascade", root, 0, 1_000);
        t.event(child, "candidates", 24, 1_500);
        t.end(child, 3_000);
        t.end(root, 4_000);

        let bytes = sink.0.lock().expect("test sink").clone();
        let out = String::from_utf8(bytes).expect("utf8 log");
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines[0], "> request [1]");
        assert_eq!(lines[1], "  > cascade [2]");
        assert_eq!(lines[2], "    * candidates=24");
        assert_eq!(lines[3], "  < cascade [2] 2.0us");
        assert_eq!(lines[4], "< request [1] 4.0us");
    }

    #[test]
    fn remote_parent_is_printed_on_the_begin_line() {
        let sink = Shared::default();
        let t = TextTracker::new(Box::new(sink.clone()));
        let id = t.begin("request", 0, 99, 0);
        t.end(id, 10);
        let bytes = sink.0.lock().expect("test sink").clone();
        let out = String::from_utf8(bytes).expect("utf8 log");
        assert!(out.contains("remote_parent=99"), "{out}");
    }
}
