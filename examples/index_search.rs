//! Index quickstart: wrap a profiled reference database in the
//! lower-bound-cascade similarity index, run exact k-NN queries, and
//! persist the envelope cache alongside the JSON store.
//!
//! Run with: `cargo run --release --example index_search`

use mrtuner::coordinator::batcher::prepare_query;
use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::prelude::*;
use mrtuner::simulator::engine::simulate;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::workload_for;

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::small(1);
    let sc = SystemConfig {
        use_runtime: false,
        ..SystemConfig::default()
    };

    // Profile two reference applications and index the database: the
    // envelope cache is built once per entry, on insert.
    let p = Profiler::new(&sc, None);
    let mut idx = IndexedDb::new();
    for app in [AppId::WordCount, AppId::TeraSort] {
        for entry in p.profile(app, &grid) {
            idx.insert(entry);
        }
    }
    println!("indexed {} reference entries", idx.len());

    // An "unknown" raw capture: Exim under the first configuration set.
    // `prepare_query` applies the same cap + de-noise + normalize the
    // stored references went through.
    let cfg = grid.configs[0];
    let workload = workload_for(AppId::EximParse);
    let sim = simulate(
        workload.as_ref(),
        &cfg,
        &sc.cluster,
        &sc.noise,
        &mut Rng::new(0xA5),
    );
    let query = prepare_query(&sim.cpu_noisy);

    // Exact nearest neighbours under the banded-DTW distance — same
    // entries a brute-force scan would return, found with most candidates
    // pruned by the LB_Kim -> LB_PAA -> LB_Keogh cascade.
    let (neighbors, stats) = idx.knn(&query, 3);
    println!("\ntop-3 nearest references (whole DB):");
    for nb in &neighbors {
        let e = &idx.entries()[nb.index];
        println!(
            "  {:12} {:24} distance {:8.3}",
            e.app.name(),
            e.config.label(),
            nb.distance
        );
    }
    println!("search: {stats}");

    // The matching phase only compares same-config patterns; the index
    // keeps a config bucket for exactly that.
    let (bucket, _) = idx.knn_in_config(&query, &cfg.label(), 1);
    let best = &idx.entries()[bucket[0].index];
    println!(
        "\nnearest same-config reference: {} (distance {:.3})",
        best.app.name(),
        bucket[0].distance
    );

    // Persistence: the envelope cache rides alongside the JSON store and
    // is reused on load (rebuilt automatically if stale).
    let path = std::env::temp_dir().join("mrtuner_index_quickstart.json");
    idx.save(&path).expect("save store + envelope sidecar");
    let restored = IndexedDb::load(&path).expect("load store + sidecar");
    let (again, _) = restored.knn(&query, 3);
    assert_eq!(again[0].index, neighbors[0].index);
    assert!((again[0].distance - neighbors[0].distance).abs() < 1e-9);
    println!(
        "\nsaved + reloaded via {} — identical neighbours",
        path.display()
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(IndexedDb::envelope_path(&path)).ok();
}
