//! Exact k-nearest-neighbour search with the lower-bound cascade.
//!
//! [`knn`] returns the same neighbours (same indices, same distances) as
//! [`brute_force_knn`] over the same candidates — the cascade only ever
//! skips candidates that provably cannot enter the result. Ties on
//! distance resolve to the lower candidate id, exactly like the linear
//! scan, so the two are interchangeable in tests.

use super::envelope::Envelope;
use super::lb::{lb_keogh, lb_kim, lb_paa, query_extrema};
use super::{SearchStats, DEFAULT_BLOCK};
use crate::dtw::banded::dtw_banded_distance_cutoff;
use crate::dtw::band_radius;

/// One search result: candidate id (position in the candidate set / the
/// database) and its exact banded-DTW distance to the query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub index: usize,
    pub distance: f64,
}

/// Queries shorter than this skip the PAA stage — the O(n) Keogh bound is
/// already nearly free there.
const PAA_MIN_LEN: usize = 64;

/// Absolute + relative slack added to the best-so-far cutoff so f64
/// rounding in the (mathematically admissible) bounds can never prune a
/// true neighbour.
fn cutoff(bsf: f64) -> f64 {
    if bsf.is_finite() {
        bsf + 1e-9 * (1.0 + bsf.abs())
    } else {
        bsf
    }
}

/// Insert into a (distance, index)-sorted top-k list; a linear scan that
/// updates on strict improvement keeps exactly the same set.
fn push_neighbor(best: &mut Vec<Neighbor>, k: usize, nb: Neighbor) {
    let pos = best
        .partition_point(|b| (b.distance, b.index) <= (nb.distance, nb.index));
    if pos < k {
        best.insert(pos, nb);
        best.truncate(k);
    }
}

/// Exact top-`k` under banded DTW via the pruning cascade
/// (LB_Kim → LB_PAA → LB_Keogh → early-abandoning DP). Candidates are
/// `(id, series, envelope)`; empty series are skipped.
pub fn knn<'a>(
    query: &[f64],
    candidates: impl IntoIterator<Item = (usize, &'a [f64], &'a Envelope)>,
    k: usize,
) -> (Vec<Neighbor>, SearchStats) {
    let mut stats = SearchStats::default();
    let mut best: Vec<Neighbor> = Vec::new();
    if k == 0 || query.is_empty() {
        return (best, stats);
    }
    let n = query.len();
    // The PAA stage is skipped for short queries, so don't pay its
    // query-side summary there either.
    let qext = if n >= PAA_MIN_LEN {
        query_extrema(query, DEFAULT_BLOCK)
    } else {
        Vec::new()
    };

    for (index, series, env) in candidates {
        if series.is_empty() {
            continue;
        }
        debug_assert_eq!(env.len(), series.len(), "envelope out of sync");
        stats.candidates += 1;
        let bsf = if best.len() == k {
            best[k - 1].distance
        } else {
            f64::INFINITY
        };
        let cut = cutoff(bsf);

        if lb_kim(query, series) > cut {
            stats.pruned_lb_kim += 1;
            continue;
        }
        let r = band_radius(n, series.len());
        if n >= PAA_MIN_LEN && lb_paa(&qext, n, DEFAULT_BLOCK, env, r) > cut {
            stats.pruned_lb_paa += 1;
            continue;
        }
        if lb_keogh(query, env, r) > cut {
            stats.pruned_lb_keogh += 1;
            continue;
        }
        match dtw_banded_distance_cutoff(query, series, r, cut) {
            None => stats.abandoned += 1,
            Some(distance) => {
                stats.dtw_evals += 1;
                push_neighbor(&mut best, k, Neighbor { index, distance });
            }
        }
    }
    (best, stats)
}

/// Reference implementation: evaluate the banded DTW on every candidate.
/// Same result contract as [`knn`]; used by the property tests and the
/// `index_perf` bench as the baseline.
pub fn brute_force_knn<'a>(
    query: &[f64],
    candidates: impl IntoIterator<Item = (usize, &'a [f64])>,
    k: usize,
) -> Vec<Neighbor> {
    let mut best: Vec<Neighbor> = Vec::new();
    if k == 0 || query.is_empty() {
        return best;
    }
    for (index, series) in candidates {
        if series.is_empty() {
            continue;
        }
        let r = band_radius(query.len(), series.len());
        let distance = dtw_banded_distance_cutoff(query, series, r, f64::INFINITY)
            .expect("infinite cutoff never abandons");
        push_neighbor(&mut best, k, Neighbor { index, distance });
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.25).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    fn corpus(g: &mut Pcg32, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| series(g, 40 + g.below(160) as usize)).collect()
    }

    fn with_envelopes(corpus: &[Vec<f64>]) -> Vec<Envelope> {
        corpus.iter().map(|s| Envelope::build(s, DEFAULT_BLOCK)).collect()
    }

    #[test]
    fn knn_matches_brute_force_exactly() {
        let mut g = Pcg32::new(60, 1);
        for round in 0..8 {
            let refs = corpus(&mut g, 30);
            let envs = with_envelopes(&refs);
            let q = series(&mut g, 30 + g.below(200) as usize);
            for k in [1usize, 3, 7] {
                let (fast, stats) = knn(
                    &q,
                    refs.iter()
                        .zip(&envs)
                        .enumerate()
                        .map(|(i, (s, e))| (i, s.as_slice(), e)),
                    k,
                );
                let slow =
                    brute_force_knn(&q, refs.iter().enumerate().map(|(i, s)| (i, s.as_slice())), k);
                assert_eq!(fast.len(), slow.len());
                for (a, b) in fast.iter().zip(&slow) {
                    assert_eq!(a.index, b.index, "round {round} k={k}");
                    assert_eq!(
                        a.distance.to_bits(),
                        b.distance.to_bits(),
                        "round {round} k={k}: {} vs {}",
                        a.distance,
                        b.distance
                    );
                }
                assert_eq!(stats.candidates, 30);
                assert_eq!(stats.pruned() + stats.dtw_started(), stats.candidates);
            }
        }
    }

    #[test]
    fn self_neighbour_is_found_with_distance_zero() {
        let mut g = Pcg32::new(61, 2);
        let refs = corpus(&mut g, 20);
        let envs = with_envelopes(&refs);
        let q = refs[13].clone();
        let (top, _) = knn(
            &q,
            refs.iter()
                .zip(&envs)
                .enumerate()
                .map(|(i, (s, e))| (i, s.as_slice(), e)),
            1,
        );
        assert_eq!(top[0].index, 13);
        assert_eq!(top[0].distance, 0.0);
    }

    #[test]
    fn pruning_actually_happens_on_a_spread_corpus() {
        // Corpus of well-separated constant levels: once the first close
        // candidate is seen, the far levels must die in the bounds.
        let refs: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![(i % 10) as f64 / 10.0; 128])
            .collect();
        let envs = with_envelopes(&refs);
        let q = vec![0.02_f64; 128];
        let (top, stats) = knn(
            &q,
            refs.iter()
                .zip(&envs)
                .enumerate()
                .map(|(i, (s, e))| (i, s.as_slice(), e)),
            1,
        );
        assert_eq!(top[0].index, 0, "level 0.0 is closest to 0.02");
        assert!(
            stats.pruned() + stats.abandoned > stats.candidates / 2,
            "no pruning on an easy corpus: {stats}"
        );
    }

    #[test]
    fn degenerate_inputs() {
        let refs: Vec<Vec<f64>> = vec![vec![0.5; 10], Vec::new()];
        let envs = with_envelopes(&refs);
        let cands = || {
            refs.iter()
                .zip(&envs)
                .enumerate()
                .map(|(i, (s, e))| (i, s.as_slice(), e))
        };
        let (empty_k, _) = knn(&[0.1, 0.2], cands(), 0);
        assert!(empty_k.is_empty());
        let (empty_q, _) = knn(&[], cands(), 3);
        assert!(empty_q.is_empty());
        // Empty candidate series is skipped, not an error.
        let (top, stats) = knn(&[0.1, 0.2, 0.3], cands(), 5);
        assert_eq!(top.len(), 1);
        assert_eq!(stats.candidates, 1);
        assert!(brute_force_knn(&[0.5], refs.iter().enumerate().map(|(i, s)| (i, s.as_slice())), 2).len() == 1);
    }
}
