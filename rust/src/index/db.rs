//! [`IndexedDb`]: a [`ReferenceDb`] plus the envelope cache and a
//! config-set secondary index, kept in sync on every insert and persisted
//! alongside the JSON store.
//!
//! The wrapper owns the database: mutations go through
//! [`IndexedDb::insert`] (which rebuilds exactly the envelope of the
//! replaced/added entry) so the cache can never go stale. Loading reuses a
//! previously saved sidecar when it still matches the store and silently
//! rebuilds otherwise — the cache is derived data, never authoritative.

use super::envelope::Envelope;
use super::knn::{brute_force_knn, knn, knn_batch, knn_parallel, Neighbor};
use super::{SearchStats, DEFAULT_BLOCK};
use crate::database::profile::ProfileEntry;
use crate::database::store::{OptimalConfig, ReferenceDb};
use crate::trace::Span;
use crate::util::json::Json;
use crate::workloads::AppId;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Render one search's cascade breakdown as spans under `parent`: a
/// `cascade` child carrying the candidate count, with one child per
/// pruning stage (`lb_kim` / `lb_paa` / `lb_keogh`) and a `dp` child for
/// the dynamic program. The spans are synthesized *after* the search from
/// its [`SearchStats`] — the hot loop never sees the tracker, so a
/// disabled tracer costs nothing here beyond one branch.
fn record_cascade(parent: &Span, stats: &SearchStats) {
    if !parent.active() {
        return;
    }
    let cascade = parent.child("cascade");
    cascade.event("candidates", stats.candidates);
    {
        let s = cascade.child("lb_kim");
        s.event("pruned", stats.pruned_lb_kim);
    }
    {
        let s = cascade.child("lb_paa");
        s.event("pruned", stats.pruned_lb_paa);
    }
    {
        let s = cascade.child("lb_keogh");
        s.event("pruned", stats.pruned_lb_keogh);
    }
    {
        let s = cascade.child("dp");
        s.event("evals", stats.dtw_evals);
        s.event("abandoned", stats.abandoned);
    }
}

/// [`record_cascade`] over a batch: one merged cascade breakdown for the
/// whole batch (per-query spans would drown the trace in small batches'
/// worth of identical stages).
fn record_cascade_batch(parent: &Span, results: &[(Vec<Neighbor>, SearchStats)]) {
    if !parent.active() {
        return;
    }
    let mut merged = SearchStats::default();
    for (_, stats) in results {
        merged.merge(stats);
    }
    record_cascade(parent, &merged);
}

/// Reference database with an always-in-sync similarity index.
#[derive(Debug, Default)]
pub struct IndexedDb {
    db: ReferenceDb,
    /// Parallel to `db.entries()`.
    envelopes: Vec<Envelope>,
    /// Config label → entry positions (the matching phase only compares
    /// same-config patterns, so searches are usually over one bucket).
    by_config: BTreeMap<String, Vec<usize>>,
}

impl IndexedDb {
    pub fn new() -> IndexedDb {
        IndexedDb::default()
    }

    /// Index an existing database (bulk build, O(total samples)).
    pub fn from_db(db: ReferenceDb) -> IndexedDb {
        let envelopes = db
            .entries()
            .iter()
            .map(|e| Envelope::build(&e.series, DEFAULT_BLOCK))
            .collect();
        let mut idx = IndexedDb {
            db,
            envelopes,
            by_config: BTreeMap::new(),
        };
        idx.rebuild_config_index();
        idx
    }

    fn rebuild_config_index(&mut self) {
        self.by_config.clear();
        for (i, e) in self.db.entries().iter().enumerate() {
            self.by_config.entry(e.config_key()).or_default().push(i);
        }
    }

    /// Insert a profiled run, replacing any previous entry for the same
    /// app + config set, and refresh exactly the affected envelope.
    pub fn insert(&mut self, entry: ProfileEntry) {
        let label = entry.config_key();
        let env = Envelope::build(&entry.series, DEFAULT_BLOCK);
        let replaced = self.db.insert(entry);
        if let Some(p) = replaced {
            // Mirror ReferenceDb::insert: the old entry is removed from
            // position `p`, shifting every later entry down by one.
            self.envelopes.remove(p);
            for positions in self.by_config.values_mut() {
                positions.retain(|&i| i != p);
                for i in positions.iter_mut() {
                    if *i > p {
                        *i -= 1;
                    }
                }
            }
        }
        self.envelopes.push(env);
        self.by_config
            .entry(label)
            .or_default()
            .push(self.db.len() - 1);
        debug_assert_eq!(self.envelopes.len(), self.db.len());
    }

    /// Borrow the underlying database (read-only; inserts must go through
    /// the wrapper so the cache stays coherent).
    pub fn db(&self) -> &ReferenceDb {
        &self.db
    }

    /// Unwrap, dropping the index.
    pub fn into_db(self) -> ReferenceDb {
        self.db
    }

    pub fn len(&self) -> usize {
        self.db.len()
    }

    pub fn is_empty(&self) -> bool {
        self.db.is_empty()
    }

    pub fn entries(&self) -> &[ProfileEntry] {
        self.db.entries()
    }

    pub fn apps(&self) -> Vec<AppId> {
        self.db.apps()
    }

    pub fn by_config(&self, key: &str) -> Vec<&ProfileEntry> {
        self.db.by_config(key)
    }

    /// Record an optimal configuration (does not touch pattern entries, so
    /// no cache maintenance is needed).
    pub fn set_optimal(&mut self, app: AppId, best: OptimalConfig) {
        self.db.set_optimal(app, best);
    }

    pub fn optimal(&self, app: AppId) -> Option<&OptimalConfig> {
        self.db.optimal(app)
    }

    /// The cached envelope of entry `i`.
    pub fn envelope(&self, i: usize) -> &Envelope {
        &self.envelopes[i]
    }

    /// Entry positions stored under a config label (empty if none).
    pub fn config_positions(&self, label: &str) -> &[usize] {
        self.by_config.get(label).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Every configuration-set label with at least one entry, sorted —
    /// what a shard advertises through the `shard_info` command.
    pub fn config_labels(&self) -> Vec<String> {
        self.by_config.keys().cloned().collect()
    }

    /// Exact top-`k` nearest entries (banded-DTW distance) over the whole
    /// database. `query` must already be preprocessed like stored series
    /// (see `coordinator::batcher::prepare_query`).
    pub fn knn(&self, query: &[f64], k: usize) -> (Vec<Neighbor>, SearchStats) {
        let entries = self.db.entries();
        knn(
            query,
            (0..entries.len()).map(|i| (i, entries[i].series.as_slice(), &self.envelopes[i])),
            k,
        )
    }

    /// Exact top-`k` restricted to entries captured under one config set —
    /// the matching phase's per-configuration search.
    pub fn knn_in_config(&self, query: &[f64], label: &str, k: usize) -> (Vec<Neighbor>, SearchStats) {
        let entries = self.db.entries();
        knn(
            query,
            self.config_positions(label)
                .iter()
                .map(|&i| (i, entries[i].series.as_slice(), &self.envelopes[i])),
            k,
        )
    }

    /// All entries as `(position, series, envelope)` candidate triples.
    fn all_candidates(&self) -> Vec<(usize, &[f64], &Envelope)> {
        let entries = self.db.entries();
        (0..entries.len())
            .map(|i| (i, entries[i].series.as_slice(), &self.envelopes[i]))
            .collect()
    }

    /// One config bucket as candidate triples.
    fn config_candidates(&self, label: &str) -> Vec<(usize, &[f64], &Envelope)> {
        let entries = self.db.entries();
        self.config_positions(label)
            .iter()
            .map(|&i| (i, entries[i].series.as_slice(), &self.envelopes[i]))
            .collect()
    }

    /// Exact top-`k` over the whole database, scored across `workers`
    /// threads with a shared early-abandoning cutoff — same result as
    /// [`IndexedDb::knn`], bit for bit (see
    /// [`crate::index::knn::knn_parallel`]).
    pub fn knn_parallel(
        &self,
        query: &[f64],
        k: usize,
        workers: usize,
    ) -> (Vec<Neighbor>, SearchStats) {
        knn_parallel(query, &self.all_candidates(), k, workers)
    }

    /// Exact top-`k` for a whole batch of queries in one entry-major pass
    /// over the database: same-length queries share one envelope pass per
    /// reference entry. Each query's result (neighbours *and* counters)
    /// is identical to [`IndexedDb::knn`] on that query alone.
    pub fn knn_batch(&self, queries: &[&[f64]], k: usize) -> Vec<(Vec<Neighbor>, SearchStats)> {
        knn_batch(queries, &self.all_candidates(), k)
    }

    /// [`IndexedDb::knn_batch`] restricted to one config bucket — the
    /// batched form of [`IndexedDb::knn_in_config`], used by the matcher
    /// to classify several unknown apps per configuration set in one pass.
    pub fn knn_batch_in_config(
        &self,
        queries: &[&[f64]],
        label: &str,
        k: usize,
    ) -> Vec<(Vec<Neighbor>, SearchStats)> {
        knn_batch(queries, &self.config_candidates(label), k)
    }

    /// [`IndexedDb::knn`] plus a post-hoc cascade-stage span breakdown
    /// under `span` (see [`record_cascade`]). Results are identical to the
    /// untraced call — tracing never touches the search itself.
    pub fn knn_traced(
        &self,
        query: &[f64],
        k: usize,
        span: &Span,
    ) -> (Vec<Neighbor>, SearchStats) {
        let out = self.knn(query, k);
        record_cascade(span, &out.1);
        out
    }

    /// [`IndexedDb::knn_in_config`] with cascade-stage spans under `span`.
    pub fn knn_in_config_traced(
        &self,
        query: &[f64],
        label: &str,
        k: usize,
        span: &Span,
    ) -> (Vec<Neighbor>, SearchStats) {
        let out = self.knn_in_config(query, label, k);
        record_cascade(span, &out.1);
        out
    }

    /// [`IndexedDb::knn_parallel`] with cascade-stage spans under `span`
    /// (one merged breakdown; per-worker attribution is not recorded).
    pub fn knn_parallel_traced(
        &self,
        query: &[f64],
        k: usize,
        workers: usize,
        span: &Span,
    ) -> (Vec<Neighbor>, SearchStats) {
        let out = self.knn_parallel(query, k, workers);
        record_cascade(span, &out.1);
        out
    }

    /// [`IndexedDb::knn_batch`] with one merged cascade breakdown for the
    /// batch under `span`.
    pub fn knn_batch_traced(
        &self,
        queries: &[&[f64]],
        k: usize,
        span: &Span,
    ) -> Vec<(Vec<Neighbor>, SearchStats)> {
        let results = self.knn_batch(queries, k);
        record_cascade_batch(span, &results);
        results
    }

    /// [`IndexedDb::knn_batch_in_config`] with one merged cascade
    /// breakdown for the batch under `span`.
    pub fn knn_batch_in_config_traced(
        &self,
        queries: &[&[f64]],
        label: &str,
        k: usize,
        span: &Span,
    ) -> Vec<(Vec<Neighbor>, SearchStats)> {
        let results = self.knn_batch_in_config(queries, label, k);
        record_cascade_batch(span, &results);
        results
    }

    /// Brute-force baseline over the whole database (same contract as
    /// [`IndexedDb::knn`]; evaluates every candidate).
    pub fn brute_force(&self, query: &[f64], k: usize) -> Vec<Neighbor> {
        let entries = self.db.entries();
        brute_force_knn(
            query,
            (0..entries.len()).map(|i| (i, entries[i].series.as_slice())),
            k,
        )
    }

    /// Sidecar path for the envelope cache of a store at `path`
    /// (`db.json` → `db.envelopes.json`).
    pub fn envelope_path(path: &Path) -> PathBuf {
        path.with_extension("envelopes.json")
    }

    /// Persist the store (same JSON format as [`ReferenceDb::save`]) plus
    /// the envelope cache sidecar.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.db.save(path)?;
        let entries = self
            .db
            .entries()
            .iter()
            .zip(&self.envelopes)
            .map(|(e, env)| {
                Json::obj(vec![
                    ("app", Json::Str(e.app.name().to_string())),
                    ("config", Json::Str(e.config_key())),
                    ("envelope", env.to_json()),
                ])
            })
            .collect();
        let sidecar = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("entries", Json::arr(entries)),
        ]);
        let sp = Self::envelope_path(path);
        std::fs::write(&sp, sidecar.to_pretty())
            .with_context(|| format!("writing {}", sp.display()))
    }

    /// Load a store and its envelope cache; if the sidecar is missing,
    /// unreadable or stale (entry mismatch), the cache is rebuilt from the
    /// series — envelopes are derived data.
    pub fn load(path: &Path) -> Result<IndexedDb> {
        let db = ReferenceDb::load(path)?;
        match Self::load_envelopes(&db, &Self::envelope_path(path)) {
            Some(envelopes) => {
                let mut idx = IndexedDb {
                    db,
                    envelopes,
                    by_config: BTreeMap::new(),
                };
                idx.rebuild_config_index();
                Ok(idx)
            }
            None => {
                log::info!(
                    "index: envelope sidecar missing or stale for {}; rebuilding",
                    path.display()
                );
                Ok(IndexedDb::from_db(db))
            }
        }
    }

    fn load_envelopes(db: &ReferenceDb, sidecar: &Path) -> Option<Vec<Envelope>> {
        let text = std::fs::read_to_string(sidecar).ok()?;
        let v = Json::parse(&text).ok()?;
        let items = v.get("entries").and_then(Json::as_arr)?;
        if items.len() != db.len() {
            return None;
        }
        let mut envelopes = Vec::with_capacity(items.len());
        for (item, entry) in items.iter().zip(db.entries()) {
            let app = item.get("app").and_then(Json::as_str)?;
            let config = item.get("config").and_then(Json::as_str)?;
            if app != entry.app.name() || config != entry.config_key() {
                return None;
            }
            let env = Envelope::from_json(item.get("envelope")?).ok()?;
            // Containment, not just shape: a sidecar left over from an
            // equal-length re-profile would pass the length check but could
            // over-estimate and silently prune true neighbours. A containing
            // envelope can only ever be loose, which keeps k-NN exact.
            if !env.contains(&entry.series) {
                return None;
            }
            envelopes.push(env);
        }
        Some(envelopes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::job::JobConfig;
    use crate::util::rng::Pcg32;

    fn entry(app: AppId, mappers: usize, g: &mut Pcg32) -> ProfileEntry {
        let len = 40 + g.below(120) as usize;
        let mut v = 0.5;
        let series = (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.2).clamp(0.0, 1.0);
                v
            })
            .collect();
        ProfileEntry {
            app,
            config: JobConfig::new(mappers, 2, 10.0, 20.0),
            series,
            raw_len: len,
            completion_secs: 10.0,
        }
    }

    fn build(g: &mut Pcg32) -> IndexedDb {
        let mut idx = IndexedDb::new();
        for m in 1..=12 {
            idx.insert(entry(AppId::WordCount, m, g));
            idx.insert(entry(AppId::TeraSort, m, g));
        }
        idx
    }

    #[test]
    fn insert_keeps_cache_in_sync() {
        let mut g = Pcg32::new(70, 1);
        let mut idx = build(&mut g);
        assert_eq!(idx.len(), 24);
        // Replace an early entry: envelopes and config buckets must follow.
        idx.insert(entry(AppId::WordCount, 3, &mut g));
        assert_eq!(idx.len(), 24);
        for (i, e) in idx.entries().iter().enumerate() {
            assert_eq!(idx.envelope(i).len(), e.series.len(), "envelope {i} stale");
        }
        for (label, positions) in &idx.by_config {
            for &p in positions {
                assert_eq!(&idx.entries()[p].config_key(), label);
            }
        }
        let bucket = idx.config_positions("M=3,R=2,FS=10M,I=20M");
        assert_eq!(bucket.len(), 2, "one entry per app in the bucket");
    }

    #[test]
    fn knn_in_config_only_sees_the_bucket() {
        let mut g = Pcg32::new(71, 2);
        let idx = build(&mut g);
        let q = idx.entries()[idx.config_positions("M=5,R=2,FS=10M,I=20M")[0]]
            .series
            .clone();
        let (top, stats) = idx.knn_in_config(&q, "M=5,R=2,FS=10M,I=20M", 2);
        assert_eq!(stats.candidates, 2);
        assert_eq!(top[0].distance, 0.0, "self entry is in the bucket");
        let (_, all_stats) = idx.knn(&q, 2);
        assert_eq!(all_stats.candidates, 24);
        let (none, none_stats) = idx.knn_in_config(&q, "M=99,R=9,FS=1M,I=1M", 2);
        assert!(none.is_empty());
        assert_eq!(none_stats.candidates, 0);
    }

    #[test]
    fn knn_agrees_with_brute_force_through_the_wrapper() {
        let mut g = Pcg32::new(72, 3);
        let idx = build(&mut g);
        let probe = entry(AppId::Grep, 99, &mut g);
        let (fast, _) = idx.knn(&probe.series, 3);
        let slow = idx.brute_force(&probe.series, 3);
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
        }
    }

    #[test]
    fn save_load_roundtrip_with_sidecar() {
        let mut g = Pcg32::new(73, 4);
        let idx = build(&mut g);
        let path = std::env::temp_dir().join("mrtuner_indexed_db_test.json");
        idx.save(&path).unwrap();
        assert!(IndexedDb::envelope_path(&path).exists());

        let back = IndexedDb::load(&path).unwrap();
        assert_eq!(back.len(), idx.len());
        for i in 0..idx.len() {
            // JSON number formatting may perturb the last ulp, so compare
            // with tolerance, not bitwise.
            assert_eq!(back.envelope(i).len(), idx.envelope(i).len());
            for ((al, ah), (bl, bh)) in idx
                .envelope(i)
                .extrema()
                .into_iter()
                .zip(back.envelope(i).extrema())
            {
                assert!((al - bl).abs() < 1e-9 && (ah - bh).abs() < 1e-9);
            }
        }
        // Same query, same neighbours after the round trip (distances may
        // move by formatting ulps; the entries must not).
        let q = idx.entries()[7].series.clone();
        let (a, _) = idx.knn(&q, 3);
        let (b, _) = back.knn(&q, 3);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert!((x.distance - y.distance).abs() < 1e-9);
        }

        // A stale sidecar (entry count mismatch) is ignored, not an error.
        let mut bigger = IndexedDb::load(&path).unwrap();
        bigger.insert(entry(AppId::Grep, 40, &mut g));
        bigger.db().save(&path).unwrap(); // store only; sidecar now stale
        let rebuilt = IndexedDb::load(&path).unwrap();
        assert_eq!(rebuilt.len(), 25);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(IndexedDb::envelope_path(&path)).ok();
    }
}
