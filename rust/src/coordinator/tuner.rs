//! Self-tuning (the paper's motivation, §1/§3.1.3): once the matcher has
//! identified the most similar reference application, reuse that
//! application's known-optimal configuration values for the new one.

use super::matcher::MatchOutcome;
use super::SystemConfig;
use crate::database::store::{OptimalConfig, ReferenceDb};
use crate::signal::noise::NoiseModel;
use crate::simulator::engine::simulate;
use crate::simulator::job::JobConfig;
use crate::util::pool::par_map;
use crate::util::rng::Rng;
use crate::workloads::{workload_for, AppId};

/// Result of one self-tuning pass.
#[derive(Debug, Clone)]
pub struct TuningReport {
    pub app: AppId,
    pub matched_app: Option<AppId>,
    /// The configuration transferred from the matched app.
    pub transferred: Option<JobConfig>,
    /// Hadoop-default baseline configuration.
    pub default_config: JobConfig,
    /// Measured completion with the default configuration (sim seconds).
    pub default_secs: f64,
    /// Measured completion with the transferred configuration.
    pub tuned_secs: f64,
}

impl TuningReport {
    /// Default-time / tuned-time (>1 means the transfer helped). A
    /// non-positive tuned time cannot be folded into "no change": it means
    /// the tuned run took no measurable time at all, so the ratio is
    /// reported as infinite and callers can tell the two cases apart.
    pub fn speedup(&self) -> f64 {
        if self.tuned_secs > 0.0 {
            self.default_secs / self.tuned_secs
        } else {
            f64::INFINITY
        }
    }
}

/// Grid-searches optimal configurations and transfers them.
pub struct Tuner {
    config: SystemConfig,
}

impl Tuner {
    pub fn new(config: &SystemConfig) -> Tuner {
        Tuner {
            config: config.clone(),
        }
    }

    /// Hadoop 0.20 default configuration for a given input size
    /// (`mapred.map.tasks = 2`, `mapred.reduce.tasks = 1`, 64 MB blocks).
    pub fn default_config(input_mb: f64) -> JobConfig {
        JobConfig::new(2, 1, 64.0, input_mb)
    }

    /// Completion time of `app` under `cfg` (noise-free run; the tuner
    /// measures performance, not patterns).
    pub fn measure(&self, app: AppId, cfg: &JobConfig) -> f64 {
        let workload = workload_for(app);
        let mut rng = Rng::new(self.config.seed ^ 0x7e57);
        simulate(
            workload.as_ref(),
            cfg,
            &self.config.cluster,
            &NoiseModel::none(),
            &mut rng,
        )
        .completion_secs
    }

    /// Grid-search the optimal (M, R, FS) for `app` at `input_mb` — the
    /// expensive procedure the paper's approach amortizes: run it once per
    /// *reference* app, then transfer to matched apps for free.
    pub fn find_optimal(&self, app: AppId, input_mb: f64) -> OptimalConfig {
        let mut candidates = Vec::new();
        for &m in &[2usize, 4, 8, 12, 16, 24, 32] {
            for &r in &[1usize, 2, 4, 8, 12] {
                for &fs in &[8.0f64, 16.0, 32.0, 64.0] {
                    candidates.push(JobConfig::new(m, r, fs, input_mb));
                }
            }
        }
        let times = par_map(&candidates, self.config.workers, |cfg| {
            self.measure(app, cfg)
        });
        let (best_idx, best_time) = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).expect("finite times"))
            .expect("nonempty grid");
        OptimalConfig {
            config: candidates[best_idx],
            completion_secs: *best_time,
        }
    }

    /// Full tuning flow: ensure the matched app has a cached optimal
    /// config (grid-searching if missing), transfer it to `app` and
    /// measure tuned-vs-default completion.
    pub fn tune(&self, app: AppId, outcome: &MatchOutcome, db: &mut ReferenceDb) -> TuningReport {
        // Input size for the tuned job: the median of the matched
        // profiles' inputs, or 100 MB if nothing is known.
        let input_mb = 100.0;
        let default_config = Self::default_config(input_mb);
        let default_secs = self.measure(app, &default_config);

        let Some(matched) = outcome.winner else {
            return TuningReport {
                app,
                matched_app: None,
                transferred: None,
                default_config,
                default_secs,
                tuned_secs: default_secs,
            };
        };

        if db.optimal(matched).is_none() {
            let best = self.find_optimal(matched, input_mb);
            log::info!(
                "tuner: optimal for {} = {} ({:.1}s)",
                matched.name(),
                best.config.label(),
                best.completion_secs
            );
            db.set_optimal(matched, best);
        }
        let mut transferred = db.optimal(matched).expect("just set").config;
        transferred.input_mb = input_mb;
        let tuned_secs = self.measure(app, &transferred);

        TuningReport {
            app,
            matched_app: Some(matched),
            transferred: Some(transferred),
            default_config,
            default_secs,
            tuned_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuner() -> Tuner {
        Tuner::new(&SystemConfig {
            workers: 4,
            use_runtime: false,
            ..SystemConfig::default()
        })
    }

    #[test]
    fn optimal_beats_default() {
        let t = tuner();
        let best = t.find_optimal(AppId::WordCount, 60.0);
        let default_secs = t.measure(AppId::WordCount, &Tuner::default_config(60.0));
        assert!(
            best.completion_secs < default_secs,
            "optimal {} vs default {default_secs}",
            best.completion_secs
        );
    }

    #[test]
    fn transfer_from_similar_app_helps() {
        // WordCount's optimum applied to Exim must beat Exim's default —
        // the paper's core claim.
        let t = tuner();
        let wc_best = t.find_optimal(AppId::WordCount, 60.0);
        let mut cfg = wc_best.config;
        cfg.input_mb = 60.0;
        let tuned = t.measure(AppId::EximParse, &cfg);
        let default_secs = t.measure(AppId::EximParse, &Tuner::default_config(60.0));
        assert!(
            tuned < default_secs,
            "transferred {tuned} vs default {default_secs}"
        );
    }

    #[test]
    fn no_winner_no_transfer() {
        let t = tuner();
        let outcome = MatchOutcome {
            query_app: AppId::Grep,
            cells: vec![],
            votes: vec![],
            winner: None,
            tally: Default::default(),
        };
        let mut db = ReferenceDb::new();
        let report = t.tune(AppId::Grep, &outcome, &mut db);
        assert!(report.transferred.is_none());
        assert!((report.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_tuned_time_reports_infinite_speedup() {
        // A degenerate measurement must be distinguishable from "the
        // transfer changed nothing" (speedup 1.0).
        let report = TuningReport {
            app: AppId::Grep,
            matched_app: None,
            transferred: None,
            default_config: Tuner::default_config(10.0),
            default_secs: 42.0,
            tuned_secs: 0.0,
        };
        assert_eq!(report.speedup(), f64::INFINITY);
    }

    #[test]
    fn measure_is_deterministic() {
        let t = tuner();
        let cfg = JobConfig::new(4, 2, 16.0, 40.0);
        assert_eq!(t.measure(AppId::TeraSort, &cfg), t.measure(AppId::TeraSort, &cfg));
    }
}
