//! Per-entry envelope cache: blockwise extrema of a stored series.
//!
//! An [`Envelope`] is the precomputed side of the Sakoe–Chiba lower bounds:
//! for each block of [`super::DEFAULT_BLOCK`] samples it keeps the min and
//! max of the series. [`Envelope::cover_range`] then answers "what values
//! can the reference take inside columns `[lo, hi]` of the band?" in
//! O(width/block) time using the *block-aligned cover* of the range — a
//! superset of the true range, so bounds built from it still under-estimate
//! the banded distance (they are just slightly looser than exact-range
//! envelopes would be).

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Blockwise min/max summary of one stored series.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    block: usize,
    len: usize,
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl Envelope {
    /// Summarize `series` with `block`-sample blocks (the last block may be
    /// shorter).
    pub fn build(series: &[f64], block: usize) -> Envelope {
        assert!(block > 0, "envelope: zero block size");
        let len = series.len();
        let blocks = (len + block - 1) / block;
        let mut lo = Vec::with_capacity(blocks);
        let mut hi = Vec::with_capacity(blocks);
        for chunk in series.chunks(block) {
            let mut l = f64::INFINITY;
            let mut h = f64::NEG_INFINITY;
            for &v in chunk {
                l = l.min(v);
                h = h.max(v);
            }
            lo.push(l);
            hi.push(h);
        }
        Envelope { block, len, lo, hi }
    }

    /// Length of the summarized series.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Block size in samples.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Number of blocks.
    pub fn blocks(&self) -> usize {
        self.lo.len()
    }

    /// Per-block `(min, max)` pairs.
    pub fn extrema(&self) -> Vec<(f64, f64)> {
        self.lo.iter().zip(&self.hi).map(|(&l, &h)| (l, h)).collect()
    }

    /// Whether every sample of `series` lies inside its block's interval.
    /// This is the property the lower bounds need to stay *admissible*: a
    /// containing envelope may be loose (weaker pruning) but can never
    /// over-estimate, so exactness survives. Used to vet deserialized
    /// envelopes against the store they claim to summarize.
    ///
    /// Allows ~1 ulp of slack per sample: both sides round-trip through the
    /// JSON number formatter independently. The worst-case bound overshoot
    /// this admits (series length × 1e-12) stays well inside the search's
    /// pruning-cutoff margin (1e-9 relative, `index::knn`), so k-NN remains
    /// exact.
    pub fn contains(&self, series: &[f64]) -> bool {
        if series.len() != self.len {
            return false;
        }
        series.chunks(self.block).zip(self.lo.iter().zip(&self.hi)).all(
            |(chunk, (&l, &h))| {
                chunk.iter().all(|&v| {
                    let eps = 1e-12 * (1.0 + v.abs());
                    l - eps <= v && v <= h + eps
                })
            },
        )
    }

    /// `(min, max)` of the series over the block-aligned cover of the
    /// inclusive sample range `[lo_idx, hi_idx]`. Indices are clamped to
    /// the series length.
    pub fn cover_range(&self, lo_idx: usize, hi_idx: usize) -> (f64, f64) {
        debug_assert!(!self.is_empty(), "cover_range on empty envelope");
        debug_assert!(lo_idx <= hi_idx);
        let b0 = (lo_idx / self.block).min(self.lo.len() - 1);
        let b1 = (hi_idx / self.block).min(self.lo.len() - 1);
        let mut l = f64::INFINITY;
        let mut h = f64::NEG_INFINITY;
        for b in b0..=b1 {
            l = l.min(self.lo[b]);
            h = h.max(self.hi[b]);
        }
        (l, h)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("block", Json::Num(self.block as f64)),
            ("len", Json::Num(self.len as f64)),
            ("lo", Json::nums(&self.lo)),
            ("hi", Json::nums(&self.hi)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Envelope> {
        let block = v
            .get("block")
            .and_then(Json::as_usize)
            .filter(|&b| b > 0)
            .ok_or_else(|| anyhow!("envelope: bad block"))?;
        let len = v
            .get("len")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("envelope: missing len"))?;
        let nums = |k: &str| -> Result<Vec<f64>> {
            Ok(v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("envelope: missing {k}"))?
                .iter()
                .filter_map(Json::as_f64)
                .collect())
        };
        let lo = nums("lo")?;
        let hi = nums("hi")?;
        if lo.len() != hi.len() || lo.len() != (len + block - 1) / block {
            return Err(anyhow!(
                "envelope: inconsistent shapes (len={len}, block={block}, lo={}, hi={})",
                lo.len(),
                hi.len()
            ));
        }
        Ok(Envelope { block, len, lo, hi })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn build_shapes_and_extrema() {
        let s: Vec<f64> = (0..37).map(|i| i as f64).collect();
        let e = Envelope::build(&s, 16);
        assert_eq!(e.len(), 37);
        assert_eq!(e.blocks(), 3);
        assert_eq!(e.cover_range(0, 0), (0.0, 15.0)); // block-aligned cover
        assert_eq!(e.cover_range(0, 36), (0.0, 36.0));
        assert_eq!(e.cover_range(32, 36), (32.0, 36.0));
    }

    #[test]
    fn cover_range_contains_true_range() {
        let mut g = Pcg32::new(40, 1);
        let s: Vec<f64> = (0..200).map(|_| g.f64()).collect();
        let e = Envelope::build(&s, 16);
        for _ in 0..200 {
            let a = g.below(200) as usize;
            let b = g.below(200) as usize;
            let (lo_idx, hi_idx) = (a.min(b), a.max(b));
            let (cl, ch) = e.cover_range(lo_idx, hi_idx);
            let true_min = s[lo_idx..=hi_idx].iter().cloned().fold(f64::INFINITY, f64::min);
            let true_max = s[lo_idx..=hi_idx]
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max);
            assert!(cl <= true_min && ch >= true_max, "cover not a superset");
        }
    }

    #[test]
    fn json_roundtrip() {
        let s: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).sin()).collect();
        let e = Envelope::build(&s, 16);
        let back =
            Envelope::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn malformed_json_rejected() {
        let v = Json::parse(r#"{"block":16,"len":40,"lo":[1.0],"hi":[1.0]}"#).unwrap();
        assert!(Envelope::from_json(&v).is_err(), "wrong block count accepted");
        let v = Json::parse(r#"{"len":4,"lo":[],"hi":[]}"#).unwrap();
        assert!(Envelope::from_json(&v).is_err(), "missing block accepted");
    }
}
