//! Discrete wavelet transforms (Haar and Daubechies-4).
//!
//! Implements the paper's §5 future-work proposal: replace the quadratic
//! DTW on raw series with a fixed-length vector of wavelet coefficients and
//! a plain distance, so an N-node cluster's `3N` resource series stay
//! tractable. `examples/cluster_scale.rs` evaluates this against full DTW.

/// Wavelet family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Haar,
    Db4,
}

const SQRT2_INV: f64 = std::f64::consts::FRAC_1_SQRT_2;

/// Daubechies-4 low-pass decomposition coefficients.
const DB4_LO: [f64; 4] = [
    0.48296291314469025,
    0.836516303737469,
    0.22414386804185735,
    -0.12940952255092145,
];

fn filters(family: Family) -> (Vec<f64>, Vec<f64>) {
    let lo: Vec<f64> = match family {
        Family::Haar => vec![SQRT2_INV, SQRT2_INV],
        Family::Db4 => DB4_LO.to_vec(),
    };
    // Quadrature mirror: hi[k] = (-1)^k * lo[L-1-k].
    let l = lo.len();
    let hi: Vec<f64> = (0..l)
        .map(|k| if k % 2 == 0 { lo[l - 1 - k] } else { -lo[l - 1 - k] })
        .collect();
    (lo, hi)
}

/// One analysis level with periodic (circular) extension.
/// Returns (approximation, detail), each of length `ceil(n/2)`.
pub fn dwt_level(xs: &[f64], family: Family) -> (Vec<f64>, Vec<f64>) {
    let n = xs.len();
    assert!(n >= 2, "dwt needs at least 2 samples");
    let (lo, hi) = filters(family);
    let half = n.div_ceil(2);
    let mut approx = Vec::with_capacity(half);
    let mut detail = Vec::with_capacity(half);
    for i in 0..half {
        let mut a = 0.0;
        let mut d = 0.0;
        for (k, (&l, &h)) in lo.iter().zip(hi.iter()).enumerate() {
            let idx = (2 * i + k) % n;
            a += l * xs[idx];
            d += h * xs[idx];
        }
        approx.push(a);
        detail.push(d);
    }
    (approx, detail)
}

/// Full multi-level decomposition down to `levels` (or until length < 2).
/// Output layout: `[a_L, d_L, d_{L-1}, ..., d_1]` (pywt "wavedec" order).
pub fn wavedec(xs: &[f64], family: Family, levels: usize) -> Vec<Vec<f64>> {
    let mut approx = xs.to_vec();
    let mut details: Vec<Vec<f64>> = Vec::new();
    for _ in 0..levels {
        if approx.len() < 2 {
            break;
        }
        let (a, d) = dwt_level(&approx, family);
        details.push(d);
        approx = a;
    }
    let mut out = vec![approx];
    out.extend(details.into_iter().rev());
    out
}

/// Fixed-length wavelet signature: decompose until the approximation band
/// has ≤ `m` coefficients, then zero-pad/truncate to exactly `m`.
/// This is the compressed representation the paper's future-work section
/// proposes comparing with a simple distance instead of DTW.
pub fn signature(xs: &[f64], family: Family, m: usize) -> Vec<f64> {
    assert!(m >= 1);
    if xs.is_empty() {
        return vec![0.0; m];
    }
    let mut approx = xs.to_vec();
    while approx.len() > m && approx.len() >= 2 {
        let (a, _) = dwt_level(&approx, family);
        approx = a;
    }
    approx.resize(m, 0.0);
    approx
}

/// Euclidean distance between equal-length signatures.
pub fn signature_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Inverse of one Haar level (exact for even-length inputs) — used to verify
/// the transform in tests and to reconstruct approximations for plots.
pub fn haar_inverse_level(approx: &[f64], detail: &[f64]) -> Vec<f64> {
    assert_eq!(approx.len(), detail.len());
    let mut out = Vec::with_capacity(approx.len() * 2);
    for (a, d) in approx.iter().zip(detail.iter()) {
        out.push((a + d) * SQRT2_INV);
        out.push((a - d) * SQRT2_INV);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn haar_perfect_reconstruction() {
        let xs: Vec<f64> = (0..64).map(|i| ((i as f64) * 0.37).sin() + 0.1 * i as f64).collect();
        let (a, d) = dwt_level(&xs, Family::Haar);
        let back = haar_inverse_level(&a, &d);
        for (x, y) in xs.iter().zip(&back) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn energy_preserved_haar() {
        // Orthonormal transform: ||x||² = ||a||² + ||d||² (even length).
        let xs: Vec<f64> = (0..128).map(|i| ((i * i) as f64 * 0.01).cos()).collect();
        let (a, d) = dwt_level(&xs, Family::Haar);
        let ex: f64 = xs.iter().map(|v| v * v).sum();
        let eout: f64 = a.iter().chain(d.iter()).map(|v| v * v).sum();
        assert!((ex - eout).abs() < 1e-9, "{ex} vs {eout}");
    }

    #[test]
    fn db4_kills_linear_detail() {
        // DB4 has 2 vanishing moments: detail of a linear ramp is ~0
        // (away from the circular wrap-around).
        let xs: Vec<f64> = (0..64).map(|i| 3.0 * i as f64 + 1.0).collect();
        let (_, d) = dwt_level(&xs, Family::Db4);
        for v in &d[..d.len() - 2] {
            assert!(v.abs() < 1e-9, "detail {v}");
        }
    }

    #[test]
    fn db4_filter_is_orthonormal() {
        let s: f64 = DB4_LO.iter().map(|c| c * c).sum();
        assert!((s - 1.0).abs() < 1e-12);
        let sum: f64 = DB4_LO.iter().sum();
        assert!((sum - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn wavedec_layout() {
        let xs: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let bands = wavedec(&xs, Family::Haar, 3);
        assert_eq!(bands.len(), 4); // a3, d3, d2, d1
        assert_eq!(bands[0].len(), 4);
        assert_eq!(bands[1].len(), 4);
        assert_eq!(bands[2].len(), 8);
        assert_eq!(bands[3].len(), 16);
    }

    #[test]
    fn signature_fixed_length_and_similarity() {
        let a: Vec<f64> = (0..100).map(|i| ((i as f64) * 0.2).sin()).collect();
        let mut b = a.clone();
        for v in &mut b {
            *v += 0.01; // tiny offset
        }
        let c: Vec<f64> = (0..100).map(|i| if i < 50 { 1.0 } else { 0.0 }).collect();
        let (sa, sb, sc) = (
            signature(&a, Family::Db4, 16),
            signature(&b, Family::Db4, 16),
            signature(&c, Family::Db4, 16),
        );
        assert_eq!(sa.len(), 16);
        assert!(signature_distance(&sa, &sb) < signature_distance(&sa, &sc));
    }

    #[test]
    fn signature_handles_short_input() {
        let s = signature(&[1.0, 2.0], Family::Haar, 8);
        assert_eq!(s.len(), 8);
        assert!(s[2..].iter().all(|&v| v == 0.0));
    }
}
