//! Exhaustive interleaving checks (loom) for mrtuner's concurrency
//! primitives. Run from this directory with:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --release
//! ```
//!
//! Three models:
//!
//! 1. [`sync::AtomicF64Min`] — the *production source file*
//!    (`rust/src/util/sync.rs`, included below via `#[path]`, which swaps
//!    its std atomics for loom's under `--cfg loom`): concurrent
//!    `fetch_min` publishers must converge to the global minimum and a
//!    `load` can never observe a value above one the loading thread
//!    already published.
//! 2. The `par_map` chunk-claim protocol (`rust/src/util/pool.rs`): a
//!    relaxed `fetch_add` claim counter must hand every index to exactly
//!    one worker — the disjointness that makes the unsynchronized
//!    result-slot writes race-free.
//! 3. The `ThreadPool` shutdown protocol (`Drop` closes the channel, the
//!    worker drains then exits): modeled with a claim counter plus a
//!    closed flag, since loom has no mpsc — queued jobs all run before
//!    the worker terminates, under every interleaving of the close.
//!
//! Without `--cfg loom` the models compile away and `cargo test` just
//! runs `sync.rs`'s std-based unit tests.

#[path = "../../../rust/src/util/sync.rs"]
pub mod sync;

#[cfg(all(loom, test))]
mod models {
    use crate::sync::AtomicF64Min;
    use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use loom::sync::Arc;
    use loom::thread;

    #[test]
    fn atomic_f64_min_converges_and_never_rises() {
        loom::model(|| {
            let m = Arc::new(AtomicF64Min::new(f64::INFINITY));
            let handles: Vec<_> = [3.0_f64, 1.0, 2.0]
                .iter()
                .map(|&v| {
                    let m = Arc::clone(&m);
                    thread::spawn(move || {
                        m.fetch_min(v);
                        // After publishing v, no load may exceed v.
                        assert!(m.load() <= v, "cell above a published value");
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("publisher");
            }
            assert_eq!(m.load(), 1.0, "global minimum lost");
        });
    }

    #[test]
    fn atomic_f64_min_load_sees_only_published_values() {
        loom::model(|| {
            let m = Arc::new(AtomicF64Min::new(f64::INFINITY));
            let writer = {
                let m = Arc::clone(&m);
                thread::spawn(move || m.fetch_min(0.5))
            };
            // Concurrent reader: the only legal observations are the
            // initial value and the published one — a torn or invented
            // bit pattern would fail both comparisons.
            let seen = m.load();
            assert!(seen == f64::INFINITY || seen == 0.5, "torn read: {seen}");
            writer.join().expect("writer");
            assert_eq!(m.load(), 0.5);
        });
    }

    #[test]
    fn par_map_chunk_claims_are_disjoint_and_cover() {
        loom::model(|| {
            // The exact claim protocol of pool.rs::par_map (chunk = 1 for
            // tractability): workers fetch_add(Relaxed) a shared counter
            // and own [start, start+chunk). Every index must be claimed by
            // exactly one worker.
            let next = Arc::new(AtomicUsize::new(0));
            let n = 3usize;
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let next = Arc::clone(&next);
                    thread::spawn(move || {
                        let mut mine = Vec::new();
                        loop {
                            // relaxed: mirrors the production claim order.
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            mine.push(i);
                        }
                        mine
                    })
                })
                .collect();
            let mut all: Vec<usize> = Vec::new();
            for h in handles {
                all.extend(h.join().expect("worker"));
            }
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2], "claims must partition the input");
        });
    }

    #[test]
    fn thread_pool_shutdown_drains_queue_before_exit() {
        loom::model(|| {
            // ThreadPool::drop closes the sender, then joins; the worker
            // keeps receiving until the channel reports closed-and-empty.
            // Modeled as: claim jobs off a counter; exit only once closed
            // AND nothing is left to claim.
            let todo = Arc::new(AtomicUsize::new(2));
            let done = Arc::new(AtomicUsize::new(0));
            let closed = Arc::new(AtomicBool::new(false));
            let worker = {
                let todo = Arc::clone(&todo);
                let done = Arc::clone(&done);
                let closed = Arc::clone(&closed);
                thread::spawn(move || loop {
                    let left = todo.load(Ordering::Acquire);
                    if left > 0 {
                        let claim = todo.compare_exchange(
                            left,
                            left - 1,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        );
                        if claim.is_ok() {
                            done.fetch_add(1, Ordering::Release);
                        }
                    } else if closed.load(Ordering::Acquire) {
                        break;
                    } else {
                        thread::yield_now();
                    }
                })
            };
            closed.store(true, Ordering::Release);
            worker.join().expect("worker");
            assert_eq!(done.load(Ordering::Acquire), 2, "job dropped at shutdown");
            assert_eq!(todo.load(Ordering::Acquire), 0);
        });
    }
}
