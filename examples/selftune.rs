//! End-to-end self-tuning driver (E4 + E5): the system's headline metric.
//!
//! 1. Build a reference database by profiling four known applications over
//!    the paper's 50-configuration grid (§5), with the matching hot path on
//!    the PJRT-compiled artifacts when available.
//! 2. Match the unknown application (Exim mainlog parsing) via the
//!    per-config vote (paper Fig. 4b).
//! 3. Transfer the matched application's grid-searched optimal
//!    configuration and report tuned-vs-default completion time — the
//!    motivation in the paper's introduction.
//!
//! Run with: `cargo run --release --example selftune [grid_size]`

use mrtuner::prelude::*;

fn main() {
    mrtuner::util::logging::init();
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(50);
    let grid = ConfigGrid::random(n, 2011);
    let t0 = std::time::Instant::now();

    let mut sys = TuningSystem::new(SystemConfig::default());
    for app in [AppId::WordCount, AppId::TeraSort, AppId::Grep, AppId::InvertedIndex] {
        sys.profile_app(app, &grid);
        println!("profiled {:14} ({} configs) t={:.1}s", app.name(), grid.len(), t0.elapsed().as_secs_f64());
    }

    let outcome = sys.match_app(AppId::EximParse, &grid);
    println!("\nvote tally over {} configuration sets: {:?}", grid.len(), outcome.tally);
    let winner = outcome.winner.expect("a match above 90%");
    println!("matched application: {}", winner.name());
    // With the paper's 2-app database Exim matches WordCount; in this wider
    // 4-app database the vote may instead pick InvertedIndex — the *other*
    // tokenisation-bound text workload, whose fingerprint is legitimately
    // even closer (its shuffle selectivity brackets Exim's). What must hold
    // is the paper's ordering: text apps beat TeraSort decisively.
    let votes = |name: &str| outcome.tally.get(name).copied().unwrap_or(0);
    assert!(
        winner == AppId::WordCount || winner == AppId::InvertedIndex,
        "winner {winner:?} is not a text-parsing app"
    );
    assert!(
        votes("wordcount") > votes("terasort"),
        "paper ordering violated: {:?}",
        outcome.tally
    );

    let report = sys.tune_app(AppId::EximParse, &grid);
    println!("\nself-tuning report for exim:");
    println!("  matched app      : {}", report.matched_app.unwrap().name());
    println!(
        "  transferred      : {}",
        report.transferred.map(|c| c.label()).unwrap_or_default()
    );
    println!("  default config   : {} -> {:.1}s", report.default_config.label(), report.default_secs);
    println!("  tuned config     : {:.1}s", report.tuned_secs);
    println!("  speedup          : {:.2}x", report.speedup());
    println!("  wall time        : {:.1}s", t0.elapsed().as_secs_f64());

    assert!(report.speedup() > 1.0, "transferred configuration must help");
}
