"""L1 Pallas kernel: masked DTW dynamic program with traceback output.

The O(N*M) recurrence (paper eqn. 1) is reformulated row-wise for the TPU
VPU: within row ``i``

    D[i,j] = d[i,j] + min(m[j], D[i,j-1]),   m[j] = min(D[i-1,j], D[i-1,j-1])

and functions ``f(c) = min(a, b + c)`` are closed under composition, so the
whole row is one ``associative_scan`` over pairs ``(a, b) = (d + m, d)`` —
a log-depth, full-lane-width primitive instead of the classic ragged
anti-diagonal wavefront. A ``fori_loop`` walks rows, keeping only two rows
of f32 state in VMEM; the only O(L^2) output is the **s8 traceback choice
matrix** (4x smaller than the float cost matrix the textbook formulation
returns).

Masking: series are padded to the bucket length ``L``; local costs outside
``[0,nx) x [0,ny)`` are set to +1e30. The valid region is closed under the
recurrence (a valid cell's predecessors are valid or the zero boundary), so
reading ``D[nx-1, ny-1]`` gives the *exact* unpadded DTW distance.

Choice encoding (shared with rust/src/dtw/mod.rs and ref.py):
0 = diagonal, 1 = up, 2 = left; ties resolve vertical-group-first,
diagonal-within-group.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; on a real TPU the same kernel lowers natively (see
DESIGN.md §Hardware-Adaptation for the VMEM/roofline estimate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30  # python scalar: jnp constants may not be captured by pallas kernels

CHOICE_DIAG = 0
CHOICE_UP = 1
CHOICE_LEFT = 2


def _minplus_combine(left, right):
    """Composition of f(c) = min(a, b + c) elements for associative_scan."""
    a1, b1 = left
    a2, b2 = right
    return jnp.minimum(a2, b2 + a1), b1 + b2


def _dtw_kernel(x_ref, y_ref, nx_ref, ny_ref, dist_ref, choices_ref):
    """One (query, reference) DTW: grid cell ``b`` sees y row ``b``."""
    x = x_ref[...]  # (L,)
    y = y_ref[...].reshape(-1)  # (1, L) block -> (L,)
    nx = nx_ref[0]
    ny = ny_ref[0]
    L = x.shape[0]
    jj = jnp.arange(L)
    valid_j = jj < ny

    # Sakoe-Chiba band (10% of the longer series, slope-following) — keep
    # in sync with rust/src/dtw/mod.rs::band_radius.
    nxf = nx.astype(jnp.float32)
    nyf = ny.astype(jnp.float32)
    drift = (jnp.maximum(nyf, 2.0) - 1.0) / (jnp.maximum(nxf, 2.0) - 1.0)
    radius = jnp.ceil(jnp.maximum(0.1 * jnp.maximum(nxf, nyf), drift + 2.0))

    def row(i, carry):
        prev, dist = carry
        centre = i.astype(jnp.float32) * drift
        in_band = (jj.astype(jnp.float32) >= jnp.floor(centre - radius)) & (
            jj.astype(jnp.float32) <= jnp.ceil(centre + radius)
        )
        d = jnp.where(valid_j & in_band & (i < nx), jnp.abs(x[i] - y), jnp.float32(BIG))
        boundary = jnp.where(i == 0, jnp.float32(0.0), jnp.float32(BIG))
        diag = jnp.concatenate([boundary[None], prev[:-1]])
        up = prev
        vg = jnp.minimum(diag, up)
        vchoice = jnp.where(diag <= up, CHOICE_DIAG, CHOICE_UP).astype(jnp.int8)

        # Row min-plus scan: D_j = d_j + min(vg_j, D_{j-1}).
        a = d + vg
        drow, _ = jax.lax.associative_scan(_minplus_combine, (a, d))

        dshift = jnp.concatenate([jnp.full((1,), BIG, jnp.float32), drow[:-1]])
        ch = jnp.where(dshift < vg, jnp.int8(CHOICE_LEFT), vchoice)
        pl.store(choices_ref, (0, i, pl.dslice(0, L)), ch)

        dist = jnp.where(i == nx - 1, jax.lax.dynamic_index_in_dim(drow, ny - 1, keepdims=False), dist)
        return drow, dist

    init = (jnp.full((L,), BIG, jnp.float32), jnp.float32(0.0))
    _, dist = jax.lax.fori_loop(0, L, row, init)
    dist_ref[0] = dist


@functools.partial(jax.jit, static_argnames=())
def dtw_batch(x, ys, nx, nys):
    """Compare one padded query against a batch of padded references.

    Args:
      x: f32[L] query.
      ys: f32[B, L] references.
      nx: i32[1] query length.
      nys: i32[B] reference lengths.

    Returns:
      ``(dists f32[B], choices s8[B, L, L])``.
    """
    B, L = ys.shape
    x = x.astype(jnp.float32)
    ys = ys.astype(jnp.float32)
    nx = nx.astype(jnp.int32)
    nys = nys.astype(jnp.int32)
    return pl.pallas_call(
        _dtw_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((L,), lambda b: (0,)),
            pl.BlockSpec((1, L), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1, L, L), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B, L, L), jnp.int8),
        ],
        interpret=True,
    )(x, ys, nx, nys)


def dtw_pair(x, y, nx, ny):
    """Single-pair convenience wrapper: ``(dist f32[], choices s8[L,L])``."""
    dists, choices = dtw_batch(x, y[None, :], nx, ny.reshape(1))
    return dists[0], choices[0]
