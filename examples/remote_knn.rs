//! Multi-node serving end to end: two shard servers, one router, typed
//! clients — all in one process on ephemeral ports.
//!
//! The reference database is profiled once, then **partitioned by
//! configuration set** into two shards (exactly what
//! `mrtuner serve --shard-of ...` does). A [`ShardRouter`] connects to
//! both, learns ownership through the `shard_info` handshake, and answers
//! `knn`/`knn_batch` by pipelined fan-out + deterministic
//! `(distance, global index)` merge — bit-identical to searching the
//! union database on one node, which this example verifies live.
//!
//! Run with: `cargo run --release --example remote_knn`

use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::prelude::*;
use mrtuner::simulator::engine::simulate;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::workload_for;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Spawn a `MatchServer` over `db`, returning its address and stop handle.
fn spawn_shard(
    db: IndexedDb,
) -> (
    String,
    Arc<AtomicBool>,
    std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let state = ServerState {
        db,
        runtime: None,
        metrics: Metrics::new(),
        sessions: mrtuner::streaming::SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    };
    let server = MatchServer::bind("127.0.0.1:0", state).expect("bind shard");
    let addr = server.local_addr().expect("addr").to_string();
    let stop = server.stop_flag();
    let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));
    (addr, stop, handle)
}

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::small(1);
    let sc = SystemConfig {
        use_runtime: false,
        ..SystemConfig::default()
    };

    // Profile the full reference database once.
    let p = Profiler::new(&sc, None);
    let mut entries = Vec::new();
    for app in [AppId::WordCount, AppId::TeraSort] {
        entries.extend(p.profile(app, &grid));
    }

    // Partition by configuration set: even-indexed configs to shard A,
    // odd to shard B — and build the single-node union database in the
    // SAME shard order (A's entries, then B's), which is the ordering the
    // router's global index space reproduces.
    let shard_a_labels: Vec<String> = grid
        .configs
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 2 == 0)
        .map(|(_, c)| c.label())
        .collect();
    let (mut shard_a, mut shard_b) = (IndexedDb::new(), IndexedDb::new());
    for e in &entries {
        if shard_a_labels.contains(&e.config_key()) {
            shard_a.insert(e.clone());
        } else {
            shard_b.insert(e.clone());
        }
    }
    let mut union = IndexedDb::new();
    for e in shard_a.entries().iter().chain(shard_b.entries()) {
        union.insert(e.clone());
    }
    println!(
        "partitioned {} entries: shard A={} shard B={}",
        union.len(),
        shard_a.len(),
        shard_b.len()
    );

    let (addr_a, stop_a, join_a) = spawn_shard(shard_a);
    let (addr_b, stop_b, join_b) = spawn_shard(shard_b);

    // A plain typed client against one shard: pipelined pings + knn.
    let mut client = MrtunerClient::connect(&addr_a).expect("connect shard A");
    let info = client.shard_info().expect("shard_info");
    println!(
        "shard A owns {} entries over configs {:?}",
        info.entries, info.configs
    );

    // The router composes both shards into one logical database.
    let metrics = Arc::new(Metrics::new());
    let mut router =
        ShardRouter::connect(&[addr_a.clone(), addr_b.clone()], Arc::clone(&metrics))
            .expect("router connect");
    println!(
        "router composed {} shards into {} entries",
        router.shards().len(),
        router.total_entries()
    );

    // A fresh capture to search for (WordCount, first config set).
    let run = simulate(
        workload_for(AppId::WordCount).as_ref(),
        &grid.configs[0],
        &sc.cluster,
        &sc.noise,
        &mut Rng::new(77),
    );
    let queries: Vec<Vec<f64>> = vec![run.cpu_noisy.clone()];

    // Routed k-NN vs single-node k-NN over the union database.
    let routed = router.knn_batch(&queries, 3, None).expect("routed knn");
    let prepared = mrtuner::coordinator::batcher::prepare_query(&queries[0]);
    let local = union.knn_batch(&[prepared.as_slice()], 3);
    println!("\ntop-3 via router (global index / app / distance):");
    for (row, local_nb) in routed.results[0].neighbors.iter().zip(&local[0].0) {
        let bit_identical =
            row.index == local_nb.index && row.distance.to_bits() == local_nb.distance.to_bits();
        println!(
            "  entry {:3}  {:12} d={:.6}  single-node agrees bit-for-bit: {}",
            row.index, row.app, row.distance, bit_identical
        );
        assert!(bit_identical, "routed result diverged from single node");
    }
    println!("\nrouter metrics: {}", metrics.report());

    for (stop, join, addr) in [(stop_a, join_a, addr_a), (stop_b, join_b, addr_b)] {
        stop.store(true, Ordering::SeqCst);
        let _ = std::net::TcpStream::connect(&addr); // unblock accept
        join.join().expect("shard thread").expect("serve");
    }
}
