//! Matching phase (paper §4, Figure 4b): profile the unknown application
//! under each configuration set, compare its pattern to every database
//! entry captured under the *same* set, pick the per-set winner when its
//! correlation clears 90%, and declare the app with the most wins the most
//! similar application.

use super::batcher::{prepare_query, similarities_auto};
use super::{ConfigGrid, SystemConfig};
use crate::database::profile::ProfileEntry;
use crate::database::store::ReferenceDb;
use crate::dtw::corr::{similarity_percent_banded, MATCH_THRESHOLD};
use crate::index::{IndexedDb, Neighbor, SearchStats};
use crate::runtime::RuntimeHandle;
use crate::simulator::job::JobConfig;
use crate::streaming::{DecisionPolicy, FinalLen, StreamSession, StreamStats};
use crate::util::pool::par_map;
use crate::workloads::AppId;
use std::collections::BTreeMap;

/// One (config set, reference app) similarity measurement.
#[derive(Debug, Clone)]
pub struct SimilarityCell {
    pub config: JobConfig,
    pub reference_app: AppId,
    pub reference_config: JobConfig,
    pub similarity: f64,
}

/// Per-configuration-set result: the best reference app, if it cleared the
/// paper's 90% acceptance threshold.
#[derive(Debug, Clone)]
pub struct ConfigVote {
    pub config: JobConfig,
    pub best_app: Option<AppId>,
    pub best_similarity: f64,
}

/// Outcome of the matching phase.
#[derive(Debug, Clone)]
pub struct MatchOutcome {
    pub query_app: AppId,
    /// Every same-config comparison performed.
    pub cells: Vec<SimilarityCell>,
    /// Per-config winner (paper Fig. 4b line 12).
    pub votes: Vec<ConfigVote>,
    /// App with the highest number of accepted CORRs, if any.
    pub winner: Option<AppId>,
    /// Votes per app.
    pub tally: BTreeMap<&'static str, usize>,
}

/// Outcome of the streaming matching phase ([`Matcher::match_stream`]).
#[derive(Debug, Clone)]
pub struct StreamMatchReport {
    /// Votes/winner in the same shape as the offline matching phase.
    pub outcome: MatchOutcome,
    /// Index-search counters from sessions that ran to completion.
    pub search: SearchStats,
    /// Aggregated per-session streaming work counters.
    pub stream: StreamStats,
    /// Sessions whose vote was fixed before the run completed.
    pub early_decisions: usize,
    /// Sessions driven (one per grid config).
    pub sessions: usize,
    /// Mean fraction of each run observed before its vote was fixed
    /// (1.0 for sessions that ran to completion).
    pub mean_fraction: f64,
}

/// Runs the matching phase.
pub struct Matcher {
    config: SystemConfig,
    runtime: Option<RuntimeHandle>,
}

impl Matcher {
    pub fn new(config: &SystemConfig, runtime: Option<RuntimeHandle>) -> Matcher {
        Matcher {
            config: config.clone(),
            runtime,
        }
    }

    /// Similarities of a raw query capture against stored references
    /// (PJRT or native per the mode policy — see batcher::use_pjrt_for_bucket).
    fn similarities(&self, raw_query: &[f64], refs: &[Vec<f64>]) -> Vec<f64> {
        similarities_auto(self.runtime.as_ref(), raw_query, refs)
    }

    /// Profile the unknown app under one configuration set: the raw (noisy)
    /// query capture. One seed derivation for every matching path — the
    /// brute-force, indexed and table routes must query identical series or
    /// their equivalence guarantees silently rot.
    fn profile_query(&self, app: AppId, cfg: &JobConfig) -> crate::simulator::engine::SimResult {
        let workload = crate::workloads::workload_for(app);
        let mut rng = crate::util::rng::Rng::new(self.run_seed(app, cfg));
        crate::simulator::engine::simulate(
            workload.as_ref(),
            cfg,
            &self.config.cluster,
            &self.config.noise,
            &mut rng,
        )
    }

    /// Full matching phase for `app` over `grid` against `db`.
    pub fn match_app(&self, app: AppId, grid: &ConfigGrid, db: &ReferenceDb) -> MatchOutcome {
        // Profile the unknown app and compare, one config set at a time.
        let per_config: Vec<(Vec<SimilarityCell>, ConfigVote)> =
            par_map(&grid.configs, self.config.workers, |cfg| {
                // Capture the raw (noisy) series; preprocessing happens in
                // the fused match path.
                let raw = self.profile_query(app, cfg).cpu_noisy;

                let refs = db.by_config(&cfg.label());
                let ref_series: Vec<Vec<f64>> =
                    refs.iter().map(|e| e.series.clone()).collect();
                let sims = self.similarities(&raw, &ref_series);

                let mut cells = Vec::with_capacity(refs.len());
                let mut best: Option<(AppId, f64)> = None;
                for (e, s) in refs.iter().zip(sims.iter()) {
                    cells.push(SimilarityCell {
                        config: *cfg,
                        reference_app: e.app,
                        reference_config: e.config,
                        similarity: *s,
                    });
                    if best.map_or(true, |(_, bs)| *s > bs) {
                        best = Some((e.app, *s));
                    }
                }
                let vote = ConfigVote {
                    config: *cfg,
                    best_app: best
                        .filter(|(_, s)| *s >= MATCH_THRESHOLD)
                        .map(|(a, _)| a),
                    best_similarity: best.map(|(_, s)| s).unwrap_or(0.0),
                };
                (cells, vote)
            });

        let mut cells = Vec::new();
        let mut votes = Vec::new();
        for (c, v) in per_config {
            cells.extend(c);
            votes.push(v);
        }

        let (tally, winner) = tally_votes(&votes);
        MatchOutcome {
            query_app: app,
            cells,
            votes,
            winner,
            tally,
        }
    }

    /// Index-backed matching phase: instead of evaluating the paper's
    /// correlation similarity against *every* same-config reference, each
    /// per-config query retrieves the `rerank` nearest references under the
    /// banded-DTW distance through the lower-bound cascade
    /// ([`IndexedDb::knn_in_config`] — exact, brute-force-identical
    /// neighbours) and only those get the full correlation treatment.
    ///
    /// With `rerank >= <bucket size>` this computes exactly what
    /// [`Matcher::match_app`] computes *on the pure-Rust path* (every
    /// candidate is retrieved and re-ranked with the same f64 pipeline;
    /// `match_app` with a PJRT runtime attached rounds through f32 and can
    /// differ in the last decimals). Smaller values trade the guarantee
    /// for sublinear work — in practice the DTW-nearest reference and the
    /// correlation winner coincide (asserted on the paper scenarios in
    /// tests and `benches/index_perf.rs`). `MatchOutcome::cells` contains
    /// only the comparisons actually performed. Each finalist pays one
    /// extra banded DP with traceback for the correlation (the cascade's
    /// distance-only pass keeps no path, on purpose — finalists are few,
    /// pruned candidates many).
    pub fn match_app_indexed(
        &self,
        app: AppId,
        grid: &ConfigGrid,
        idx: &IndexedDb,
        rerank: usize,
    ) -> (MatchOutcome, SearchStats) {
        let rerank = rerank.max(1);
        let per_config: Vec<(Vec<SimilarityCell>, ConfigVote, SearchStats)> =
            par_map(&grid.configs, self.config.workers, |cfg| {
                let q = prepare_query(&self.profile_query(app, cfg).cpu_noisy);
                let (neighbors, stats) = idx.knn_in_config(&q, &cfg.label(), rerank);
                let (cells, vote) = score_neighbors(&q, &neighbors, idx.entries(), cfg);
                (cells, vote, stats)
            });

        let mut cells = Vec::new();
        let mut votes = Vec::new();
        let mut stats = SearchStats::default();
        for (c, v, s) in per_config {
            cells.extend(c);
            votes.push(v);
            stats.merge(&s);
        }
        let (tally, winner) = tally_votes(&votes);
        (
            MatchOutcome {
                query_app: app,
                cells,
                votes,
                winner,
                tally,
            },
            stats,
        )
    }

    /// Batched index-backed matching phase: classify several unknown apps
    /// in one pass. Per configuration set, every app's query is profiled
    /// and then searched together through
    /// [`IndexedDb::knn_batch_in_config`], whose entry-major walk shares
    /// one envelope pass per reference entry across the whole query batch
    /// — the per-(query, entry) envelope work of `B` separate
    /// [`Matcher::match_app_indexed`] calls collapses to one. Results are
    /// returned in `apps` order and are identical — votes, winners,
    /// similarities and search counters — to calling `match_app_indexed`
    /// once per app (pinned by `rust/tests/query_engine.rs`).
    pub fn match_apps_indexed(
        &self,
        apps: &[AppId],
        grid: &ConfigGrid,
        idx: &IndexedDb,
        rerank: usize,
    ) -> Vec<(MatchOutcome, SearchStats)> {
        let rerank = rerank.max(1);
        if apps.is_empty() {
            return Vec::new();
        }
        // Config-major: one batched search per configuration set, every
        // app riding in the same batch.
        let per_config: Vec<Vec<(Vec<SimilarityCell>, ConfigVote, SearchStats)>> =
            par_map(&grid.configs, self.config.workers, |cfg| {
                let queries: Vec<Vec<f64>> = apps
                    .iter()
                    .map(|&app| prepare_query(&self.profile_query(app, cfg).cpu_noisy))
                    .collect();
                let qrefs: Vec<&[f64]> = queries.iter().map(Vec::as_slice).collect();
                let results = idx.knn_batch_in_config(&qrefs, &cfg.label(), rerank);
                queries
                    .iter()
                    .zip(results)
                    .map(|(q, (neighbors, stats))| {
                        let (cells, vote) = score_neighbors(q, &neighbors, idx.entries(), cfg);
                        (cells, vote, stats)
                    })
                    .collect()
            });

        // Transpose back to per-app outcomes in input order.
        apps.iter()
            .enumerate()
            .map(|(ai, &app)| {
                let mut cells = Vec::new();
                let mut votes = Vec::new();
                let mut stats = SearchStats::default();
                for cfg_rows in &per_config {
                    let (c, v, s) = &cfg_rows[ai];
                    cells.extend(c.iter().cloned());
                    votes.push(v.clone());
                    stats.merge(s);
                }
                let (tally, winner) = tally_votes(&votes);
                (
                    MatchOutcome {
                        query_app: app,
                        cells,
                        votes,
                        winner,
                        tally,
                    },
                    stats,
                )
            })
            .collect()
    }

    /// Streaming matching phase: each per-config query is *streamed* into
    /// a [`StreamSession`] batch by batch instead of being captured whole,
    /// and its vote is fixed the moment the session's early-exit policy
    /// declares — before the simulated job finishes. Sessions that never
    /// declare run to completion and finalize through the exact indexed
    /// search, so with [`DecisionPolicy::never`] this reproduces
    /// [`Matcher::match_app_indexed`] vote for vote (pinned in tests).
    ///
    /// `batch` is the feed granularity in samples (a SysStat agent's
    /// upload period); `rerank` bounds the finalists scored on
    /// finalization, exactly like `match_app_indexed`.
    pub fn match_stream(
        &self,
        app: AppId,
        grid: &ConfigGrid,
        idx: &IndexedDb,
        batch: usize,
        rerank: usize,
        policy: DecisionPolicy,
    ) -> StreamMatchReport {
        let batch = batch.max(1);
        let rerank = rerank.max(1);
        struct PerConfig {
            cells: Vec<SimilarityCell>,
            vote: ConfigVote,
            search: SearchStats,
            stream: StreamStats,
            fraction: f64,
            early: bool,
        }
        let per_config: Vec<PerConfig> = par_map(&grid.configs, self.config.workers, |cfg| {
            let sim = self.profile_query(app, cfg);
            let mut source = sim.live_stream();
            let mut session = StreamSession::open(
                idx,
                Some(cfg),
                FinalLen::Known(source.final_len()),
                policy,
            );
            while let Some(chunk) = source.next_batch(batch) {
                if session.push(idx, chunk).is_some() {
                    break;
                }
            }
            let entries = idx.entries();
            match session.decision().cloned() {
                Some(d) => PerConfig {
                    cells: vec![SimilarityCell {
                        config: *cfg,
                        reference_app: d.app,
                        reference_config: d.config,
                        similarity: d.similarity,
                    }],
                    vote: ConfigVote {
                        config: *cfg,
                        best_app: Some(d.app).filter(|_| d.similarity >= MATCH_THRESHOLD),
                        best_similarity: d.similarity,
                    },
                    search: SearchStats::default(),
                    stream: session.stats(),
                    fraction: d.fraction,
                    early: true,
                },
                None => {
                    // Ran to completion: identical to the offline indexed
                    // path (same query preparation, same search, same
                    // correlation re-rank via the shared scorer).
                    let (neighbors, search) = session.finalize(idx, rerank);
                    let q = prepare_query(&sim.cpu_noisy);
                    let (cells, vote) = score_neighbors(&q, &neighbors, entries, cfg);
                    PerConfig {
                        cells,
                        vote,
                        search,
                        stream: session.stats(),
                        fraction: 1.0,
                        early: false,
                    }
                }
            }
        });

        let mut cells = Vec::new();
        let mut votes = Vec::new();
        let mut search = SearchStats::default();
        let mut stream = StreamStats::default();
        let mut early_decisions = 0;
        let mut fraction_sum = 0.0;
        let sessions = per_config.len();
        for pc in per_config {
            cells.extend(pc.cells);
            votes.push(pc.vote);
            search.merge(&pc.search);
            stream.merge(&pc.stream);
            early_decisions += pc.early as usize;
            fraction_sum += pc.fraction;
        }
        let (tally, winner) = tally_votes(&votes);
        StreamMatchReport {
            outcome: MatchOutcome {
                query_app: app,
                cells,
                votes,
                winner,
                tally,
            },
            search,
            stream,
            early_decisions,
            sessions,
            mean_fraction: if sessions == 0 {
                0.0
            } else {
                fraction_sum / sessions as f64
            },
        }
    }

    /// Cross-config similarity table (Table 1 reproduction): the query app
    /// profiled at each grid config vs *every* reference entry — including
    /// different-config references, which the paper's Table 1 shows as the
    /// off-diagonal cells.
    pub fn similarity_table(
        &self,
        app: AppId,
        grid: &ConfigGrid,
        db: &ReferenceDb,
    ) -> Vec<SimilarityCell> {
        let all_refs: Vec<(AppId, JobConfig, Vec<f64>)> = db
            .entries()
            .iter()
            .map(|e| (e.app, e.config, e.series.clone()))
            .collect();
        let per_config: Vec<Vec<SimilarityCell>> =
            par_map(&grid.configs, self.config.workers, |cfg| {
                let sim = self.profile_query(app, cfg);
                let ref_series: Vec<Vec<f64>> =
                    all_refs.iter().map(|(_, _, s)| s.clone()).collect();
                let sims = self.similarities(&sim.cpu_noisy, &ref_series);
                all_refs
                    .iter()
                    .zip(sims)
                    .map(|((ra, rc, _), s)| SimilarityCell {
                        config: *cfg,
                        reference_app: *ra,
                        reference_config: *rc,
                        similarity: s,
                    })
                    .collect()
            });
        per_config.into_iter().flatten().collect()
    }

    fn run_seed(&self, app: AppId, cfg: &JobConfig) -> u64 {
        // Distinct stream from the profiler's (the paper re-runs the new
        // application; it does not reuse the reference capture).
        let mut h: u64 = self.config.seed ^ 0x00c0_ffee_0000_0001;
        for b in app.name().bytes().chain(cfg.label().bytes()) {
            h = h.wrapping_mul(0x100_0000_01b3) ^ b as u64;
        }
        h
    }
}

/// Correlation re-rank of retrieved neighbours into similarity cells and
/// the per-config vote. Shared by the offline indexed path and the
/// streaming finalization path so the two can never drift — the
/// never-policy equivalence test pins them to each other.
fn score_neighbors(
    q: &[f64],
    neighbors: &[Neighbor],
    entries: &[ProfileEntry],
    cfg: &JobConfig,
) -> (Vec<SimilarityCell>, ConfigVote) {
    let mut cells = Vec::with_capacity(neighbors.len());
    let mut best: Option<(AppId, f64)> = None;
    for nb in neighbors {
        let e = &entries[nb.index];
        let s = similarity_percent_banded(q, &e.series);
        cells.push(SimilarityCell {
            config: *cfg,
            reference_app: e.app,
            reference_config: e.config,
            similarity: s,
        });
        if best.map_or(true, |(_, bs)| s > bs) {
            best = Some((e.app, s));
        }
    }
    let vote = ConfigVote {
        config: *cfg,
        best_app: best.filter(|(_, s)| *s >= MATCH_THRESHOLD).map(|(a, _)| a),
        best_similarity: best.map(|(_, s)| s).unwrap_or(0.0),
    };
    (cells, vote)
}

/// Per-config votes → (votes per app, app with the most accepted CORRs).
/// Shared by the brute-force and index-backed paths so their aggregation
/// (including tie behaviour) can never diverge.
fn tally_votes(votes: &[ConfigVote]) -> (BTreeMap<&'static str, usize>, Option<AppId>) {
    let mut tally: BTreeMap<&'static str, usize> = BTreeMap::new();
    for v in votes {
        if let Some(app) = v.best_app {
            *tally.entry(app.name()).or_insert(0) += 1;
        }
    }
    let winner = tally
        .iter()
        .max_by_key(|(_, &n)| n)
        .map(|(name, _)| AppId::from_name(name).expect("tally key is valid"));
    (tally, winner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::profiler::Profiler;

    fn sysconfig() -> SystemConfig {
        SystemConfig {
            workers: 2,
            use_runtime: false,
            ..SystemConfig::default()
        }
    }

    fn build_db(grid: &ConfigGrid) -> ReferenceDb {
        let cfg = sysconfig();
        let p = Profiler::new(&cfg, None);
        let mut db = ReferenceDb::new();
        for app in [AppId::WordCount, AppId::TeraSort] {
            for e in p.profile(app, grid) {
                db.insert(e);
            }
        }
        db
    }

    #[test]
    fn self_match_wins_every_config() {
        // Matching WordCount against a DB containing WordCount must vote
        // WordCount everywhere (different noise seeds, same underlying
        // pattern).
        let grid = ConfigGrid::small(1);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let outcome = m.match_app(AppId::WordCount, &grid, &db);
        assert_eq!(outcome.winner, Some(AppId::WordCount));
        let wc_votes = outcome.tally.get("wordcount").copied().unwrap_or(0);
        assert!(
            wc_votes >= grid.len() - 1,
            "wordcount won only {wc_votes}/{} votes: {:?}",
            grid.len(),
            outcome.tally
        );
    }

    #[test]
    fn exim_matches_wordcount_not_terasort() {
        // The paper's headline result.
        let grid = ConfigGrid::small(2);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let outcome = m.match_app(AppId::EximParse, &grid, &db);
        assert_eq!(outcome.winner, Some(AppId::WordCount), "tally {:?}", outcome.tally);
    }

    #[test]
    fn empty_db_yields_no_winner() {
        let grid = ConfigGrid::small(3);
        let db = ReferenceDb::new();
        let m = Matcher::new(&sysconfig(), None);
        let outcome = m.match_app(AppId::Grep, &grid, &db);
        assert_eq!(outcome.winner, None);
        assert!(outcome.cells.is_empty());
    }

    #[test]
    fn indexed_match_with_full_rerank_equals_brute_force() {
        // rerank >= bucket size retrieves every candidate, so the indexed
        // path must reproduce the brute-force outcome vote for vote.
        let grid = ConfigGrid::small(5);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let brute = m.match_app(AppId::EximParse, &grid, &db);
        let idx = IndexedDb::from_db(db);
        let (fast, stats) = m.match_app_indexed(AppId::EximParse, &grid, &idx, usize::MAX);
        assert_eq!(fast.winner, brute.winner);
        assert_eq!(fast.tally, brute.tally);
        assert_eq!(fast.votes.len(), brute.votes.len());
        for (a, b) in fast.votes.iter().zip(&brute.votes) {
            assert_eq!(a.best_app, b.best_app, "config {}", a.config.label());
            assert!(
                (a.best_similarity - b.best_similarity).abs() < 1e-9,
                "config {}: {} vs {}",
                a.config.label(),
                a.best_similarity,
                b.best_similarity
            );
        }
        // 2 reference apps per config: every candidate was examined.
        assert_eq!(stats.candidates, 2 * grid.len() as u64);
    }

    #[test]
    fn indexed_match_top1_self_match_wins() {
        let grid = ConfigGrid::small(1);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let idx = IndexedDb::from_db(db);
        let (outcome, stats) = m.match_app_indexed(AppId::WordCount, &grid, &idx, 1);
        assert_eq!(outcome.winner, Some(AppId::WordCount));
        // Top-1 retrieval computes the correlation for one reference per
        // config only.
        assert_eq!(outcome.cells.len(), grid.len());
        assert_eq!(stats.candidates, 2 * grid.len() as u64);
    }

    #[test]
    fn indexed_match_empty_db_yields_no_winner() {
        let grid = ConfigGrid::small(3);
        let idx = IndexedDb::from_db(ReferenceDb::new());
        let m = Matcher::new(&sysconfig(), None);
        let (outcome, stats) = m.match_app_indexed(AppId::Grep, &grid, &idx, 1);
        assert_eq!(outcome.winner, None);
        assert!(outcome.cells.is_empty());
        assert_eq!(stats.candidates, 0);
    }

    #[test]
    fn batched_matcher_equals_per_app_indexed() {
        let grid = ConfigGrid::small(4);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let idx = IndexedDb::from_db(db);
        let apps = [AppId::EximParse, AppId::WordCount];
        let batch = m.match_apps_indexed(&apps, &grid, &idx, 1);
        assert_eq!(batch.len(), apps.len());
        for (i, &app) in apps.iter().enumerate() {
            let (want, wstats) = m.match_app_indexed(app, &grid, &idx, 1);
            assert_eq!(batch[i].0.winner, want.winner, "app {}", app.name());
            assert_eq!(batch[i].0.tally, want.tally);
            assert_eq!(batch[i].1, wstats, "app {}", app.name());
            assert_eq!(batch[i].0.votes.len(), want.votes.len());
            for (a, b) in batch[i].0.votes.iter().zip(&want.votes) {
                assert_eq!(a.best_app, b.best_app, "config {}", a.config.label());
                assert_eq!(
                    a.best_similarity.to_bits(),
                    b.best_similarity.to_bits(),
                    "config {}",
                    a.config.label()
                );
            }
        }
        assert!(m.match_apps_indexed(&[], &grid, &idx, 1).is_empty());
    }

    #[test]
    fn stream_match_with_never_policy_equals_indexed() {
        // Sessions that are never allowed to exit early must reproduce the
        // offline indexed matching phase vote for vote.
        let grid = ConfigGrid::small(7);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let idx = IndexedDb::from_db(db);
        let (offline, _) = m.match_app_indexed(AppId::EximParse, &grid, &idx, 1);
        let report = m.match_stream(
            AppId::EximParse,
            &grid,
            &idx,
            16,
            1,
            crate::streaming::DecisionPolicy::never(),
        );
        assert_eq!(report.early_decisions, 0);
        assert!((report.mean_fraction - 1.0).abs() < 1e-12);
        assert_eq!(report.outcome.winner, offline.winner);
        assert_eq!(report.outcome.tally, offline.tally);
        for (a, b) in report.outcome.votes.iter().zip(&offline.votes) {
            assert_eq!(a.best_app, b.best_app, "config {}", a.config.label());
            assert!(
                (a.best_similarity - b.best_similarity).abs() < 1e-12,
                "config {}: {} vs {}",
                a.config.label(),
                a.best_similarity,
                b.best_similarity
            );
        }
    }

    #[test]
    fn stream_match_early_policy_still_finds_the_right_app() {
        let grid = ConfigGrid::small(1);
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let idx = IndexedDb::from_db(db);
        let report = m.match_stream(
            AppId::WordCount,
            &grid,
            &idx,
            16,
            1,
            crate::streaming::DecisionPolicy::default(),
        );
        assert_eq!(report.outcome.winner, Some(AppId::WordCount));
        assert_eq!(report.sessions, grid.len());
        assert!(
            report.early_decisions >= 1,
            "early-exit policy never fired: mean_fraction={}",
            report.mean_fraction
        );
        assert!(report.mean_fraction <= 1.0);
        assert!(report.stream.samples > 0 && report.stream.lb_evals > 0);
    }

    #[test]
    fn similarity_table_is_complete() {
        let grid = ConfigGrid::paper_table1();
        let db = build_db(&grid);
        let m = Matcher::new(&sysconfig(), None);
        let table = m.similarity_table(AppId::EximParse, &grid, &db);
        // 4 query configs x (2 apps x 4 ref configs) = 32 cells.
        assert_eq!(table.len(), 32);
        for c in &table {
            assert!((0.0..=100.0).contains(&c.similarity));
        }
    }
}
