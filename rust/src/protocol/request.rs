//! Typed requests: every wire command as one enum, with hand-rolled
//! conversions from both envelope flavors and a serializer for v2 client
//! lines. Field validation (and its error messages) lives here so the v1
//! shim and the v2 path can never drift apart.

use super::{ErrorCode, ServerError, MAX_K, MAX_KNN_BATCH, MAX_POLL_K, PROTOCOL_VERSION};
use crate::simulator::job::JobConfig;
use crate::util::json::Json;

/// One parsed request, whatever envelope it arrived in.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    Stats,
    Apps,
    /// What this server owns: entry count, apps, config labels, live
    /// session ids. The shard router's handshake.
    ShardInfo,
    /// Structured metrics snapshot (counters, latency summaries with
    /// quantiles, per-code proto errors, per-shard fan-out) as JSON — the
    /// machine-readable sibling of `stats`' human report string.
    Metrics,
    /// Snapshot the server's flight recorder: the last N finished spans
    /// as a Chrome-loadable trace document. Post-incident forensics
    /// without restarting anything.
    TraceDump,
    /// Preprocess a raw capture and score it against every reference of
    /// one configuration set (the paper's matching phase).
    Match { series: Vec<f64>, config: JobConfig },
    /// Index-backed exact k-NN (whole database, or one config bucket).
    /// `allow_partial` opts into graceful degradation behind the router:
    /// results merged from the surviving shard groups (with a `degraded`
    /// reply annotation) instead of a `shard_unavailable` error. Single
    /// nodes ignore it — their answer is never partial.
    Knn {
        series: Vec<f64>,
        k: usize,
        config: Option<JobConfig>,
        allow_partial: bool,
    },
    /// Many k-NN queries answered in one entry-major pass.
    KnnBatch {
        queries: Vec<Vec<f64>>,
        k: usize,
        config: Option<JobConfig>,
        allow_partial: bool,
    },
    /// Open a live classification session. Options are kept raw here; the
    /// server applies the same clamping rules to both envelope flavors.
    StreamOpen {
        config: Option<JobConfig>,
        final_len: Option<usize>,
        max_len: Option<usize>,
        min_fraction: Option<f64>,
        margin: Option<f64>,
        min_samples: Option<usize>,
    },
    /// Feed one batch of raw CPU samples into a live session. `progress`
    /// optionally reports the producing job's completed fraction in
    /// `(0, 1]`; the server feeds it to the session's final-length
    /// predictor so prefix bounds tighten as the job advances.
    StreamFeed {
        session: u64,
        samples: Vec<f64>,
        progress: Option<f64>,
    },
    /// A live session's anytime top-k without feeding it.
    StreamPoll { session: u64, k: usize },
    /// Snapshot every live session in one request.
    StreamPollAll { k: usize },
    /// Close a session: exact final search over the whole capture.
    StreamClose { session: u64 },
    /// Tuning advice for a live session: the current decision (frozen or
    /// anytime leader) plus the matched application's cached optimal
    /// configuration, if the server knows one. Read-only — it never
    /// grid-searches.
    StreamTune { session: u64 },
}

fn parse_series_field(req: &Json) -> Result<Vec<f64>, ServerError> {
    let series = req
        .get("series")
        .and_then(Json::as_arr)
        .ok_or_else(|| ServerError::bad_request("missing series"))?
        .iter()
        .filter_map(Json::as_f64)
        .collect::<Vec<f64>>();
    if series.len() < 4 {
        return Err(ServerError::bad_request("series too short"));
    }
    Ok(series)
}

/// Parse a `{"mappers":..,"reducers":..,"split_mb":..,"input_mb":..}`
/// object (shared by every command that scopes to a configuration set).
pub fn parse_config(v: &Json) -> Result<JobConfig, ServerError> {
    let num = |k: &str| -> Result<f64, ServerError> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| ServerError::bad_request(format!("config missing {k}")))
    };
    Ok(JobConfig::new(
        num("mappers")? as usize,
        num("reducers")? as usize,
        num("split_mb")?,
        num("input_mb")?,
    ))
}

/// Serialize a configuration set the way [`parse_config`] reads it.
pub fn config_to_json(c: &JobConfig) -> Json {
    Json::obj(vec![
        ("mappers", Json::Num(c.mappers as f64)),
        ("reducers", Json::Num(c.reducers as f64)),
        ("split_mb", Json::Num(c.split_mb)),
        ("input_mb", Json::Num(c.input_mb)),
    ])
}

fn opt_config(req: &Json) -> Result<Option<JobConfig>, ServerError> {
    match req.get("config") {
        Some(c) => Ok(Some(parse_config(c)?)),
        None => Ok(None),
    }
}

fn parse_session_field(req: &Json) -> Result<u64, ServerError> {
    req.get("session")
        .and_then(Json::as_usize)
        .map(|id| id as u64)
        .ok_or_else(|| ServerError::bad_request("missing session id"))
}

fn parse_samples_field(req: &Json) -> Result<Vec<f64>, ServerError> {
    let samples: Vec<f64> = req
        .get("samples")
        .and_then(Json::as_arr)
        .ok_or_else(|| ServerError::bad_request("missing samples"))?
        .iter()
        .filter_map(Json::as_f64)
        .collect();
    if samples.is_empty() {
        return Err(ServerError::bad_request("empty samples"));
    }
    Ok(samples)
}

fn parse_queries_field(req: &Json) -> Result<Vec<Vec<f64>>, ServerError> {
    let queries_json = req
        .get("queries")
        .and_then(Json::as_arr)
        .ok_or_else(|| ServerError::bad_request("missing queries"))?;
    if queries_json.is_empty() {
        return Err(ServerError::bad_request("empty queries"));
    }
    if queries_json.len() > MAX_KNN_BATCH {
        return Err(ServerError::new(
            ErrorCode::TooLarge,
            format!(
                "batch too large ({} queries, max {MAX_KNN_BATCH})",
                queries_json.len()
            ),
        ));
    }
    let mut queries: Vec<Vec<f64>> = Vec::with_capacity(queries_json.len());
    for (qi, qj) in queries_json.iter().enumerate() {
        let series: Vec<f64> = qj
            .as_arr()
            .ok_or_else(|| ServerError::bad_request(format!("query {qi}: not an array")))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        if series.len() < 4 {
            return Err(ServerError::bad_request(format!("query {qi}: series too short")));
        }
        queries.push(series);
    }
    Ok(queries)
}

fn allow_partial(req: &Json) -> bool {
    req.get("allow_partial").and_then(Json::as_bool).unwrap_or(false)
}

fn stream_open_fields(req: &Json) -> Result<Request, ServerError> {
    Ok(Request::StreamOpen {
        config: opt_config(req)?,
        final_len: req.get("final_len").and_then(Json::as_usize),
        max_len: req.get("max_len").and_then(Json::as_usize),
        min_fraction: req.get("min_fraction").and_then(Json::as_f64),
        margin: req.get("margin").and_then(Json::as_f64),
        min_samples: req.get("min_samples").and_then(Json::as_usize),
    })
}

impl Request {
    /// Decode a legacy `{"cmd": ...}` command object. Defaults and clamps
    /// mirror the pre-envelope server exactly (`k` is forced to at least
    /// 1), so v1 lines keep answering byte-compatibly.
    pub fn from_v1(req: &Json) -> Result<Request, ServerError> {
        Request::from_tagged(req, "cmd", 1, "unknown cmd")
    }

    /// Decode the body of a v2 envelope (the caller has already checked
    /// `v` and `id`). Unlike v1, `k = 0` is legal and means "answer with
    /// nothing" — the edge case v1's lower clamp papered over.
    pub fn from_v2(req: &Json) -> Result<Request, ServerError> {
        Request::from_tagged(req, "type", 0, "unknown type")
    }

    /// The one decode body behind both envelope flavors: they differ only
    /// in the tag key, the `k` floor, and the unknown-command message —
    /// so command parsing can never drift between v1 and v2.
    fn from_tagged(
        req: &Json,
        tag: &str,
        k_floor: usize,
        unknown: &'static str,
    ) -> Result<Request, ServerError> {
        let k_knn = || {
            req.get("k")
                .and_then(Json::as_usize)
                .unwrap_or(1)
                .clamp(k_floor, MAX_K)
        };
        let k_poll = || {
            req.get("k")
                .and_then(Json::as_usize)
                .unwrap_or(3)
                .clamp(k_floor, MAX_POLL_K)
        };
        match req.get(tag).and_then(Json::as_str) {
            Some("ping") => Ok(Request::Ping),
            Some("stats") => Ok(Request::Stats),
            Some("apps") => Ok(Request::Apps),
            Some("shard_info") => Ok(Request::ShardInfo),
            Some("metrics") => Ok(Request::Metrics),
            Some("trace_dump") => Ok(Request::TraceDump),
            Some("match") => {
                let series = parse_series_field(req)?;
                let config = parse_config(
                    req.get("config")
                        .ok_or_else(|| ServerError::bad_request("match: missing config"))?,
                )?;
                Ok(Request::Match { series, config })
            }
            Some("knn") => Ok(Request::Knn {
                series: parse_series_field(req)?,
                k: k_knn(),
                config: opt_config(req)?,
                allow_partial: allow_partial(req),
            }),
            Some("knn_batch") => Ok(Request::KnnBatch {
                queries: parse_queries_field(req)?,
                k: k_knn(),
                config: opt_config(req)?,
                allow_partial: allow_partial(req),
            }),
            Some("stream_open") => stream_open_fields(req),
            Some("stream_feed") => Ok(Request::StreamFeed {
                session: parse_session_field(req)?,
                samples: parse_samples_field(req)?,
                progress: req.get("progress").and_then(Json::as_f64),
            }),
            Some("stream_poll") => Ok(Request::StreamPoll {
                session: parse_session_field(req)?,
                k: k_poll(),
            }),
            Some("stream_poll_all") => Ok(Request::StreamPollAll { k: k_poll() }),
            Some("stream_close") => Ok(Request::StreamClose {
                session: parse_session_field(req)?,
            }),
            Some("stream_tune") => Ok(Request::StreamTune {
                session: parse_session_field(req)?,
            }),
            _ => Err(ServerError::new(ErrorCode::UnknownCommand, unknown)),
        }
    }

    /// The `type` tag this request serializes under.
    pub fn type_name(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Stats => "stats",
            Request::Apps => "apps",
            Request::ShardInfo => "shard_info",
            Request::Metrics => "metrics",
            Request::TraceDump => "trace_dump",
            Request::Match { .. } => "match",
            Request::Knn { .. } => "knn",
            Request::KnnBatch { .. } => "knn_batch",
            Request::StreamOpen { .. } => "stream_open",
            Request::StreamFeed { .. } => "stream_feed",
            Request::StreamPoll { .. } => "stream_poll",
            Request::StreamPollAll { .. } => "stream_poll_all",
            Request::StreamClose { .. } => "stream_close",
            Request::StreamTune { .. } => "stream_tune",
        }
    }

    /// True when replaying the request after a lost connection cannot
    /// change server state — what lets the client retry transparently.
    pub fn is_idempotent(&self) -> bool {
        !matches!(
            self,
            Request::StreamOpen { .. } | Request::StreamFeed { .. } | Request::StreamClose { .. }
        )
    }

    /// Serialize as one v2 request line (envelope + flat parameters).
    pub fn to_v2(&self, id: u64) -> Json {
        self.to_v2_traced(id, 0)
    }

    /// [`Request::to_v2`] with trace propagation: when `trace` is
    /// non-zero it is emitted as the envelope's `trace` field (the
    /// sender's span id), so the receiver's spans nest under it. A zero
    /// trace emits nothing — the line is byte-identical to `to_v2`.
    pub fn to_v2_traced(&self, id: u64, trace: u64) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("v", Json::Num(PROTOCOL_VERSION as f64)),
            ("id", Json::Num(id as f64)),
            ("type", Json::Str(self.type_name().to_string())),
        ];
        if trace != 0 {
            pairs.push(("trace", Json::Num(trace as f64)));
        }
        match self {
            Request::Ping
            | Request::Stats
            | Request::Apps
            | Request::ShardInfo
            | Request::Metrics
            | Request::TraceDump => {}
            Request::Match { series, config } => {
                pairs.push(("series", Json::nums(series)));
                pairs.push(("config", config_to_json(config)));
            }
            Request::Knn {
                series,
                k,
                config,
                allow_partial,
            } => {
                pairs.push(("series", Json::nums(series)));
                pairs.push(("k", Json::Num(*k as f64)));
                if let Some(c) = config {
                    pairs.push(("config", config_to_json(c)));
                }
                if *allow_partial {
                    pairs.push(("allow_partial", Json::Bool(true)));
                }
            }
            Request::KnnBatch {
                queries,
                k,
                config,
                allow_partial,
            } => {
                pairs.push((
                    "queries",
                    Json::arr(queries.iter().map(|q| Json::nums(q)).collect()),
                ));
                pairs.push(("k", Json::Num(*k as f64)));
                if let Some(c) = config {
                    pairs.push(("config", config_to_json(c)));
                }
                if *allow_partial {
                    pairs.push(("allow_partial", Json::Bool(true)));
                }
            }
            Request::StreamOpen {
                config,
                final_len,
                max_len,
                min_fraction,
                margin,
                min_samples,
            } => {
                if let Some(c) = config {
                    pairs.push(("config", config_to_json(c)));
                }
                if let Some(n) = final_len {
                    pairs.push(("final_len", Json::Num(*n as f64)));
                }
                if let Some(n) = max_len {
                    pairs.push(("max_len", Json::Num(*n as f64)));
                }
                if let Some(f) = min_fraction {
                    pairs.push(("min_fraction", Json::Num(*f)));
                }
                if let Some(m) = margin {
                    pairs.push(("margin", Json::Num(*m)));
                }
                if let Some(s) = min_samples {
                    pairs.push(("min_samples", Json::Num(*s as f64)));
                }
            }
            Request::StreamFeed {
                session,
                samples,
                progress,
            } => {
                pairs.push(("session", Json::Num(*session as f64)));
                pairs.push(("samples", Json::nums(samples)));
                if let Some(p) = progress {
                    pairs.push(("progress", Json::Num(*p)));
                }
            }
            Request::StreamPoll { session, k } => {
                pairs.push(("session", Json::Num(*session as f64)));
                pairs.push(("k", Json::Num(*k as f64)));
            }
            Request::StreamPollAll { k } => {
                pairs.push(("k", Json::Num(*k as f64)));
            }
            Request::StreamClose { session } => {
                pairs.push(("session", Json::Num(*session as f64)));
            }
            Request::StreamTune { session } => {
                pairs.push(("session", Json::Num(*session as f64)));
            }
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dyadic sample values so the JSON number round trip is bit-exact.
    fn series(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i % 17) as f64 / 16.0).collect()
    }

    fn sample_requests() -> Vec<Request> {
        let cfg = JobConfig::new(4, 2, 10.0, 20.0);
        vec![
            Request::Ping,
            Request::Stats,
            Request::Apps,
            Request::ShardInfo,
            Request::Metrics,
            Request::TraceDump,
            Request::Match {
                series: series(16),
                config: cfg,
            },
            Request::Knn {
                series: series(8),
                k: 3,
                config: None,
                allow_partial: false,
            },
            Request::Knn {
                series: series(8),
                k: 0,
                config: Some(cfg),
                allow_partial: true,
            },
            Request::KnnBatch {
                queries: vec![series(8), series(12)],
                k: 5,
                config: None,
                allow_partial: false,
            },
            Request::KnnBatch {
                queries: vec![series(4)],
                k: 1,
                config: Some(cfg),
                allow_partial: true,
            },
            Request::StreamOpen {
                config: Some(cfg),
                final_len: Some(64),
                max_len: None,
                min_fraction: Some(0.25),
                margin: Some(1.5),
                min_samples: Some(24),
            },
            Request::StreamOpen {
                config: None,
                final_len: None,
                max_len: Some(128),
                min_fraction: None,
                margin: None,
                min_samples: None,
            },
            Request::StreamFeed {
                session: 7,
                samples: series(5),
                progress: None,
            },
            Request::StreamFeed {
                session: 7,
                samples: series(5),
                progress: Some(0.25),
            },
            Request::StreamPoll { session: 7, k: 2 },
            Request::StreamPollAll { k: 4 },
            Request::StreamClose { session: 7 },
            Request::StreamTune { session: 7 },
        ]
    }

    #[test]
    fn v2_roundtrip_is_exact() {
        for (i, req) in sample_requests().into_iter().enumerate() {
            let line = req.to_v2(i as u64 + 1).to_string();
            let parsed = Json::parse(&line).unwrap();
            assert_eq!(parsed.get("v").and_then(Json::as_u64), Some(2), "case {i}");
            assert_eq!(
                parsed.get("id").and_then(Json::as_u64),
                Some(i as u64 + 1),
                "case {i}"
            );
            let back = Request::from_v2(&parsed).unwrap();
            assert_eq!(back, req, "case {i}: {line}");
        }
    }

    #[test]
    fn trace_field_is_optional_and_transparent() {
        let req = Request::KnnBatch {
            queries: vec![series(8)],
            k: 2,
            config: None,
            allow_partial: false,
        };
        // trace = 0 emits nothing: byte-identical to the untraced line.
        assert_eq!(req.to_v2_traced(3, 0).to_string(), req.to_v2(3).to_string());
        // A non-zero trace appears in the envelope and parses back to the
        // same request (the field belongs to the envelope, not the body).
        let line = req.to_v2_traced(3, 41).to_string();
        assert!(line.contains(r#""trace":41"#), "{line}");
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(Request::from_v2(&parsed).unwrap(), req);
    }

    #[test]
    fn allow_partial_is_optional_and_off_by_default() {
        // Absent on the wire parses as false, and false emits nothing —
        // the serialized line is byte-identical to a pre-degradation one.
        let base = Request::Knn {
            series: series(8),
            k: 2,
            config: None,
            allow_partial: false,
        };
        let line = base.to_v2(1).to_string();
        assert!(!line.contains("allow_partial"), "{line}");
        assert_eq!(Request::from_v2(&Json::parse(&line).unwrap()).unwrap(), base);

        // True rides the wire and round-trips.
        let partial = Request::Knn {
            series: series(8),
            k: 2,
            config: None,
            allow_partial: true,
        };
        let line = partial.to_v2(1).to_string();
        assert!(line.contains(r#""allow_partial":true"#), "{line}");
        assert_eq!(Request::from_v2(&Json::parse(&line).unwrap()).unwrap(), partial);
    }

    #[test]
    fn v1_and_v2_parse_agree_on_shared_commands() {
        let series_json = Json::nums(&series(8));
        let v1 = Json::obj(vec![
            ("cmd", Json::Str("knn".into())),
            ("series", series_json.clone()),
            ("k", Json::Num(3.0)),
        ]);
        let v2 = Json::obj(vec![
            ("v", Json::Num(2.0)),
            ("id", Json::Num(1.0)),
            ("type", Json::Str("knn".into())),
            ("series", series_json),
            ("k", Json::Num(3.0)),
        ]);
        assert_eq!(Request::from_v1(&v1).unwrap(), Request::from_v2(&v2).unwrap());
    }

    #[test]
    fn k_clamps_differ_between_envelopes_only_at_zero() {
        let mk = |k: f64| {
            Json::obj(vec![
                ("cmd", Json::Str("knn".into())),
                ("type", Json::Str("knn".into())),
                ("series", Json::nums(&series(8))),
                ("k", Json::Num(k)),
            ])
        };
        // v1 forces k >= 1 (legacy behavior, byte-compat pinned).
        match Request::from_v1(&mk(0.0)).unwrap() {
            Request::Knn { k, .. } => assert_eq!(k, 1),
            other => panic!("{other:?}"),
        }
        // v2 lets k = 0 through: the server answers with an empty result.
        match Request::from_v2(&mk(0.0)).unwrap() {
            Request::Knn { k, .. } => assert_eq!(k, 0),
            other => panic!("{other:?}"),
        }
        // Both cap at MAX_K.
        let parsers: [fn(&Json) -> Result<Request, ServerError>; 2] =
            [Request::from_v1, Request::from_v2];
        for parse in parsers {
            match parse(&mk(1e6)).unwrap() {
                Request::Knn { k, .. } => assert_eq!(k, MAX_K),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn validation_errors_keep_legacy_messages() {
        let cases = [
            (r#"{"cmd":"match"}"#, "missing series"),
            (r#"{"cmd":"knn","series":[1,2]}"#, "series too short"),
            (r#"{"cmd":"knn_batch"}"#, "missing queries"),
            (r#"{"cmd":"knn_batch","queries":[]}"#, "empty queries"),
            (
                r#"{"cmd":"knn_batch","queries":[[1,2]]}"#,
                "query 0: series too short",
            ),
            (r#"{"cmd":"stream_feed","samples":[1]}"#, "missing session id"),
            (
                r#"{"cmd":"stream_feed","session":1,"samples":[]}"#,
                "empty samples",
            ),
            (r#"{"cmd":"nope"}"#, "unknown cmd"),
        ];
        for (line, want) in cases {
            let req = Json::parse(line).unwrap();
            let err = Request::from_v1(&req).unwrap_err();
            assert_eq!(err.message, want, "line={line}");
        }
    }

    #[test]
    fn oversized_batches_are_too_large() {
        let q: Vec<Json> = (0..MAX_KNN_BATCH + 1)
            .map(|_| Json::nums(&series(4)))
            .collect();
        let req = Json::obj(vec![
            ("cmd", Json::Str("knn_batch".into())),
            ("queries", Json::arr(q)),
        ]);
        let err = Request::from_v1(&req).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
        assert!(err.message.contains("batch too large"), "{}", err.message);
    }

    #[test]
    fn idempotency_classification() {
        assert!(Request::Ping.is_idempotent());
        assert!(Request::TraceDump.is_idempotent(), "dumping is read-only, safe to retry");
        assert!(Request::StreamPoll { session: 1, k: 1 }.is_idempotent());
        assert!(!Request::StreamFeed {
            session: 1,
            samples: vec![0.5],
            progress: None
        }
        .is_idempotent());
        assert!(!Request::StreamClose { session: 1 }.is_idempotent());
        assert!(
            Request::StreamTune { session: 1 }.is_idempotent(),
            "tuning advice is read-only, safe to retry"
        );
    }

    #[test]
    fn feed_progress_is_optional_and_off_the_wire_when_absent() {
        let bare = Request::StreamFeed {
            session: 3,
            samples: series(5),
            progress: None,
        };
        let line = bare.to_v2(1).to_string();
        assert!(!line.contains("progress"), "{line}");
        assert_eq!(Request::from_v2(&Json::parse(&line).unwrap()).unwrap(), bare);

        let with = Request::StreamFeed {
            session: 3,
            samples: series(5),
            progress: Some(0.5),
        };
        let line = with.to_v2(1).to_string();
        assert!(line.contains(r#""progress":0.5"#), "{line}");
        assert_eq!(Request::from_v2(&Json::parse(&line).unwrap()).unwrap(), with);
    }
}
