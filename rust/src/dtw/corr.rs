//! The paper's similarity measure (eqn. 3): correlation coefficient between
//! the query series `X` and the DTW-warped reference `Y'`, as a percentage.

use super::banded::dtw_banded;
use super::full::{dtw, DtwResult};

/// Paper's acceptance threshold: `CORR(X, Y') >= 0.9` counts as a match.
pub const MATCH_THRESHOLD: f64 = 90.0;

/// Similarity in percent between `x` and `y` (order follows the paper:
/// warp the *reference* `y` onto the *query* `x`'s time axis, then
/// correlate). Returns a value in `[0, 100]` — negative correlations clamp
/// to 0 ("no similarity at all").
pub fn similarity_percent(x: &[f64], y: &[f64]) -> f64 {
    let r = dtw(x, y);
    similarity_from_alignment(&r, x, y)
}

/// Similarity with the production pipeline's Sakoe–Chiba constraint
/// (10% band): restricting pathological warps is what lets the measure
/// discriminate configuration sets (see DESIGN.md §Deviations).
pub fn similarity_percent_banded(x: &[f64], y: &[f64]) -> f64 {
    let r = dtw_banded(x, y, super::band_radius(x.len(), y.len()));
    similarity_from_alignment(&r, x, y)
}

/// Similarity given an existing alignment (avoids recomputing DTW when the
/// runtime already produced the traceback).
pub fn similarity_from_alignment(r: &DtwResult, x: &[f64], y: &[f64]) -> f64 {
    let warped = r.warp_onto_x(y, x.len());
    let c = crate::util::stats::pearson(x, &warped);
    (c.max(0.0) * 100.0).min(100.0)
}

/// True when the similarity clears the paper's 90% acceptance threshold.
pub fn is_match(sim_percent: f64) -> bool {
    sim_percent >= MATCH_THRESHOLD
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn identical_series_full_similarity() {
        let x: Vec<f64> = (0..120).map(|i| 0.5 + 0.5 * ((i as f64) * 0.1).sin()).collect();
        let s = similarity_percent(&x, &x);
        assert!((s - 100.0).abs() < 1e-9, "s={s}");
        assert!(is_match(s));
    }

    #[test]
    fn stretched_copy_still_high() {
        // Same shape, different length (time-stretched) → DTW should absorb
        // the stretch and leave a high correlation.
        let x: Vec<f64> = (0..100).map(|i| 0.5 + 0.4 * ((i as f64) * 0.10).sin()).collect();
        let y: Vec<f64> = (0..140).map(|i| 0.5 + 0.4 * ((i as f64 * 100.0 / 140.0) * 0.10).sin()).collect();
        let s = similarity_percent(&x, &y);
        assert!(s > 95.0, "s={s}");
    }

    #[test]
    fn unrelated_shapes_low() {
        let mut g = Pcg32::new(30, 1);
        // Rising ramp vs white noise.
        let x: Vec<f64> = (0..150).map(|i| i as f64 / 150.0).collect();
        let y: Vec<f64> = (0..150).map(|_| g.f64()).collect();
        let s = similarity_percent(&x, &y);
        assert!(s < MATCH_THRESHOLD, "s={s}");
    }

    #[test]
    fn anti_correlated_clamps_to_zero() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..50).map(|i| 50.0 - i as f64).collect();
        // DTW will warp heavily, but any residual negative corr clamps at 0.
        let s = similarity_percent(&x, &y);
        assert!((0.0..=100.0).contains(&s));
    }

    #[test]
    fn symmetric_enough_for_same_length_shapes() {
        // The measure is not symmetric by construction (warp direction), but
        // for same-shape series it should be close both ways.
        let x: Vec<f64> = (0..100).map(|i| 0.5 + 0.3 * ((i as f64) * 0.07).cos()).collect();
        let y: Vec<f64> = (0..100).map(|i| 0.5 + 0.3 * (((i + 4) as f64) * 0.07).cos()).collect();
        let a = similarity_percent(&x, &y);
        let b = similarity_percent(&y, &x);
        assert!((a - b).abs() < 5.0, "a={a} b={b}");
        assert!(a > MATCH_THRESHOLD);
    }

    #[test]
    fn range_always_valid() {
        let mut g = Pcg32::new(31, 2);
        for _ in 0..25 {
            let x: Vec<f64> = (0..(2 + g.below(60) as usize)).map(|_| g.f64()).collect();
            let y: Vec<f64> = (0..(2 + g.below(60) as usize)).map(|_| g.f64()).collect();
            let s = similarity_percent(&x, &y);
            assert!((0.0..=100.0).contains(&s), "s={s}");
        }
    }
}
