//! Session multiplexer: many concurrent live streams behind the blocking
//! server.
//!
//! The registry lock is held only to look up / insert / remove a session
//! slot; per-session work (bound refreshes, prefix DPs) runs under that
//! session's own lock, so concurrent connections feeding *different*
//! sessions never serialize. Sessions left behind by dead clients are
//! swept by [`SessionManager::reap_idle`], which the server calls from its
//! read-timeout tick.
//!
//! A manager built with [`SessionManager::with_tracer`] opens one
//! **session-lifetime span** per registration: a `session` root that stays
//! open for the whole stream, collects per-feed/poll child spans and
//! decision events through [`SessionManager::with_span`], and closes when
//! the slot is dropped — annotated `end=close` on an explicit
//! `stream_close`, `end=reap` when the idle sweeper collects it. A
//! day-long MapReduce job thus renders as one long bar with its feeds
//! nested inside, not as disconnected per-request blips.

use super::session::{StreamDecision, StreamSession, TopEntry};
use crate::index::IndexedDb;
use crate::trace::{Span, TraceHandle};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Slot {
    session: Mutex<StreamSession>,
    touched: Mutex<Instant>,
    /// Session-lifetime span: opened at registration, ended when the slot
    /// drops (close, reap, or the last straggling reference going away).
    span: Span,
}

/// Registry of live [`StreamSession`]s keyed by server-assigned id.
#[derive(Default)]
pub struct SessionManager {
    next: AtomicU64,
    slots: Mutex<HashMap<u64, Arc<Slot>>>,
    /// Span source for session-lifetime spans; the default (disabled)
    /// handle makes every session span inert.
    tracer: TraceHandle,
}

/// One live session's anytime snapshot, as returned by
/// [`SessionManager::poll_all`].
#[derive(Debug, Clone)]
pub struct SessionPoll {
    pub id: u64,
    pub observed: usize,
    pub live_candidates: usize,
    pub culled: u64,
    pub top: Vec<TopEntry>,
    pub decision: Option<StreamDecision>,
}

impl SessionManager {
    pub fn new() -> SessionManager {
        SessionManager::default()
    }

    /// A manager whose sessions get lifetime spans from `tracer` (the
    /// server shares its request tracer here, so session bars and request
    /// trees land in one timeline).
    pub fn with_tracer(tracer: TraceHandle) -> SessionManager {
        SessionManager {
            tracer,
            ..SessionManager::default()
        }
    }

    /// Register a session, returning its id.
    pub fn open(&self, session: StreamSession) -> u64 {
        // relaxed: monotone id counter — uniqueness is all that matters,
        // no other memory is published through it.
        let id = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        // Lifetime span, sampled on the session id so a 1-in-N policy
        // keeps whole sessions, never half of one.
        let span = self.tracer.root_sampled("session", 0, id);
        span.event("session", id);
        let slot = Arc::new(Slot {
            session: Mutex::new(session),
            // Idle-reaping bookkeeping, compared only against other
            // Instants from this registry. lint: allow(no-raw-clock)
            touched: Mutex::new(Instant::now()),
            span,
        });
        self.slots.lock().expect("session registry").insert(id, slot);
        id
    }

    /// Run `f` against a session, refreshing its idle clock.
    pub fn with<T>(&self, id: u64, f: impl FnOnce(&mut StreamSession) -> T) -> Result<T> {
        self.with_span(id, |s, _| f(s))
    }

    /// [`SessionManager::with`], also handing `f` the session's lifetime
    /// span so callers can hang per-feed/poll child spans and decision
    /// events on it (inert when the manager is untraced or the session
    /// was sampled out).
    pub fn with_span<T>(
        &self,
        id: u64,
        f: impl FnOnce(&mut StreamSession, &Span) -> T,
    ) -> Result<T> {
        let slot = self
            .slots
            .lock()
            .expect("session registry")
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow!("unknown session {id}"))?;
        // lint: allow(no-raw-clock) same registry-internal idle clock.
        *slot.touched.lock().expect("session clock") = Instant::now();
        let mut session = slot.session.lock().expect("session state");
        Ok(f(&mut session, &slot.span))
    }

    /// Remove a session, returning its final state.
    pub fn close(&self, id: u64) -> Result<StreamSession> {
        let slot = self
            .slots
            .lock()
            .expect("session registry")
            .remove(&id)
            .ok_or_else(|| anyhow!("unknown session {id}"))?;
        slot.span.note("end", "close");
        match Arc::try_unwrap(slot) {
            Ok(s) => Ok(s.session.into_inner().expect("session state")),
            // Another connection is mid-call on this session; hand the
            // caller a snapshot and let the straggler's Arc drop.
            Err(arc) => Ok(arc.session.lock().expect("session state").clone()),
        }
    }

    /// Poll every live session in one pass: the batched form of per-session
    /// `stream_poll`, so a dashboard (or the tuner watching a whole fleet)
    /// pays one request instead of one per session. Snapshots are taken
    /// under each session's own lock — concurrent feeds never serialize
    /// against each other — and returned sorted by session id for a
    /// deterministic wire order. Unlike [`SessionManager::with`], polling
    /// is read-only and does **not** refresh idle clocks: a fleet
    /// dashboard polling forever must not keep abandoned sessions alive
    /// past [`SessionManager::reap_idle`]'s deadline.
    pub fn poll_all(&self, idx: &IndexedDb, k: usize) -> Vec<SessionPoll> {
        // Snapshot the registry first; per-session locks are taken outside
        // the registry lock so a slow session cannot block open/close.
        let slots: Vec<(u64, Arc<Slot>)> = self
            .slots
            .lock()
            .expect("session registry")
            .iter()
            .map(|(&id, slot)| (id, Arc::clone(slot)))
            .collect();
        let mut polls: Vec<SessionPoll> = slots
            .into_iter()
            .map(|(id, slot)| {
                let s = slot.session.lock().expect("session state");
                SessionPoll {
                    id,
                    observed: s.observed(),
                    live_candidates: s.live_candidates(),
                    culled: s.stats().culled,
                    top: s.top(idx, k),
                    decision: s.decision().cloned(),
                }
            })
            .collect();
        polls.sort_by_key(|p| p.id);
        polls
    }

    /// Drop sessions idle for longer than `max_idle`; returns how many.
    /// A reaped session's lifetime span closes annotated `end=reap`, so
    /// abandoned streams are distinguishable from clean closes in a dump.
    pub fn reap_idle(&self, max_idle: Duration) -> usize {
        let mut slots = self.slots.lock().expect("session registry");
        let before = slots.len();
        slots.retain(|_, slot| {
            let keep = slot
                .touched
                .lock()
                .map(|t| t.elapsed() <= max_idle)
                .unwrap_or(false);
            if !keep {
                slot.span.note("end", "reap");
            }
            keep
        });
        before - slots.len()
    }

    /// Live session ids, sorted. Sessions are addressed by id, not by
    /// connection — a client may open on one TCP connection and feed,
    /// poll or close from another (reconnects are routine for day-long
    /// jobs) — so the id list is the whole observable registry state and
    /// is what `shard_info` reports.
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .slots
            .lock()
            .expect("session registry")
            .keys()
            .copied()
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Live session count.
    pub fn len(&self) -> usize {
        self.slots.lock().expect("session registry").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::IndexedDb;
    use crate::streaming::{DecisionPolicy, FinalLen};

    fn session() -> StreamSession {
        let idx = IndexedDb::new();
        StreamSession::open(&idx, None, FinalLen::AtMost(512), DecisionPolicy::default())
    }

    #[test]
    fn open_with_close_roundtrip() {
        let mgr = SessionManager::new();
        let idx = IndexedDb::new();
        let a = mgr.open(session());
        let b = mgr.open(session());
        assert_ne!(a, b);
        assert_eq!(mgr.len(), 2);
        assert_eq!(mgr.ids(), vec![a, b]);
        mgr.with(a, |s| s.push(&idx, &[0.1, 0.2])).unwrap();
        let closed = mgr.close(a).unwrap();
        assert_eq!(closed.observed(), 2);
        assert_eq!(mgr.len(), 1);
        assert!(mgr.with(a, |s| s.observed()).is_err(), "closed id resolves");
        assert!(mgr.close(a).is_err());
        mgr.close(b).unwrap();
        assert!(mgr.is_empty());
    }

    #[test]
    fn reaping_spares_touched_sessions() {
        let mgr = SessionManager::new();
        let id = mgr.open(session());
        let _stale = mgr.open(session());
        std::thread::sleep(Duration::from_millis(30));
        mgr.with(id, |_| ()).unwrap(); // refresh one clock
        let reaped = mgr.reap_idle(Duration::from_millis(20));
        assert_eq!(reaped, 1);
        assert_eq!(mgr.len(), 1);
        assert!(mgr.with(id, |_| ()).is_ok());
    }

    #[test]
    fn poll_all_snapshots_every_session_sorted() {
        let mgr = SessionManager::new();
        let idx = IndexedDb::new();
        let a = mgr.open(session());
        let b = mgr.open(session());
        let c = mgr.open(session());
        mgr.with(b, |s| {
            s.push(&idx, &[0.1, 0.2, 0.3]);
        })
        .unwrap();
        let polls = mgr.poll_all(&idx, 3);
        assert_eq!(polls.len(), 3);
        assert!(polls.windows(2).all(|w| w[0].id < w[1].id), "ids not sorted");
        assert_eq!(polls.iter().find(|p| p.id == a).unwrap().observed, 0);
        assert_eq!(polls.iter().find(|p| p.id == b).unwrap().observed, 3);
        assert!(polls.iter().all(|p| p.decision.is_none()));
        mgr.close(c).unwrap();
        assert_eq!(mgr.poll_all(&idx, 1).len(), 2);

        // Polling is read-only: it must NOT refresh idle clocks, so a
        // permanently polling dashboard cannot keep dead sessions alive.
        std::thread::sleep(Duration::from_millis(30));
        mgr.poll_all(&idx, 1);
        assert_eq!(mgr.reap_idle(Duration::from_millis(20)), 2);
        assert!(mgr.is_empty());
    }

    #[test]
    fn sessions_get_lifetime_spans_closed_by_close_or_reap() {
        use crate::trace::{InMemoryTracker, VirtualClock};

        let tracker = Arc::new(InMemoryTracker::new());
        let tracer = TraceHandle::with_clock(
            Arc::clone(&tracker) as Arc<dyn crate::trace::Tracker>,
            Arc::new(VirtualClock::new(10)),
        );
        let mgr = SessionManager::with_tracer(tracer);
        let idx = IndexedDb::new();

        let a = mgr.open(session());
        let b = mgr.open(session());
        // Feed work hangs child spans and events off the lifetime span.
        mgr.with_span(a, |s, span| {
            let feed = span.child("feed");
            s.push(&idx, &[0.1, 0.2]);
            feed.event("samples", 2);
            span.event("samples_seen", s.observed() as u64);
        })
        .unwrap();

        // Clean close: span ends annotated end=close.
        mgr.close(a).unwrap();
        let spans = tracker.find("session");
        assert_eq!(spans.len(), 2, "one lifetime span per open");
        let sa = spans.iter().find(|s| s.events.contains(&("session", a))).unwrap();
        assert!(sa.end_ns > sa.start_ns, "closed session's span is ended");
        assert_eq!(sa.notes, vec![("end", "close".to_string())]);
        assert_eq!(sa.events, vec![("session", a), ("samples_seen", 2)]);
        let feeds = tracker.find("feed");
        assert_eq!(feeds.len(), 1);
        assert_eq!(feeds[0].parent, sa.id, "feed nests under the session span");

        // Abandoned session: the reaper ends the span annotated end=reap.
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(mgr.reap_idle(Duration::from_millis(20)), 1);
        let spans = tracker.find("session");
        let sb = spans.iter().find(|s| s.events.contains(&("session", b))).unwrap();
        assert!(sb.end_ns > sb.start_ns, "reaped session's span is ended");
        assert_eq!(sb.notes, vec![("end", "reap".to_string())]);

        // An untraced manager stays inert end to end.
        let plain = SessionManager::new();
        let id = plain.open(session());
        plain.with_span(id, |_, span| assert!(!span.active())).unwrap();
    }

    #[test]
    fn concurrent_feeds_do_not_lose_samples() {
        let mgr = std::sync::Arc::new(SessionManager::new());
        let idx = std::sync::Arc::new(IndexedDb::new());
        let id = mgr.open(session());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let mgr = std::sync::Arc::clone(&mgr);
                let idx = std::sync::Arc::clone(&idx);
                s.spawn(move || {
                    for _ in 0..50 {
                        mgr.with(id, |sess| {
                            sess.push(&idx, &[0.5]);
                        })
                        .unwrap();
                    }
                });
            }
        });
        assert_eq!(mgr.with(id, |s| s.observed()).unwrap(), 200);
    }
}
