//! Online final-length prediction from a partial run.
//!
//! A streaming session's band geometry (see
//! [`crate::streaming::prefix_lb`]) is only as tight as its
//! [`FinalLen`] hint, and mid-run the final capture length is unknown.
//! This module closes that gap: a [`LengthPredictor`] ingests
//! `(progress, elapsed)` observations from the running job — the
//! simulator's [`crate::simulator::SimTick::progress`] task fraction, or
//! a client-reported fraction over the wire — and extrapolates the final
//! length (at the 1 Hz SysStat rate, seconds and samples coincide).
//!
//! The estimate is a least-squares polynomial fit of elapsed time over
//! progress (degree 2 once enough points exist, degree 1 before that,
//! with a plain `elapsed/progress` ratio as the numerical fallback),
//! evaluated at progress 1. Around it the predictor keeps a confidence
//! band built from two conservative edges — the elapsed time itself from
//! below (a job never finishes before *now*) and the estimate widened by
//! a slack proportional to the unobserved remainder from above — and
//! *intersects* the band across updates, so the interval tightens
//! monotonically and keeps covering the final length as long as each
//! individual band does. Tight intervals promote the session hint to
//! [`FinalLen::Known`]; wide ones still narrow its [`FinalLen::AtMost`]
//! geometry. Short or low-progress prefixes yield no prediction at all
//! (`rust/tests/properties.rs` and the tests below pin all three
//! behaviours).

use crate::streaming::FinalLen;

/// Fewest observations before any prediction is attempted.
const MIN_POINTS: usize = 4;

/// Minimum observed completion fraction before extrapolating: below it
/// the fit has essentially no leverage and any interval would be noise.
const MIN_PROGRESS: f64 = 0.05;

/// Switch from a linear to a quadratic fit at this many points (a
/// quadratic needs enough support not to chase its own tail).
const QUADRATIC_AT: usize = 8;

/// Relative half-width of one update's band per unit of *unobserved*
/// progress: at fraction `p` the band spans `estimate * (1 ± SLACK *
/// (1/p - 1))`, so it is wide early and collapses as `p → 1`.
const SLACK: f64 = 0.75;

/// Interval widths at or below `max(KNOWN_ABS_WIDTH, estimate *
/// KNOWN_REL_WIDTH)` promote the hint to `FinalLen::Known`.
const KNOWN_ABS_WIDTH: f64 = 2.0;
const KNOWN_REL_WIDTH: f64 = 0.06;

/// A predicted final length with its confidence interval (seconds ≙
/// samples at 1 Hz).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// Point estimate of the final length.
    pub estimate: f64,
    /// Conservative lower edge (never below the elapsed time observed).
    pub lo: f64,
    /// Conservative upper edge.
    pub hi: f64,
}

impl Prediction {
    /// Interval width.
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }
}

/// Online predictor of a running job's final capture length.
#[derive(Debug, Clone, Default)]
pub struct LengthPredictor {
    /// `(progress, elapsed_secs)` observations, progress in `(0, 1]`,
    /// kept monotone in both coordinates.
    points: Vec<(f64, f64)>,
    /// Running intersection of every per-update confidence band.
    band: Option<(f64, f64)>,
}

impl LengthPredictor {
    pub fn new() -> LengthPredictor {
        LengthPredictor::default()
    }

    /// Observations accepted so far.
    pub fn observations(&self) -> usize {
        self.points.len()
    }

    /// Latest accepted completion fraction (`0.0` before any).
    pub fn progress(&self) -> f64 {
        match self.points.last() {
            Some(&(p, _)) => p,
            None => 0.0,
        }
    }

    /// Ingest one `(progress, elapsed_secs)` observation. Non-finite,
    /// non-positive-progress, or non-monotone samples (stale or
    /// reordered feeds) are dropped — ignoring them can only cost
    /// tightness, never correctness.
    pub fn observe(&mut self, progress: f64, elapsed_secs: f64) {
        if !progress.is_finite() || !elapsed_secs.is_finite() {
            return;
        }
        if progress <= 0.0 || elapsed_secs < 0.0 {
            return;
        }
        let progress = progress.min(1.0);
        if let Some(&(lp, le)) = self.points.last() {
            if progress < lp || elapsed_secs < le {
                return;
            }
        }
        self.points.push((progress, elapsed_secs));
        self.refresh();
    }

    /// The current prediction, or `None` while the prefix is too short
    /// (fewer than [`MIN_POINTS`] observations or progress below
    /// [`MIN_PROGRESS`]).
    pub fn predict(&self) -> Option<Prediction> {
        let (p, _) = *self.points.last()?;
        if self.points.len() < MIN_POINTS || p < MIN_PROGRESS {
            return None;
        }
        let (lo, hi) = self.band?;
        let estimate = self.extrapolate()?.clamp(lo, hi);
        Some(Prediction { estimate, lo, hi })
    }

    /// Convert the current prediction into a final-length hint for a
    /// streaming session, capped at `cap` samples. `Known` is issued
    /// only once the interval is tight; a wide interval still narrows
    /// the session's `AtMost` geometry. `None` means "keep whatever
    /// hint you have".
    pub fn final_len_hint(&self, cap: usize) -> Option<FinalLen> {
        let pred = self.predict()?;
        let tight = pred.width() <= (pred.estimate * KNOWN_REL_WIDTH).max(KNOWN_ABS_WIDTH);
        if tight {
            let est = pred.estimate.round().max(1.0) as usize;
            Some(FinalLen::Known(est.min(cap)))
        } else {
            let hi = pred.hi.ceil().max(1.0) as usize;
            Some(FinalLen::AtMost(hi.min(cap)))
        }
    }

    /// Point-extrapolate the final length from the fit (clamped from
    /// below by the elapsed time — a job never finishes before now).
    fn extrapolate(&self) -> Option<f64> {
        let (p, elapsed) = *self.points.last()?;
        let ratio = elapsed / p;
        let deg = if self.points.len() >= QUADRATIC_AT { 2 } else { 1 };
        let est = match polyfit_at_one(&self.points, deg) {
            Some(v) if v.is_finite() => v,
            _ => ratio,
        };
        Some(est.max(elapsed))
    }

    /// Recompute this update's confidence band and intersect it with the
    /// running one. The intersection keeps `lo` non-decreasing and `hi`
    /// non-increasing while staying non-empty, which is exactly the
    /// monotone-tightening guarantee the property tests pin.
    fn refresh(&mut self) {
        let Some(est) = self.extrapolate() else {
            return;
        };
        let Some(&(p, elapsed)) = self.points.last() else {
            return;
        };
        let rel = SLACK * (1.0 / p - 1.0);
        let lo = elapsed.max(est * (1.0 - rel));
        let hi = (est * (1.0 + rel) + 1.0).max(lo);
        self.band = Some(match self.band {
            None => (lo, hi),
            Some((bl, bh)) => {
                let l = bl.max(lo).min(bh);
                let h = bh.min(hi).max(l);
                (l, h)
            }
        });
    }
}

/// Least-squares polynomial fit of `y` over `x` of degree `deg` (≤ 2),
/// evaluated at `x = 1` (the sum of the coefficients). Solves the normal
/// equations by Gaussian elimination with partial pivoting; returns
/// `None` when the system is underdetermined or numerically singular.
fn polyfit_at_one(points: &[(f64, f64)], deg: usize) -> Option<f64> {
    let n = deg + 1;
    if n > 3 || points.len() < n {
        return None;
    }
    let mut a = [[0.0f64; 3]; 3];
    let mut b = [0.0f64; 3];
    for &(x, y) in points {
        let xs = [1.0, x, x * x];
        for i in 0..n {
            for j in 0..n {
                a[i][j] += xs[i] * xs[j];
            }
            b[i] += xs[i] * y;
        }
    }
    for col in 0..n {
        let mut piv = col;
        for row in col + 1..n {
            if a[row][col].abs() > a[piv][col].abs() {
                piv = row;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut c = [0.0f64; 3];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * c[k];
        }
        c[row] = acc / a[row][row];
    }
    Some(c[..n].iter().sum())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::noise::NoiseModel;
    use crate::simulator::job::JobConfig;
    use crate::simulator::profile_run;
    use crate::workloads::AppId;

    #[test]
    fn intervals_tighten_and_cover_on_simulator_runs() {
        // Noise-free simulator captures with an honest progress signal:
        // the interval must cover the true final length at every prefix
        // and only ever tighten.
        for (app, cfg) in [
            (AppId::WordCount, JobConfig::new(4, 2, 10.0, 40.0)),
            (AppId::TeraSort, JobConfig::new(6, 3, 10.0, 60.0)),
            (AppId::Grep, JobConfig::new(2, 1, 16.0, 30.0)),
        ] {
            let res = profile_run(app, &cfg, &NoiseModel::none(), 9);
            let truth = res.cpu_clean.len() as f64;
            let mut pred = LengthPredictor::new();
            let mut last: Option<Prediction> = None;
            for i in 1..=res.cpu_clean.len() {
                let t = i as f64;
                pred.observe(t / truth, t);
                let Some(p) = pred.predict() else { continue };
                assert!(
                    p.lo <= truth + 1e-6 && truth <= p.hi + 1e-6,
                    "{app:?}: [{}, {}] misses truth {truth} at t={t}",
                    p.lo,
                    p.hi
                );
                if let Some(q) = last {
                    assert!(
                        p.lo >= q.lo - 1e-9 && p.hi <= q.hi + 1e-9,
                        "{app:?}: interval widened at t={t}: [{}, {}] after [{}, {}]",
                        p.lo,
                        p.hi,
                        q.lo,
                        q.hi
                    );
                }
                last = Some(p);
            }
            let p = last.expect("a full run must yield predictions");
            assert!(
                (p.estimate - truth).abs() <= 0.1 * truth + 2.0,
                "{app:?}: estimate {} far from {truth}",
                p.estimate
            );
        }
    }

    #[test]
    fn short_prefixes_degrade_gracefully_to_at_most() {
        let mut p = LengthPredictor::new();
        assert!(p.predict().is_none());
        assert!(p.final_len_hint(512).is_none());
        p.observe(0.01, 2.0);
        p.observe(0.02, 4.0);
        p.observe(0.03, 6.0);
        assert!(p.predict().is_none(), "below MIN_POINTS");
        p.observe(0.04, 8.0);
        assert!(p.predict().is_none(), "progress below MIN_PROGRESS");
        p.observe(0.06, 12.0);
        let hint = p.final_len_hint(512).expect("enough evidence now");
        assert!(
            matches!(hint, FinalLen::AtMost(_)),
            "wide early interval must stay AtMost: {hint:?}"
        );
    }

    #[test]
    fn tight_intervals_promote_to_known() {
        let mut p = LengthPredictor::new();
        let truth = 100.0;
        for i in 1..=99 {
            let t = i as f64;
            p.observe(t / truth, t);
        }
        match p.final_len_hint(1 << 16) {
            Some(FinalLen::Known(n)) => {
                assert!((n as f64 - truth).abs() <= KNOWN_ABS_WIDTH, "Known({n})")
            }
            other => panic!("expected a Known hint, got {other:?}"),
        }
    }

    #[test]
    fn hint_respects_the_cap() {
        let mut p = LengthPredictor::new();
        for i in 1..=10 {
            // 6% progress at t=600: the extrapolated length is ~10_000.
            p.observe(0.006 * i as f64, 60.0 * i as f64);
        }
        match p.final_len_hint(512) {
            Some(FinalLen::AtMost(n)) => assert_eq!(n, 512),
            other => panic!("expected a capped AtMost, got {other:?}"),
        }
    }

    #[test]
    fn hostile_inputs_are_ignored() {
        let mut p = LengthPredictor::new();
        p.observe(f64::NAN, 1.0);
        p.observe(0.5, f64::INFINITY);
        p.observe(-0.1, 1.0);
        p.observe(0.0, 1.0);
        p.observe(0.5, -3.0);
        assert_eq!(p.observations(), 0);
        p.observe(0.5, 10.0);
        p.observe(0.4, 12.0); // progress went backwards: stale, dropped
        p.observe(0.6, 8.0); // elapsed went backwards: stale, dropped
        assert_eq!(p.observations(), 1);
        assert_eq!(p.progress(), 0.5);
    }

    #[test]
    fn polyfit_recovers_exact_polynomials() {
        // y = 3 + 2x  →  value at 1 is 5.
        let line: Vec<(f64, f64)> = (1..=6).map(|i| {
            let x = i as f64 * 0.1;
            (x, 3.0 + 2.0 * x)
        }).collect();
        let v = polyfit_at_one(&line, 1).expect("well-posed");
        assert!((v - 5.0).abs() < 1e-9, "{v}");
        // y = 1 + x + 4x²  →  value at 1 is 6.
        let quad: Vec<(f64, f64)> = (1..=9).map(|i| {
            let x = i as f64 * 0.1;
            (x, 1.0 + x + 4.0 * x * x)
        }).collect();
        let v = polyfit_at_one(&quad, 2).expect("well-posed");
        assert!((v - 6.0).abs() < 1e-9, "{v}");
        // Underdetermined and degenerate systems decline.
        assert!(polyfit_at_one(&line[..1], 1).is_none());
        assert!(polyfit_at_one(&[(0.5, 1.0), (0.5, 1.0), (0.5, 1.0)], 1).is_none());
    }
}
