//! Cluster model: nodes, cores, task slots, disk.

/// Static description of the simulated cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of worker nodes (pseudo-distributed: 1).
    pub nodes: usize,
    /// Physical cores per node (CPU capacity for processor sharing).
    pub cores_per_node: usize,
    /// Concurrent map task slots per node (Hadoop 0.20 default: 2).
    pub map_slots_per_node: usize,
    /// Concurrent reduce task slots per node (default: 2).
    pub reduce_slots_per_node: usize,
    /// Sequential disk bandwidth per node, MB/s (shared by its tasks).
    pub disk_mb_s: f64,
    /// Memory per node in MB (only used for the memory-pressure series).
    pub mem_mb: f64,
    /// Lognormal sigma of the per-task speed jitter (straggler model).
    pub task_jitter: f64,
    /// Enable speculative re-execution of straggling tasks.
    pub speculative: bool,
    /// Fraction of maps that must finish before reducers may start
    /// (mapred.reduce.slowstart.completed.maps; Hadoop 0.20 default 0.05).
    pub reduce_slowstart: f64,
}

impl ClusterConfig {
    /// The paper's testbed: one 2-core laptop (Dell Latitude E4300,
    /// 2.26 GHz Centrino, 4 GB RAM, 80 GB disk) running all daemons.
    pub fn pseudo_distributed() -> ClusterConfig {
        ClusterConfig {
            nodes: 1,
            cores_per_node: 2,
            map_slots_per_node: 2,
            reduce_slots_per_node: 2,
            disk_mb_s: 35.0,
            mem_mb: 4096.0,
            task_jitter: 0.06,
            speculative: false,
            reduce_slowstart: 0.05,
        }
    }

    /// An N-node cluster for the future-work scale experiment (§5).
    pub fn cluster(nodes: usize) -> ClusterConfig {
        ClusterConfig {
            nodes,
            cores_per_node: 4,
            map_slots_per_node: 4,
            reduce_slots_per_node: 2,
            disk_mb_s: 120.0,
            mem_mb: 8192.0,
            ..ClusterConfig::pseudo_distributed()
        }
    }

    pub fn total_map_slots(&self) -> usize {
        self.nodes * self.map_slots_per_node
    }

    pub fn total_reduce_slots(&self) -> usize {
        self.nodes * self.reduce_slots_per_node
    }

    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pseudo_distributed_matches_paper_testbed() {
        let c = ClusterConfig::pseudo_distributed();
        assert_eq!(c.nodes, 1);
        assert_eq!(c.total_cores(), 2);
        assert_eq!(c.total_map_slots(), 2);
        assert_eq!(c.total_reduce_slots(), 2);
        assert!(!c.speculative);
    }

    #[test]
    fn cluster_scales_slots() {
        let c = ClusterConfig::cluster(8);
        assert_eq!(c.total_map_slots(), 32);
        assert_eq!(c.total_cores(), 32);
    }
}
