//! Exim mainlog parsing — the paper's evaluation application (§5).
//!
//! Exim is a Unix mail transfer agent; its `exim_mainlog` records every
//! message transaction across several lines sharing a 16-character
//! transaction id (`1QpX2b-0003ab-C8`). The MapReduce job parses the log
//! into individual transactions keyed by that id — the map side is regex/
//! tokenisation bound over text, which is why the paper finds its CPU
//! pattern close to WordCount's and far from TeraSort's.

use super::traits::{CostModel, Emit, Workload};
use super::AppId;
use crate::util::rng::Rng;
use regex::bytes::Regex;

pub struct EximParse {
    id_re: Regex,
}

impl Default for EximParse {
    fn default() -> Self {
        EximParse {
            // Transaction id: 6 base62 chars, dash, 6 base62, dash, 2 base62.
            id_re: Regex::new(r"\b[0-9A-Za-z]{6}-[0-9A-Za-z]{6}-[0-9A-Za-z]{2}\b")
                .expect("static regex compiles"),
        }
    }
}

const BASE62: &[u8] = b"0123456789ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz";

fn txn_id(rng: &mut Rng) -> String {
    let mut id = String::with_capacity(16);
    for len in [6usize, 6, 2] {
        if !id.is_empty() {
            id.push('-');
        }
        for _ in 0..len {
            id.push(*rng.choose(BASE62) as char);
        }
    }
    id
}

const DOMAINS: &[&str] = &["example.com", "mail.net", "corp.org", "uni.edu", "isp.com.au"];
const USERS: &[&str] = &["alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi"];

impl EximParse {
    fn address(&self, rng: &mut Rng) -> String {
        format!("{}@{}", rng.choose(USERS), rng.choose(DOMAINS))
    }
}

impl Workload for EximParse {
    fn id(&self) -> AppId {
        AppId::EximParse
    }

    fn generate(&self, bytes: usize, rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::with_capacity(bytes + 256);
        let mut secs = 0u64;
        while out.len() < bytes {
            secs += rng.range_u64(1, 30);
            let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
            let ts = format!("2011-05-26 {h:02}:{m:02}:{s:02}");
            let id = txn_id(rng);
            let from = self.address(rng);
            let size = rng.range_u64(400, 40_000);
            out.extend_from_slice(
                format!("{ts} {id} <= {from} H=host.{} S={size}\n", rng.choose(DOMAINS)).as_bytes(),
            );
            // 1–3 deliveries.
            for _ in 0..rng.range_u64(1, 4) {
                let to = self.address(rng);
                out.extend_from_slice(
                    format!("{ts} {id} => {to} R=dnslookup T=remote_smtp\n").as_bytes(),
                );
            }
            out.extend_from_slice(format!("{ts} {id} Completed\n").as_bytes());
            // Occasional non-transaction noise line.
            if rng.chance(0.05) {
                out.extend_from_slice(
                    format!("{ts} SMTP connection from [10.0.0.{}]\n", rng.below(256)).as_bytes(),
                );
            }
        }
        out
    }

    fn map(&self, split: &[u8], emit: &mut Emit) {
        for line in split.split(|&b| b == b'\n') {
            if line.is_empty() {
                continue;
            }
            if let Some(m) = self.id_re.find(line) {
                emit(m.as_bytes(), line);
            }
        }
    }

    fn reduce(&self, key: &[u8], values: &[Vec<u8>], out: &mut Vec<u8>) {
        // Assemble the transaction: id header, then its lines sorted so the
        // arrival (<=) precedes deliveries (=>) precedes Completed.
        out.extend_from_slice(b"== ");
        out.extend_from_slice(key);
        out.push(b'\n');
        let mut lines: Vec<&Vec<u8>> = values.iter().collect();
        lines.sort_by_key(|l| {
            if find_sub(l, b" <= ").is_some() {
                0u8
            } else if find_sub(l, b" => ").is_some() {
                1
            } else {
                2
            }
        });
        for l in lines {
            out.extend_from_slice(l);
            out.push(b'\n');
        }
    }

    fn default_costs(&self) -> CostModel {
        // Regex-scan map (slightly dearer than WordCount's tokenizer), much
        // weaker "combining" (whole lines are kept), moderate reduce.
        CostModel {
            map_cpu_s_per_mb: 7.0,
            map_selectivity: 0.45,
            sort_cpu_s_per_mb: 0.7,
            reduce_cpu_s_per_mb: 1.3,
            reduce_selectivity: 1.05,
            startup_cpu_s: 1.2,
        }
    }
}

fn find_sub(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mapreduce::run_job;

    #[test]
    fn generated_log_parses_back() {
        let ex = EximParse::default();
        let mut rng = Rng::new(1);
        let data = ex.generate(16 * 1024, &mut rng);
        let text = std::str::from_utf8(&data).expect("ascii log");
        let arrivals = text.lines().filter(|l| l.contains(" <= ")).count();
        let completed = text.lines().filter(|l| l.contains(" Completed")).count();
        assert!(arrivals > 10);
        assert_eq!(arrivals, completed, "every txn completes");
    }

    #[test]
    fn transactions_grouped_by_id() {
        let ex = EximParse::default();
        let mut rng = Rng::new(2);
        let data = ex.generate(8 * 1024, &mut rng);
        let out = run_job(&ex, &data, 3, 2);
        let text: String = out
            .reducer_outputs
            .iter()
            .map(|o| String::from_utf8_lossy(o).into_owned())
            .collect();
        // Transaction blocks: each "== <id>" header is followed by an
        // arrival line first.
        let mut blocks = 0;
        let mut lines = text.lines().peekable();
        while let Some(line) = lines.next() {
            if let Some(id) = line.strip_prefix("== ") {
                blocks += 1;
                let first = lines.peek().expect("block has lines");
                assert!(first.contains(" <= "), "arrival first in block {id}");
            }
        }
        let arrivals = String::from_utf8_lossy(&data)
            .lines()
            .filter(|l| l.contains(" <= "))
            .count();
        // One block per transaction whose lines made it into the input
        // (the final transaction may be truncated mid-record by the byte
        // budget, so allow off-by-one).
        assert!(
            (blocks as i64 - arrivals as i64).abs() <= 1,
            "blocks={blocks} arrivals={arrivals}"
        );
    }

    #[test]
    fn noise_lines_dropped_by_map() {
        let ex = EximParse::default();
        let input = b"2011-05-26 01:02:03 SMTP connection from [10.0.0.4]\n\
                      2011-05-26 01:02:04 1QpX2b-0003ab-C8 <= bob@mail.net S=100\n"
            .to_vec();
        let mut pairs = 0;
        ex.map(&input, &mut |k, _| {
            assert_eq!(k, b"1QpX2b-0003ab-C8");
            pairs += 1;
        });
        assert_eq!(pairs, 1);
    }

    #[test]
    fn map_selectivity_moderate() {
        // Exim keeps whole lines (unlike WordCount's count-collapse): the
        // shuffle should be a large fraction of the input.
        let ex = EximParse::default();
        let mut rng = Rng::new(3);
        let data = ex.generate(32 * 1024, &mut rng);
        let out = run_job(&ex, &data, 2, 2);
        let ratio = out.counters.combine_output_bytes as f64 / data.len() as f64;
        assert!((0.5..=1.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn cost_model_plausible() {
        assert!(EximParse::default().default_costs().is_plausible());
    }
}
