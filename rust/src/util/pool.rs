//! Fixed-size thread pool and a scoped parallel map.
//!
//! Tokio is not available offline, and the coordinator's concurrency needs
//! are simple: fan a batch of independent comparisons / simulations over the
//! cores and join. `par_map` uses `std::thread::scope`, so closures can
//! borrow from the caller without `'static` bounds.
//!
//! Both primitives have exact panic semantics (pinned by the tests below):
//! a panic inside `par_map`'s closure propagates to the caller once the
//! scope joins, while a panic inside a [`ThreadPool`] job is *contained* —
//! the worker catches the unwind, bumps a counter (and the optional
//! [`PanicHook`], which the server wires to its metrics), and keeps
//! serving. The chunk-claim protocol is additionally model-checked by
//! `tools/loom-models`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default (logical cores, capped at 16 —
/// the batcher saturates PJRT well before that).
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(16)
}

/// Apply `f` to every element of `items` using up to `workers` threads,
/// preserving input order in the output. Panics in `f` propagate: the
/// scope joins every worker and resumes the unwind in the caller (other
/// workers finish the chunks they already claimed; no deadlock, no
/// poisoned slot is ever read).
///
/// Work is claimed in contiguous chunks through one atomic counter and
/// each chunk's results are written through its own disjoint `&mut` output
/// slice — the element hot path performs no locking at all (the seed
/// version paid a `Mutex` lock/unlock per element). Chunks are small
/// (`~8 ×` the worker count) so uneven per-element costs still balance.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(&f).collect();
    }
    // One claimable task per chunk: the input chunk zipped with the
    // matching disjoint window of the output. The Mutex is touched once
    // per *chunk* (take on claim), never per element.
    type ChunkTask<'s, T, R> = Mutex<Option<(&'s [T], &'s mut [Option<R>])>>;
    let chunk = n.div_ceil(workers * 8).max(1);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let tasks: Vec<ChunkTask<'_, T, R>> = items
        .chunks(chunk)
        .zip(out.chunks_mut(chunk))
        .map(|pair| Mutex::new(Some(pair)))
        .collect();
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                // relaxed: monotone claim counter — fetch_add alone makes
                // the claims disjoint, and the chunk's data is handed
                // over through the Mutex (model-checked in
                // tools/loom-models).
                let ci = next.fetch_add(1, Ordering::Relaxed);
                if ci >= tasks.len() {
                    break;
                }
                let (xs, slots) = tasks[ci]
                    .lock()
                    .expect("chunk slot")
                    .take()
                    .expect("chunk claimed once");
                for (x, slot) in xs.iter().zip(slots.iter_mut()) {
                    *slot = Some(f(x));
                }
            });
        }
    });
    drop(tasks);
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Shared callback invoked once per job panic a pool worker catches —
/// the server installs one that bumps its `Metrics` counter.
pub type PanicHook = Arc<dyn Fn() + Send + Sync>;

/// Long-lived FIFO thread pool for the serve loop: jobs are boxed
/// closures. Panicking jobs are caught and counted, never fatal — see
/// [`ThreadPool::panics`].
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    panics: Arc<AtomicU64>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    /// Spawn a pool with `workers` threads.
    pub fn new(workers: usize) -> Self {
        ThreadPool::with_panic_hook(workers, None)
    }

    /// [`ThreadPool::new`], additionally invoking `hook` every time a
    /// worker catches a panicking job.
    pub fn with_panic_hook(workers: usize, hook: Option<PanicHook>) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicU64::new(0));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                let hook = hook.clone();
                thread::Builder::new()
                    .name(format!("mrtuner-worker-{i}"))
                    .spawn(move || loop {
                        // The receiver guard is a temporary of this
                        // statement — dropped before the job runs, so a
                        // panicking job can never poison the rx lock.
                        let job = rx.lock().expect("pool rx lock").recv();
                        match job {
                            Ok(job) => {
                                // A worker must survive a hostile job:
                                // before this catch, every panic killed
                                // its worker and silently shrank the pool
                                // until execute() died on a channel with
                                // no receivers left.
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    // relaxed: monotone statistics counter.
                                    panics.fetch_add(1, Ordering::Relaxed);
                                    if let Some(hook) = &hook {
                                        hook();
                                    }
                                }
                            }
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            handles,
            panics,
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool is live")
            .send(Box::new(f))
            .expect("pool worker alive");
    }

    /// Jobs that panicked (and were caught) since the pool started.
    pub fn panics(&self) -> u64 {
        // relaxed: monotone statistics counter.
        self.panics.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..500).collect();
        let ys = par_map(&xs, 8, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_allows_borrows() {
        let base = vec![10u64, 20, 30];
        let xs = vec![0usize, 1, 2];
        let ys = par_map(&xs, 2, |&i| base[i] + 1);
        assert_eq!(ys, vec![11, 21, 31]);
    }

    #[test]
    fn par_map_chunking_covers_uneven_sizes() {
        // Sizes around the chunking boundaries: n < workers, n == workers,
        // n not divisible by the chunk count, n >> chunks.
        for n in [1usize, 3, 7, 8, 9, 63, 64, 65, 1000] {
            for workers in [2usize, 5, 16] {
                let xs: Vec<u64> = (0..n as u64).collect();
                let ys = par_map(&xs, workers, |&x| x + 1);
                assert_eq!(
                    ys,
                    xs.iter().map(|x| x + 1).collect::<Vec<_>>(),
                    "n={n} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(par_map(&none, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_panics_propagate() {
        // The doc claim, made real: a panic in `f` reaches the caller
        // (via the scope join) instead of deadlocking on a half-filled
        // slot vector or being swallowed.
        let xs: Vec<u64> = (0..64).collect();
        let result = catch_unwind(|| {
            par_map(&xs, 4, |&x| {
                assert!(x != 13, "injected failure");
                x
            })
        });
        assert!(result.is_err(), "panic in f must propagate to the caller");
    }

    #[test]
    fn par_map_survives_yield_injection() {
        // Seeded schedule perturbation for the chunk-claim path: random
        // yields inside `f` shuffle which worker claims which chunk.
        // Whatever the interleaving, every slot must be filled exactly
        // once and order preserved.
        let want: Vec<u64> = (0..257).map(|x| x * 3).collect();
        for seed in 0..8u64 {
            let xs: Vec<u64> = (0..257).collect();
            let ys = par_map(&xs, 4, |&x| {
                let mut g = Pcg32::new(seed, x);
                for _ in 0..g.below(4) {
                    thread::yield_now();
                }
                x * 3
            });
            assert_eq!(ys, want, "seed={seed}");
        }
    }

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins: all jobs must have completed.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        let caught = Arc::new(AtomicU64::new(0));
        {
            let c = Arc::clone(&caught);
            let hook: PanicHook = Arc::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
            // One worker = worst case: every later job depends on that
            // single thread outliving both hostile jobs.
            let pool = ThreadPool::with_panic_hook(1, Some(hook));
            pool.execute(|| panic!("hostile first job"));
            for i in 0..100u64 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                if i == 50 {
                    pool.execute(|| panic!("hostile mid-stream job"));
                }
            }
            // Drop joins; under the old kill-on-panic behavior this
            // deadlocked (no worker left) or execute() panicked.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100, "jobs lost after a panic");
        assert_eq!(caught.load(Ordering::SeqCst), 2, "hook fires once per caught panic");
    }

    #[test]
    fn pool_counts_caught_panics() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.panics(), 0);
        pool.execute(|| panic!("a"));
        pool.execute(|| panic!("b"));
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while pool.panics() < 2 {
            assert!(std::time::Instant::now() < deadline, "panics never counted");
            thread::yield_now();
        }
        assert_eq!(pool.panics(), 2);
    }
}
