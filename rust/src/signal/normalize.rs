//! Magnitude normalization (paper §3.1.1: series bounded into `[0,1]`).

/// Min-max normalize into `[0,1]`. A constant series maps to all-zeros
/// (no information; avoids division by zero).
pub fn min_max(xs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return Vec::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = hi - lo;
    if span <= 0.0 {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|x| (x - lo) / span).collect()
}

/// Z-score normalize (mean 0, stddev 1); constant series maps to zeros.
pub fn z_score(xs: &[f64]) -> Vec<f64> {
    let m = crate::util::stats::mean(xs);
    let s = crate::util::stats::stddev(xs);
    if s <= 0.0 {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|x| (x - m) / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_bounds() {
        let y = min_max(&[3.0, -1.0, 7.0, 5.0]);
        assert_eq!(y[1], 0.0);
        assert_eq!(y[2], 1.0);
        for v in &y {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn min_max_preserves_order() {
        let xs = [2.0, 9.0, 4.0, 4.5];
        let y = min_max(&xs);
        for i in 0..xs.len() {
            for j in 0..xs.len() {
                assert_eq!(xs[i] < xs[j], y[i] < y[j]);
            }
        }
    }

    #[test]
    fn constant_series_is_zeros() {
        assert_eq!(min_max(&[5.0; 4]), vec![0.0; 4]);
        assert_eq!(z_score(&[5.0; 4]), vec![0.0; 4]);
    }

    #[test]
    fn empty_ok() {
        assert!(min_max(&[]).is_empty());
        assert!(z_score(&[]).is_empty());
    }

    #[test]
    fn z_score_moments() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64 * 0.7 - 3.0).collect();
        let y = z_score(&xs);
        assert!(crate::util::stats::mean(&y).abs() < 1e-12);
        assert!((crate::util::stats::stddev(&y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_max_scale_invariant() {
        let xs = [1.0, 2.0, 5.0, 3.0];
        let scaled: Vec<f64> = xs.iter().map(|x| 10.0 * x + 4.0).collect();
        assert_eq!(min_max(&xs), min_max(&scaled));
    }
}
