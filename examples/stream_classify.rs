//! Streaming classification quickstart: profile a reference database,
//! then classify a *live* CPU stream while the job is still running.
//!
//! A `StreamSession` ingests the capture batch by batch (here replayed
//! from a simulated run via `LiveStream`), tightens monotone lower bounds
//! per reference as samples arrive, culls hopeless candidates, and
//! declares an early decision once the margin policy is satisfied —
//! typically well before the job finishes. Closing the session runs the
//! exact indexed search over the full capture for comparison.
//!
//! Run with: `cargo run --release --example stream_classify`

use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::prelude::*;
use mrtuner::simulator::engine::simulate;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::workload_for;

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::small(1);
    let sc = SystemConfig {
        use_runtime: false,
        ..SystemConfig::default()
    };

    // Reference database: WordCount and TeraSort profiled over the grid.
    let p = Profiler::new(&sc, None);
    let mut idx = IndexedDb::new();
    for app in [AppId::WordCount, AppId::TeraSort] {
        for entry in p.profile(app, &grid) {
            idx.insert(entry);
        }
    }
    println!("reference DB: {} entries over {} config sets", idx.len(), grid.len());

    // A "new" job starts: WordCount under the first config set, fresh
    // noise seed. We only get to see its CPU samples as they happen.
    let cfg = grid.configs[0];
    let run = simulate(
        workload_for(AppId::WordCount).as_ref(),
        &cfg,
        &sc.cluster,
        &sc.noise,
        &mut Rng::new(2024),
    );
    let mut source = run.live_stream();
    let total = source.final_len();
    println!(
        "live job started under {} ({total} samples total, but nobody knows the pattern yet)",
        cfg.label(),
    );

    let mut session = StreamSession::open(
        &idx,
        Some(&cfg),
        FinalLen::Known(total),
        DecisionPolicy::default(),
    );

    // Feed 10-second SysStat batches until the session declares.
    while let Some(batch) = source.next_batch(10) {
        let decision = session.push(&idx, batch).cloned();
        if let Some(d) = decision {
            println!(
                "EARLY DECISION after {} of {total} samples ({:.0}% observed): {} (similarity {:.1}%, {} candidates culled)",
                d.at_sample,
                d.fraction * 100.0,
                d.app.name(),
                d.similarity,
                session.stats().culled,
            );
            break;
        }
    }

    // Drain the rest of the run and compare with the exact offline answer.
    while let Some(batch) = source.next_batch(10) {
        session.push(&idx, batch);
    }
    let (top, stats) = session.finalize(&idx, 1);
    let offline = idx.entries()[top[0].index].app;
    println!(
        "offline full-series answer: {} (distance {:.4}; search: {})",
        offline.name(),
        top[0].distance,
        stats
    );
    match session.decision() {
        Some(d) if d.app == offline => println!("early decision AGREES with the full series"),
        Some(d) => println!("early decision ({}) disagrees with the full series", d.app.name()),
        None => println!("policy never fired; the exact finalize answered instead"),
    }
}
