//! Cross-layer parity: the PJRT-compiled artifacts (L1 Pallas kernels
//! lowered through L2 JAX) must agree with the pure-Rust implementations.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! loud message) if the artifact directory is missing so `cargo test` still
//! works in a fresh checkout.

use mrtuner::coordinator::batcher::{similarities_fallback, Batcher};
use mrtuner::dtw::{band_radius, banded::dtw_banded};
use mrtuner::runtime::{Padded, RuntimeService};
use mrtuner::signal;
use mrtuner::util::rng::Rng;

fn runtime() -> Option<RuntimeService> {
    let svc = RuntimeService::try_default();
    if svc.is_none() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` for parity tests");
    }
    svc
}

fn wave(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let f = 0.05 + rng.f64() * 0.2;
    let phase = rng.f64() * 6.28;
    (0..len)
        .map(|i| {
            (0.5 + 0.35 * ((i as f64) * f + phase).sin() + rng.normal_ms(0.0, 0.03))
                .clamp(0.0, 1.0)
        })
        .collect()
}

#[test]
fn preprocess_matches_rust_chebyshev() {
    let Some(svc) = runtime() else { return };
    let rt = svc.handle();
    for seed in 0..6u64 {
        let len = 60 + (seed as usize) * 37;
        let raw = wave(len, seed);
        let bucket = rt.bucket_for(len);
        let got = rt.preprocess(Padded::fit(&raw, bucket)).expect("preprocess");
        let want = signal::preprocess(&raw);
        assert_eq!(got.len, len);
        for (i, (a, b)) in got.valid().iter().zip(want.iter()).enumerate() {
            assert!(
                (a - b).abs() < 5e-4,
                "seed {seed} sample {i}: pjrt {a} vs rust {b}"
            );
        }
        // Padding must be exactly zero.
        for &v in &got.data[len..] {
            assert_eq!(v, 0.0);
        }
    }
}

#[test]
fn dtw_batch_distances_match_rust() {
    let Some(svc) = runtime() else { return };
    let rt = svc.handle();
    let b = rt.batch();
    let query = signal::preprocess(&wave(100, 42));
    let refs: Vec<Vec<f64>> = (0..b as u64)
        .map(|s| signal::preprocess(&wave(64 + 11 * s as usize, 100 + s)))
        .collect();

    let bucket = rt.bucket_for(refs.iter().map(|r| r.len()).max().unwrap().max(query.len()));
    let padded_refs: Vec<Padded> = refs.iter().map(|r| Padded::fit(r, bucket)).collect();
    let out = rt
        .dtw_batch(Padded::fit(&query, bucket), padded_refs)
        .expect("dtw_batch");

    for (lane, r) in refs.iter().enumerate() {
        let want = dtw_banded(&query, r, band_radius(query.len(), r.len())).distance;
        let got = out.dists[lane] as f64;
        // Band-edge rounding differs by at most one cell between the two
        // implementations; distances agree within a small relative bound.
        assert!(
            (got - want).abs() < 2e-2 * want.max(1.0),
            "lane {lane}: pjrt {got} vs rust {want}"
        );
    }
}

#[test]
fn match_one_similarities_track_fallback() {
    let Some(svc) = runtime() else { return };
    let rt = svc.handle();
    let raw_query = wave(90, 7);
    let refs: Vec<Vec<f64>> = (0..12u64)
        .map(|s| signal::preprocess(&wave(50 + 13 * s as usize, 500 + s)))
        .collect();

    let pjrt = Batcher::new(rt.clone())
        .similarities(&raw_query, &refs)
        .expect("batcher");
    let rust = similarities_fallback(&raw_query, &refs);
    assert_eq!(pjrt.len(), rust.len());
    for (i, (a, b)) in pjrt.iter().zip(rust.iter()).enumerate() {
        // f32 vs f64 and tie-breaking differences keep these within a
        // fraction of a percentage point, not bit-identical.
        assert!((a - b).abs() < 1.5, "ref {i}: pjrt {a} vs rust {b}");
    }
}

#[test]
fn self_similarity_is_perfect_through_pjrt() {
    let Some(svc) = runtime() else { return };
    let rt = svc.handle();
    let raw = wave(120, 9);
    // Reference = the preprocessed query itself.
    let pre = signal::preprocess(&raw);
    let sims = Batcher::new(rt)
        .similarities(&raw, &[pre])
        .expect("batcher");
    assert!(sims[0] > 99.0, "self similarity {}", sims[0]);
}

#[test]
fn batch_lanes_are_independent() {
    // The same reference must get the same similarity regardless of which
    // lane (and which chunk) it lands in.
    let Some(svc) = runtime() else { return };
    let rt = svc.handle();
    let raw_query = wave(80, 21);
    let r = signal::preprocess(&wave(70, 77));
    let refs: Vec<Vec<f64>> = (0..10).map(|_| r.clone()).collect();
    let sims = Batcher::new(rt).similarities(&raw_query, &refs).expect("batcher");
    for s in &sims[1..] {
        assert!((s - sims[0]).abs() < 1e-6, "{s} vs {}", sims[0]);
    }
}
