//! Descriptive statistics shared by the similarity pipeline, the simulator
//! cost models and the bench harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation on a *sorted copy* (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Pearson correlation coefficient between equal-length series.
/// Returns 0.0 when either side is constant (no linear relation defined).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Online mean/variance accumulator (Welford). Used by the metrics module so
/// the serve loop never stores full sample vectors.
#[derive(Debug, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Welford {
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Fixed-bucket log₂-scale latency histogram: 32 buckets, bucket `i`
/// covering `[2^(i+10), 2^(i+11))` nanoseconds, i.e. ~1 µs up to ~37 min
/// with a factor-2 resolution. Paired with a [`Welford`] inside the
/// metrics module so the serve loop gets p50/p95/p99 without ever storing
/// sample vectors. Durations below the first bucket land in bucket 0 and
/// above the last clamp into bucket 31.
#[derive(Debug, Clone, Default)]
pub struct LogHistogram {
    counts: [u64; 32],
    total: u64,
}

/// First bucket's low edge as a power of two (2^10 ns ≈ 1 µs).
const HIST_SHIFT: u32 = 10;

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    fn bucket_of(secs: f64) -> usize {
        let ns = (secs * 1e9).max(0.0) as u64;
        if ns == 0 {
            return 0;
        }
        let log2 = 63 - ns.leading_zeros();
        (log2.saturating_sub(HIST_SHIFT) as usize).min(31)
    }

    /// Record one duration in seconds.
    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket_of(secs)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Fold another histogram into this one, bucket-wise. Exact: merging
    /// then querying is identical to having recorded every sample into
    /// one histogram (buckets are fixed, so there is no re-binning
    /// error). The router uses this to aggregate per-shard fan-out
    /// latency into one fleet-wide distribution.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.total += other.total;
    }

    /// The `q`-quantile (`q` in `[0, 1]`) in seconds: the geometric
    /// representative (1.5 × low edge) of the bucket containing the
    /// target rank. Exact to within the factor-2 bucket width; 0.0 when
    /// empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return (1u64 << (i as u32 + HIST_SHIFT)) as f64 * 1.5e-9;
            }
        }
        (1u64 << (31 + HIST_SHIFT)) as f64 * 1.5e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let xs = [1.0, 1.0, 1.0];
        let ys = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn log_histogram_quantiles_track_the_distribution() {
        let mut h = LogHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        for _ in 0..90 {
            h.record(1e-3); // 1 ms
        }
        for _ in 0..10 {
            h.record(100e-3); // 100 ms
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // Bucket resolution is a factor of 2 around the true value.
        assert!((0.5e-3..=2e-3).contains(&p50), "p50={p50}");
        assert!((50e-3..=200e-3).contains(&p99), "p99={p99}");
        assert!(p99 > 10.0 * p50);
        // Identical samples: every quantile lands in the same bucket.
        let mut u = LogHistogram::new();
        for _ in 0..32 {
            u.record(5e-3);
        }
        assert_eq!(u.quantile(0.5), u.quantile(0.99));
    }

    #[test]
    fn log_histogram_merge_equals_recording_into_one() {
        let samples_a = [1e-3, 2e-3, 50e-3, 1e-6];
        let samples_b = [4e-3, 100e-3, 0.5, 3e-5];
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut combined = LogHistogram::new();
        for &s in &samples_a {
            a.record(s);
            combined.record(s);
        }
        for &s in &samples_b {
            b.record(s);
            combined.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), combined.count());
        for q in [0.0, 0.25, 0.5, 0.75, 0.95, 0.99, 1.0] {
            assert_eq!(a.quantile(q), combined.quantile(q), "q={q}");
        }
    }

    #[test]
    fn log_histogram_merge_edge_cases() {
        // Empty ∪ empty: still empty, quantiles stay 0.
        let mut e = LogHistogram::new();
        e.merge(&LogHistogram::new());
        assert_eq!(e.count(), 0);
        assert_eq!(e.quantile(0.5), 0.0, "empty after merging empties");

        // Single sample merged into an empty: every quantile is that
        // sample's bucket representative.
        let mut one = LogHistogram::new();
        one.record(5e-3);
        let mut m = LogHistogram::new();
        m.merge(&one);
        assert_eq!(m.count(), 1);
        assert_eq!(m.quantile(0.0), m.quantile(1.0));
        let rep = m.quantile(0.5);
        assert!((2.5e-3..=10e-3).contains(&rep), "rep={rep}");

        // Top-bucket saturation: absurd durations clamp into bucket 31 on
        // both sides and stay clamped after the merge.
        let mut hot = LogHistogram::new();
        hot.record(1e9);
        let mut hot2 = LogHistogram::new();
        hot2.record(4e9);
        hot.merge(&hot2);
        assert_eq!(hot.count(), 2);
        let top = hot.quantile(1.0);
        assert_eq!(hot.quantile(0.0), top, "both samples share the top bucket");
        assert!(top < 1e9, "clamped representative, not the raw value");
    }

    #[test]
    fn log_histogram_clamps_extremes() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e-9);
        h.record(1e9); // far past the last bucket
        assert_eq!(h.count(), 4);
        assert!(h.quantile(0.0) > 0.0, "bucket representatives are positive");
        assert!(h.quantile(1.0) < 1e9, "clamped into the last bucket");
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [0.5, 1.5, -2.0, 8.0, 3.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -2.0);
        assert_eq!(w.max(), 8.0);
        assert_eq!(w.count(), 5);
    }
}
