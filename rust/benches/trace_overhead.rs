//! Tracing overhead bench (PR 7 acceptance gate): the span-instrumented
//! k-NN path with the default [`NullTracker`] must cost no more than 2%
//! over the untraced baseline — tracing compiled in but disabled has to
//! be free enough to leave on everywhere. Live backends
//! ([`InMemoryTracker`], [`ChromeTracker`]) are measured too, for scale,
//! and the production serve topology — a [`FlightRecorder`] behind the
//! seeded 1-in-64 [`SamplingTracker`] — carries a second 2% gate.
//!
//! Results go to stdout and `BENCH_trace.json`. `MRTUNER_BENCH_SMOKE=1`
//! shrinks the workload for CI.
//!
//! Run with: `cargo bench --bench trace_overhead`

#[path = "harness.rs"]
mod harness;

use harness::bench;
use mrtuner::database::profile::ProfileEntry;
use mrtuner::prelude::*;
use mrtuner::signal;
use mrtuner::trace::{
    ChromeTracker, FlightRecorder, InMemoryTracker, NullTracker, SamplingTracker, TraceHandle,
    Tracker,
};
use mrtuner::util::json::Json;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::AppId;
use std::sync::Arc;

/// Noisy sine, preprocessed exactly like stored profiles.
fn wave(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let f = 0.04 + rng.f64() * 0.12;
    let phase = rng.f64() * 6.28;
    signal::preprocess(
        &(0..len)
            .map(|i| {
                (0.55 + 0.35 * ((i as f64) * f + phase).sin() + rng.normal_ms(0.0, 0.04))
                    .clamp(0.0, 1.0)
            })
            .collect::<Vec<_>>(),
    )
}

fn synthetic_db(n: usize) -> IndexedDb {
    let mut db = ReferenceDb::new();
    for i in 0..n {
        let cfg = JobConfig::new(
            i % 42 + 1,
            (i / 42) % 40 + 1,
            (i / (42 * 40) + 1) as f64,
            100.0,
        );
        let len = 64 + (i * 37) % 192;
        db.insert(ProfileEntry {
            app: AppId::all()[i % AppId::all().len()],
            config: cfg,
            series: wave(len, i as u64),
            raw_len: len,
            completion_secs: 100.0,
        });
    }
    IndexedDb::from_db(db)
}

fn main() {
    mrtuner::util::logging::init();
    let smoke = std::env::var("MRTUNER_BENCH_SMOKE").is_ok();
    let (db_n, n_queries, samples) = if smoke { (120, 4, 5) } else { (800, 8, 20) };

    let idx = synthetic_db(db_n);
    let queries: Vec<Vec<f64>> = (0..n_queries)
        .map(|qi| wave(96 + qi * 24, (qi * 7 + 3) as u64))
        .collect();
    let qrefs: Vec<&[f64]> = queries.iter().map(Vec::as_slice).collect();
    let k = 5;

    println!("== knn_batch ({n_queries} queries, DB={db_n}, k={k}): untraced vs traced ==");
    let baseline = bench("untraced  idx.knn_batch", 3, samples, || idx.knn_batch(&qrefs, k));

    let variants: Vec<(&str, Arc<dyn Tracker>)> = vec![
        ("null", Arc::new(NullTracker)),
        ("memory", Arc::new(InMemoryTracker::new())),
        ("chrome", Arc::new(ChromeTracker::new())),
    ];
    let mut rows = Vec::new();
    let mut null_overhead_pct = f64::NAN;
    for (name, tracker) in variants {
        let tracer = TraceHandle::new(tracker);
        let stats = bench(&format!("traced    knn_batch [{name:6}]"), 3, samples, || {
            let root = tracer.root("request");
            let span = root.child("knn_batch");
            idx.knn_batch_traced(&qrefs, k, &span)
        });
        // p50 over p50: the median is robust to the odd scheduler blip
        // that would otherwise dominate a percent-level comparison.
        let overhead_pct = (stats.p50_s / baseline.p50_s - 1.0) * 100.0;
        println!("    {name}: {overhead_pct:+.2}% vs untraced");
        if name == "null" {
            null_overhead_pct = overhead_pct;
        }
        rows.push(Json::obj(vec![
            ("tracker", Json::Str(name.into())),
            ("mean_ms", Json::Num(stats.mean_s * 1e3)),
            ("p50_ms", Json::Num(stats.p50_s * 1e3)),
            ("overhead_pct", Json::Num(overhead_pct)),
        ]));
    }

    // The production serve topology: a flight-recorder ring behind the
    // seeded 1-in-64 head sampler, keys walking like live request ids.
    // This is what `mrtuner serve` runs by default, so it gets its own
    // acceptance gate: amortized over all requests (63 of 64 take the
    // cheap sampled-out path), it must also stay within 2% of untraced.
    let recorder = Arc::new(FlightRecorder::new(4096));
    let sampler = TraceHandle::new(Arc::new(SamplingTracker::with_seed(
        Arc::clone(&recorder) as Arc<dyn Tracker>,
        64,
        1,
    )));
    let mut key = 0u64;
    let stats = bench("traced    knn_batch [sampled 1-in-64]", 3, samples, || {
        key += 1;
        let root = sampler.root_sampled("request", 0, key);
        let span = root.child("knn_batch");
        idx.knn_batch_traced(&qrefs, k, &span)
    });
    let sampled_overhead_pct = (stats.p50_s / baseline.p50_s - 1.0) * 100.0;
    println!("    sampled 1-in-64: {sampled_overhead_pct:+.2}% vs untraced ({} spans in the ring)", recorder.len());
    rows.push(Json::obj(vec![
        ("tracker", Json::Str("sampled_1_in_64".into())),
        ("mean_ms", Json::Num(stats.mean_s * 1e3)),
        ("p50_ms", Json::Num(stats.p50_s * 1e3)),
        ("overhead_pct", Json::Num(sampled_overhead_pct)),
    ]));

    let null_pass = null_overhead_pct <= 2.0;
    let sampled_pass = sampled_overhead_pct <= 2.0;
    let pass = null_pass && sampled_pass;
    println!(
        "    acceptance: NullTracker overhead {null_overhead_pct:+.2}% (target <= 2%): {}",
        if null_pass { "PASS" } else { "FAIL" }
    );
    println!(
        "    acceptance: sampled 1-in-64 overhead {sampled_overhead_pct:+.2}% (target <= 2%): {}",
        if sampled_pass { "PASS" } else { "FAIL" }
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("trace_overhead".into())),
        ("smoke", Json::Bool(smoke)),
        ("db", Json::Num(db_n as f64)),
        ("queries", Json::Num(n_queries as f64)),
        ("k", Json::Num(k as f64)),
        ("baseline_p50_ms", Json::Num(baseline.p50_s * 1e3)),
        ("variants", Json::arr(rows)),
        (
            "acceptance",
            Json::obj(vec![
                ("target_pct", Json::Num(2.0)),
                ("null_overhead_pct", Json::Num(null_overhead_pct)),
                ("sampled_overhead_pct", Json::Num(sampled_overhead_pct)),
                ("pass", Json::Bool(pass)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_trace.json", report.to_pretty()).expect("write BENCH_trace.json");
    println!("wrote BENCH_trace.json");
}
