//! mrtuner CLI — leader entrypoint.
//!
//! ```text
//! mrtuner profile --app wordcount --grid table1|grid50|small --db db.json
//! mrtuner match   --app exim      --grid table1 --db db.json
//! mrtuner tune    --app exim      --grid small  --db db.json
//! mrtuner table1  [--seed N]                  # reproduce the paper's Table 1
//! mrtuner serve   --db db.json --port 7070    # match-as-a-service
//! mrtuner calibrate --app terasort            # re-measure cost model
//! ```

use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::coordinator::{matcher::Matcher, ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::database::store::ReferenceDb;
use mrtuner::util::cli::Args;
use mrtuner::workloads::{workload_for, AppId};
use std::path::PathBuf;

fn grid_from(args: &Args) -> ConfigGrid {
    let seed = args.opt::<u64>("seed", 1);
    match args.opt_str("grid", "small").as_str() {
        "table1" => ConfigGrid::paper_table1(),
        "grid50" => ConfigGrid::paper_grid50(seed),
        "small" => ConfigGrid::small(seed),
        other => {
            let n: usize = other.parse().unwrap_or_else(|_| {
                eprintln!("unknown grid {other:?}; use table1|grid50|small|<N>");
                std::process::exit(2);
            });
            ConfigGrid::random(n, seed)
        }
    }
}

fn app_from(args: &Args) -> AppId {
    let name = args.opt_str("app", "");
    AppId::from_name(&name).unwrap_or_else(|| {
        eprintln!(
            "unknown --app {name:?}; known: {}",
            AppId::all().iter().map(|a| a.name()).collect::<Vec<_>>().join(", ")
        );
        std::process::exit(2);
    })
}

fn system(args: &Args) -> TuningSystem {
    let mut config = SystemConfig {
        seed: args.opt::<u64>("seed", SystemConfig::default().seed),
        workers: args.opt::<usize>("workers", SystemConfig::default().workers),
        use_runtime: !args.has_flag("no-runtime"),
        ..SystemConfig::default()
    };
    if args.has_flag("no-noise") {
        config.noise = mrtuner::signal::noise::NoiseModel::none();
    }
    let mut sys = TuningSystem::new(config);
    let db_path = args.opt_str("db", "");
    if !db_path.is_empty() {
        if let Ok(db) = ReferenceDb::load(&PathBuf::from(&db_path)) {
            log::info!("loaded {} entries from {db_path}", db.len());
            sys.db = db;
        }
    }
    sys
}

fn save_db(sys: &TuningSystem, args: &Args) {
    let db_path = args.opt_str("db", "");
    if !db_path.is_empty() {
        sys.db.save(&PathBuf::from(&db_path)).expect("saving database");
        log::info!("saved {} entries to {db_path}", sys.db.len());
    }
}

fn main() -> anyhow::Result<()> {
    mrtuner::util::logging::init();
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("profile") => {
            let app = app_from(&args);
            let grid = grid_from(&args);
            let mut sys = system(&args);
            sys.profile_app(app, &grid);
            println!("profiled {} under {} configuration sets", app.name(), grid.len());
            save_db(&sys, &args);
        }
        Some("match") => {
            let app = app_from(&args);
            let grid = grid_from(&args);
            let sys = system(&args);
            let outcome = sys.match_app(app, &grid);
            for v in &outcome.votes {
                println!(
                    "{:28} best={:12} sim={:6.2}%",
                    v.config.label(),
                    v.best_app.map(|a| a.name()).unwrap_or("-"),
                    v.best_similarity
                );
            }
            println!("tally: {:?}", outcome.tally);
            match outcome.winner {
                Some(w) => println!("most similar application: {}", w.name()),
                None => println!("no application cleared the 90% threshold"),
            }
        }
        Some("tune") => {
            let app = app_from(&args);
            let grid = grid_from(&args);
            let mut sys = system(&args);
            let report = sys.tune_app(app, &grid);
            println!("matched: {:?}", report.matched_app.map(|a| a.name()));
            if let Some(cfg) = report.transferred {
                println!("transferred config: {}", cfg.label());
            }
            println!(
                "default {:.1}s -> tuned {:.1}s (speedup {:.2}x)",
                report.default_secs,
                report.tuned_secs,
                report.speedup()
            );
            save_db(&sys, &args);
        }
        Some("table1") => {
            let mut sys = system(&args);
            let grid = ConfigGrid::paper_table1();
            sys.profile_app(AppId::WordCount, &grid);
            sys.profile_app(AppId::TeraSort, &grid);
            let m = Matcher::new(&sys.config, sys.runtime());
            let table = m.similarity_table(AppId::EximParse, &grid, &sys.db);
            mrtuner::coordinator::print_table1(&table, &grid);
        }
        Some("serve") => {
            let mut sys = system(&args);
            let port = args.opt::<u16>("port", 7070);
            let runtime = sys.runtime();
            // Wrap the store in the similarity index once at startup; every
            // connection then shares the immutable envelope cache.
            let state = ServerState {
                db: mrtuner::index::IndexedDb::from_db(std::mem::take(&mut sys.db)),
                runtime,
                metrics: mrtuner::coordinator::metrics::Metrics::new(),
                sessions: mrtuner::streaming::SessionManager::new(),
            };
            let server = MatchServer::bind(&format!("127.0.0.1:{port}"), state)?;
            println!("serving on {}", server.local_addr()?);
            server.serve(args.opt::<usize>("workers", 4))?;
        }
        Some("calibrate") => {
            let app = app_from(&args);
            let w = workload_for(app);
            let measured = w.calibrate(
                args.opt::<usize>("sample-kb", 1024) * 1024,
                args.opt::<f64>("speed-factor", 4.0),
                args.opt::<u64>("seed", 1),
            );
            println!("calibrated cost model for {}: {measured:#?}", app.name());
            println!("shipped default:             {:#?}", w.default_costs());
        }
        _ => {
            println!(
                "usage: mrtuner <profile|match|tune|table1|serve|calibrate> \
                 [--app NAME] [--grid table1|grid50|small|N] [--db FILE] \
                 [--seed N] [--workers N] [--port N] [--no-runtime] [--no-noise]"
            );
        }
    }
    Ok(())
}
