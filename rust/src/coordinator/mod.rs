//! The coordinator: the paper's two-phase system (profiling + matching)
//! plus the self-tuning step that motivates it, orchestrated over the
//! simulator substrate and the PJRT runtime.

pub mod batcher;
pub mod matcher;
pub mod metrics;
pub mod profiler;
pub mod router;
pub mod server;
pub mod tuner;

use crate::database::store::ReferenceDb;
use crate::runtime::{RuntimeHandle, RuntimeService};
use crate::signal::noise::NoiseModel;
use crate::simulator::cluster::ClusterConfig;
use crate::simulator::job::JobConfig;
use crate::util::rng::Rng;
use crate::workloads::AppId;

/// System-wide configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Simulated cluster (defaults to the paper's pseudo-distributed box).
    pub cluster: ClusterConfig,
    /// Measurement-noise model applied to captured series.
    pub noise: NoiseModel,
    /// Master seed for all deterministic randomness.
    pub seed: u64,
    /// Worker threads for profiling / matching fan-out.
    pub workers: usize,
    /// Use the PJRT runtime when artifacts are available.
    pub use_runtime: bool,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            cluster: ClusterConfig::pseudo_distributed(),
            noise: NoiseModel::default(),
            seed: 0x5eed,
            workers: crate::util::pool::default_workers(),
            use_runtime: true,
        }
    }
}

/// A set of configuration-parameter values to profile/match over.
#[derive(Debug, Clone)]
pub struct ConfigGrid {
    pub configs: Vec<JobConfig>,
}

impl ConfigGrid {
    /// The paper's Table 1 configuration sets.
    pub fn paper_table1() -> ConfigGrid {
        ConfigGrid {
            configs: JobConfig::paper_table1(),
        }
    }

    /// The paper's §5 experimental design: 50 random sets with mappers and
    /// reducers in 1..=42, split size 1..=50 MB, input size 10..=500 MB.
    pub fn paper_grid50(seed: u64) -> ConfigGrid {
        ConfigGrid::random(50, seed)
    }

    /// `n` random configuration sets drawn from the paper's ranges.
    pub fn random(n: usize, seed: u64) -> ConfigGrid {
        let mut rng = Rng::new(seed ^ 0xc0f1_69d5);
        let configs = (0..n)
            .map(|_| {
                JobConfig::new(
                    rng.range_u64(1, 43) as usize,
                    rng.range_u64(1, 41) as usize,
                    rng.range_u64(1, 51) as f64,
                    rng.range_u64(10, 501) as f64,
                )
            })
            .collect();
        ConfigGrid { configs }
    }

    /// Small, fast grid for tests and the quickstart example.
    pub fn small(seed: u64) -> ConfigGrid {
        let mut rng = Rng::new(seed ^ 0x5a11);
        let configs = (0..6)
            .map(|_| {
                JobConfig::new(
                    rng.range_u64(2, 13) as usize,
                    rng.range_u64(1, 7) as usize,
                    rng.range_u64(5, 21) as f64,
                    rng.range_u64(10, 61) as f64,
                )
            })
            .collect();
        ConfigGrid { configs }
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }
}

/// Facade tying the whole system together; what the CLI and the examples
/// use.
pub struct TuningSystem {
    pub config: SystemConfig,
    pub db: ReferenceDb,
    runtime: Option<RuntimeService>,
}

impl TuningSystem {
    /// Create a system; starts the PJRT runtime when artifacts exist and
    /// `config.use_runtime` is set, otherwise falls back to pure Rust.
    pub fn new(config: SystemConfig) -> TuningSystem {
        let runtime = if config.use_runtime {
            RuntimeService::try_default()
        } else {
            None
        };
        if runtime.is_none() {
            log::info!("runtime: PJRT artifacts unavailable; using pure-Rust fallback");
        }
        TuningSystem {
            config,
            db: ReferenceDb::new(),
            runtime,
        }
    }

    /// Handle to the PJRT runtime, if running.
    pub fn runtime(&self) -> Option<RuntimeHandle> {
        self.runtime.as_ref().map(|r| r.handle())
    }

    /// Profiling phase (paper Figure 4a) for one application.
    pub fn profile_app(&mut self, app: AppId, grid: &ConfigGrid) {
        let profiler = profiler::Profiler::new(&self.config, self.runtime());
        for entry in profiler.profile(app, grid) {
            self.db.insert(entry);
        }
    }

    /// Matching phase (paper Figure 4b) for an unknown application.
    pub fn match_app(&self, app: AppId, grid: &ConfigGrid) -> matcher::MatchOutcome {
        let m = matcher::Matcher::new(&self.config, self.runtime());
        m.match_app(app, grid, &self.db)
    }

    /// Self-tuning: find the matched reference app's optimal configuration
    /// (grid-searching if not cached) and transfer it to `app`.
    pub fn tune_app(&mut self, app: AppId, grid: &ConfigGrid) -> tuner::TuningReport {
        let outcome = self.match_app(app, grid);
        let t = tuner::Tuner::new(&self.config);
        t.tune(app, &outcome, &mut self.db)
    }
}

/// Print a Table-1-shaped similarity matrix: rows = (reference app,
/// reference config), columns = query (Exim) configs; the paper's "red
/// diagonal" cells (same config set) are marked with `*`.
pub fn print_table1(cells: &[matcher::SimilarityCell], grid: &ConfigGrid) {
    let mut rows: Vec<(AppId, JobConfig)> = Vec::new();
    for c in cells {
        if !rows
            .iter()
            .any(|(a, rc)| *a == c.reference_app && rc.label() == c.reference_config.label())
        {
            rows.push((c.reference_app, c.reference_config));
        }
    }
    print!("{:40}", "reference \\ query (exim)");
    for q in &grid.configs {
        print!(" {:>24}", q.label());
    }
    println!();
    for (app, rc) in &rows {
        print!("{:12} {:27}", app.name(), rc.label());
        for q in &grid.configs {
            let cell = cells
                .iter()
                .find(|c| {
                    c.reference_app == *app
                        && c.reference_config.label() == rc.label()
                        && c.config.label() == q.label()
                })
                .map(|c| c.similarity)
                .unwrap_or(f64::NAN);
            let mark = if rc.label() == q.label() { "*" } else { " " };
            print!(" {:>22.4}%{mark}", cell);
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_have_expected_sizes_and_validity() {
        assert_eq!(ConfigGrid::paper_table1().len(), 4);
        let g = ConfigGrid::paper_grid50(1);
        assert_eq!(g.len(), 50);
        assert!(g.configs.iter().all(|c| c.is_valid()));
        for c in &g.configs {
            assert!((1..=42).contains(&c.mappers));
            assert!((1..=40).contains(&c.reducers));
            assert!((1.0..=50.0).contains(&c.split_mb));
            assert!((10.0..=500.0).contains(&c.input_mb));
        }
    }

    #[test]
    fn grid_is_seeded() {
        let a = ConfigGrid::random(10, 7);
        let b = ConfigGrid::random(10, 7);
        let c = ConfigGrid::random(10, 8);
        assert_eq!(a.configs, b.configs);
        assert_ne!(a.configs, c.configs);
    }
}
