//! End-to-end integration over the full coordinator stack (pure-Rust
//! fallback path — artifact-dependent tests live in `parity.rs`).

use mrtuner::prelude::*;
use mrtuner::workloads::{workload_for, AppId};

fn system() -> TuningSystem {
    TuningSystem::new(SystemConfig {
        workers: 4,
        use_runtime: false,
        ..SystemConfig::default()
    })
}

#[test]
fn profile_match_tune_end_to_end() {
    let grid = ConfigGrid::small(11);
    let mut sys = system();
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);
    assert_eq!(sys.db.len(), 2 * grid.len());

    let report = sys.tune_app(AppId::EximParse, &grid);
    assert_eq!(report.matched_app, Some(AppId::WordCount));
    let transferred = report.transferred.expect("transfer happened");
    assert!(transferred.is_valid());
    assert!(
        report.speedup() > 1.0,
        "transferred config not faster: default {}s tuned {}s",
        report.default_secs,
        report.tuned_secs
    );
}

#[test]
fn database_survives_persistence_round_trip() {
    let grid = ConfigGrid::small(13);
    let mut sys = system();
    sys.profile_app(AppId::Grep, &grid);
    let path = std::env::temp_dir().join("mrtuner_integration_db.json");
    sys.db.save(&path).unwrap();

    let restored = ReferenceDb::load(&path).unwrap();
    assert_eq!(restored.len(), sys.db.len());
    // Matching against the restored DB behaves identically.
    let m = Matcher::new(&sys.config, None);
    let a = m.match_app(AppId::Grep, &grid, &sys.db);
    let b = m.match_app(AppId::Grep, &grid, &restored);
    assert_eq!(a.winner, b.winner);
    std::fs::remove_file(&path).ok();
}

#[test]
fn five_app_database_still_ranks_text_apps_together() {
    // Wider DB (extension experiment E4): Exim should match WordCount ahead
    // of TeraSort even with Grep and InvertedIndex competing.
    let grid = ConfigGrid::small(17);
    let mut sys = system();
    for app in [
        AppId::WordCount,
        AppId::TeraSort,
        AppId::Grep,
        AppId::InvertedIndex,
    ] {
        sys.profile_app(app, &grid);
    }
    let outcome = sys.match_app(AppId::EximParse, &grid);
    let wc = outcome.tally.get("wordcount").copied().unwrap_or(0);
    let ts = outcome.tally.get("terasort").copied().unwrap_or(0);
    assert!(wc > ts, "wordcount {wc} vs terasort {ts}: {:?}", outcome.tally);
}

#[test]
fn indexed_matching_agrees_with_brute_force_end_to_end() {
    use mrtuner::coordinator::matcher::Matcher;

    // Four reference apps over the small grid, like a production DB slice.
    // Grid seed 11 is the one profile_match_tune_end_to_end already pins
    // to the paper's Exim -> WordCount headline result.
    let grid = ConfigGrid::small(11);
    let mut sys = system();
    for app in [
        AppId::WordCount,
        AppId::TeraSort,
        AppId::Grep,
        AppId::InvertedIndex,
    ] {
        sys.profile_app(app, &grid);
    }
    let m = Matcher::new(&sys.config, None);
    let brute = m.match_app(AppId::EximParse, &grid, &sys.db);
    let idx = IndexedDb::from_db(std::mem::take(&mut sys.db));

    // Full re-rank (k >= bucket size): vote-for-vote identical to brute
    // force by construction.
    let (full, full_stats) = m.match_app_indexed(AppId::EximParse, &grid, &idx, usize::MAX);
    assert_eq!(full.winner, brute.winner);
    assert_eq!(full.tally, brute.tally);
    assert_eq!(full_stats.candidates, 4 * grid.len() as u64);

    // Sublinear retrieval (top-1 by banded-DTW distance) on the paper's
    // two-reference-app scenario: the headline winner must not change, and
    // only one correlation per config is paid.
    let mut sys2 = system();
    sys2.profile_app(AppId::WordCount, &grid);
    sys2.profile_app(AppId::TeraSort, &grid);
    let brute2 = m.match_app(AppId::EximParse, &grid, &sys2.db);
    assert_eq!(brute2.winner, Some(AppId::WordCount), "paper's headline result");
    let idx2 = IndexedDb::from_db(std::mem::take(&mut sys2.db));
    let (fast, stats) = m.match_app_indexed(AppId::EximParse, &grid, &idx2, 1);
    assert_eq!(fast.winner, brute2.winner, "tally {:?}", fast.tally);
    assert_eq!(fast.cells.len(), grid.len());
    assert_eq!(stats.candidates, 2 * grid.len() as u64);
    assert_eq!(stats.pruned() + stats.dtw_started(), stats.candidates);
}

#[test]
fn real_execution_calibration_is_sane() {
    // The calibrate path really executes the map/reduce functions; its
    // measured selectivities must be close to the cost-model constants the
    // simulator uses.
    for app in [AppId::WordCount, AppId::TeraSort, AppId::EximParse] {
        let w = workload_for(app);
        let measured = w.calibrate(256 * 1024, 1.0, 1234);
        assert!(measured.is_plausible(), "{app:?}: {measured:?}");
        let expected = w.default_costs();
        let ratio = measured.map_selectivity / expected.map_selectivity;
        assert!(
            (0.3..=3.0).contains(&ratio),
            "{app:?}: measured selectivity {} vs model {}",
            measured.map_selectivity,
            expected.map_selectivity
        );
    }
}

#[test]
fn simulator_workload_separation_is_robust_across_seeds() {
    // The separation Table 1 relies on (same-config text apps similar,
    // TeraSort different) must hold across noise seeds, not just the one
    // used in the paper benches.
    use mrtuner::coordinator::profiler::Profiler;
    use mrtuner::dtw::corr::similarity_percent;
    let cfg = JobConfig::new(8, 4, 10.0, 50.0);
    for seed in [1u64, 2, 3] {
        let sc = SystemConfig {
            seed,
            workers: 2,
            use_runtime: false,
            ..SystemConfig::default()
        };
        let p = Profiler::new(&sc, None);
        let wc = p.profile_one(AppId::WordCount, &cfg);
        let ex = p.profile_one(AppId::EximParse, &cfg);
        let ts = p.profile_one(AppId::TeraSort, &cfg);
        let s_wc = similarity_percent(&ex.series, &wc.series);
        let s_ts = similarity_percent(&ex.series, &ts.series);
        assert!(
            s_wc > s_ts,
            "seed {seed}: exim~wordcount {s_wc} <= exim~terasort {s_ts}"
        );
    }
}
