//! Chaos suite: a 2-shard × 2-replica fleet behind deterministic
//! [`FaultProxy`](mrtuner::faultproxy::FaultProxy) instances, driven
//! through scripted fault schedules. Every assertion is on outcomes —
//! error codes, fault counters, merged result bits — never on elapsed
//! wall time:
//!
//! * full-health proxied fleet answers **bit-identically** to the same
//!   fleet with no proxies in the path;
//! * a single replica failure costs **zero** failed idempotent requests
//!   (failover to the standby, within a request deadline);
//! * garbled replies are a transport failure, not an answer: failover
//!   recovers the request;
//! * `allow_partial` degrades around a dead shard with a correct
//!   `degraded` annotation and results bit-identical to a single node
//!   over the surviving union;
//! * a replica that answers too slowly burns the request's `deadline_ms`
//!   budget and surfaces the typed `deadline_exceeded` error;
//! * retries / failovers / circuit transitions are visible in metrics.

use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::router::{dispatch_routed, route_line, ShardRouter};
use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::database::profile::ProfileEntry;
use mrtuner::faultproxy::{Fault, FaultPlan, FaultProxy};
use mrtuner::index::IndexedDb;
use mrtuner::protocol::{ErrorCode, KnnBody, Request, Response};
use mrtuner::simulator::job::JobConfig;
use mrtuner::streaming::SessionManager;
use mrtuner::util::json::Json;
use mrtuner::workloads::AppId;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn raw_wave(freq: f64, len: usize) -> Vec<f64> {
    (0..len)
        .map(|i| (0.5 + 0.4 * ((i as f64) * freq).sin()).clamp(0.0, 1.0))
        .collect()
}

fn entry(app: AppId, cfg: JobConfig, freq: f64, len: usize) -> ProfileEntry {
    ProfileEntry {
        app,
        config: cfg,
        series: mrtuner::signal::preprocess(&raw_wave(freq, len)),
        raw_len: len,
        completion_secs: 100.0,
    }
}

/// Two config sets, two apps each — deterministic, so calling it once
/// per replica yields byte-identical shard databases (that's what makes
/// two servers *replicas* of the same shard).
fn shard_dbs() -> (Vec<IndexedDb>, Vec<JobConfig>) {
    let configs = vec![
        JobConfig::new(4, 2, 10.0, 20.0),
        JobConfig::new(8, 4, 20.0, 40.0),
    ];
    let mut shards = Vec::new();
    for (ci, cfg) in configs.iter().enumerate() {
        let mut db = IndexedDb::new();
        for (ai, app) in [AppId::WordCount, AppId::TeraSort].into_iter().enumerate() {
            let freq = 0.15 + 0.11 * (ci * 2 + ai) as f64;
            let len = 48 + 16 * ci;
            db.insert(entry(app, *cfg, freq, len));
        }
        shards.push(db);
    }
    (shards, configs)
}

fn state_over(db: IndexedDb) -> ServerState {
    ServerState {
        db,
        runtime: None,
        metrics: Metrics::new(),
        sessions: SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    }
}

struct Server {
    addr: String,
    stop: Arc<AtomicBool>,
    join: std::thread::JoinHandle<anyhow::Result<()>>,
}

fn spawn_server(db: IndexedDb) -> Server {
    let server = MatchServer::bind("127.0.0.1:0", state_over(db)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_flag();
    let join = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));
    Server { addr, stop, join }
}

fn shutdown(servers: Vec<Server>) {
    for s in &servers {
        s.stop.store(true, Ordering::SeqCst);
        let _ = std::net::TcpStream::connect(&s.addr);
    }
    for s in servers {
        s.join.join().unwrap().unwrap();
    }
}

/// Spawn `replicas` servers per shard slot: `fleet[si][ri]`.
fn spawn_replicated_fleet(replicas: usize) -> Vec<Vec<Server>> {
    let nshards = shard_dbs().0.len();
    (0..nshards)
        .map(|si| {
            (0..replicas)
                .map(|_| {
                    let (mut dbs, _) = shard_dbs();
                    spawn_server(dbs.remove(si))
                })
                .collect()
        })
        .collect()
}

fn assert_knn_bits_eq(a: &KnnBody, b: &KnnBody, ctx: &str) {
    assert_eq!(a.neighbors.len(), b.neighbors.len(), "{ctx}: row count");
    for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
        assert_eq!(x.index, y.index, "{ctx}: neighbour index");
        assert_eq!(
            x.distance.to_bits(),
            y.distance.to_bits(),
            "{ctx}: distance bits ({} vs {})",
            x.distance,
            y.distance
        );
        assert_eq!(x.app, y.app, "{ctx}: app");
        assert_eq!(x.config, y.config, "{ctx}: config");
    }
}

fn queries() -> Vec<Vec<f64>> {
    vec![
        raw_wave(0.15, 48),
        raw_wave(0.7, 100),
        raw_wave(0.37, 64),
    ]
}

#[test]
fn full_health_proxied_fleet_is_bit_identical_to_direct_fleet() {
    let fleet = spawn_replicated_fleet(2);
    let proxies: Vec<Vec<FaultProxy>> = fleet
        .iter()
        .map(|slot| {
            slot.iter()
                .map(|s| FaultProxy::spawn(&s.addr, FaultPlan::healthy()).unwrap())
                .collect()
        })
        .collect();

    let proxied_groups: Vec<Vec<String>> = proxies
        .iter()
        .map(|slot| slot.iter().map(|p| p.addr().to_string()).collect())
        .collect();
    let direct_groups: Vec<Vec<String>> = fleet
        .iter()
        .map(|slot| slot.iter().map(|s| s.addr.clone()).collect())
        .collect();

    let pm = Arc::new(Metrics::new());
    let mut proxied = ShardRouter::connect_groups(&proxied_groups, Arc::clone(&pm)).unwrap();
    let mut direct =
        ShardRouter::connect_groups(&direct_groups, Arc::new(Metrics::new())).unwrap();

    for k in [1usize, 2, 4] {
        let a = proxied.knn_batch(&queries(), k, None).unwrap();
        let b = direct.knn_batch(&queries(), k, None).unwrap();
        assert!(a.degraded.is_empty() && b.degraded.is_empty());
        assert_eq!(a.results.len(), b.results.len());
        for (qi, (ra, rb)) in a.results.iter().zip(&b.results).enumerate() {
            assert_knn_bits_eq(ra, rb, &format!("k={k} query {qi}"));
        }
    }

    // A healthy fleet records no fault activity at all.
    assert_eq!(pm.fault_summary(), (0, 0, 0, 0, 0), "healthy fleet stays silent");

    drop(proxied);
    drop(direct);
    drop(proxies);
    shutdown(fleet.into_iter().flatten().collect());
}

#[test]
fn replica_failure_fails_over_with_zero_failed_requests() {
    let fleet = spawn_replicated_fleet(2);
    // Only shard 0's first replica sits behind a proxy — the one we
    // will crash. Everything else is direct.
    let proxy = FaultProxy::spawn(&fleet[0][0].addr, FaultPlan::healthy()).unwrap();
    let groups = vec![
        vec![proxy.addr().to_string(), fleet[0][1].addr.clone()],
        vec![fleet[1][0].addr.clone()],
    ];

    let metrics = Arc::new(Metrics::new());
    let mut router = ShardRouter::connect_groups(&groups, Arc::clone(&metrics)).unwrap();
    let mut direct = ShardRouter::connect_groups(
        &[vec![fleet[0][1].addr.clone()], vec![fleet[1][0].addr.clone()]],
        Arc::new(Metrics::new()),
    )
    .unwrap();
    assert_eq!(router.shards()[0].active_replica(), 0);

    // Warm request through the proxy.
    let warm = router.knn(&queries()[0], 2, None).unwrap();
    assert_knn_bits_eq(&warm, &direct.knn(&queries()[0], 2, None).unwrap(), "warm");

    // Crash the active replica: sever its live sockets and refuse every
    // connection from now on.
    proxy.set_fault(Fault::Refuse);
    proxy.kill_connections();

    // Zero failed idempotent requests: every k-NN still answers, and
    // bit-identically to the always-healthy direct fleet.
    for (i, q) in queries().iter().enumerate() {
        let got = router.knn(q, 2, None).unwrap();
        let want = direct.knn(q, 2, None).unwrap();
        assert_knn_bits_eq(&got, &want, &format!("post-crash query {i}"));
    }
    assert_eq!(
        router.shards()[0].active_replica(),
        1,
        "failover promoted the standby"
    );

    // Failover also completes under a request deadline generous enough
    // for the reconnect handshake.
    let line = r#"{"v":2,"id":1,"type":"knn","series":[1,2,3,4],"k":1,"deadline_ms":20000}"#;
    let rm = Metrics::new();
    let tracer = mrtuner::trace::TraceHandle::disabled();
    let mrouter = Mutex::new(router);
    let resp = route_line(line, &mrouter, &rm, &tracer);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");

    // The recovery is observable: at least one failover, no degradation.
    let (_retries, failovers, _opens, _probes, degraded) = metrics.fault_summary();
    assert!(failovers >= 1, "failover counter: {:?}", metrics.fault_summary());
    assert_eq!(degraded, 0);
    let snap = metrics.snapshot();
    let counted = snap
        .get("fault")
        .and_then(|f| f.get("failovers"))
        .and_then(Json::as_f64)
        .unwrap();
    assert!(counted >= 1.0, "{snap:?}");

    drop(mrouter);
    drop(direct);
    drop(proxy);
    shutdown(fleet.into_iter().flatten().collect());
}

#[test]
fn garbled_replies_trigger_failover_not_wrong_answers() {
    let fleet = spawn_replicated_fleet(2);
    let proxy = FaultProxy::spawn(&fleet[0][0].addr, FaultPlan::new(0xC4A0)).unwrap();
    let groups = vec![
        vec![proxy.addr().to_string(), fleet[0][1].addr.clone()],
        vec![fleet[1][0].addr.clone()],
    ];
    let metrics = Arc::new(Metrics::new());
    let mut router = ShardRouter::connect_groups(&groups, Arc::clone(&metrics)).unwrap();
    let mut direct = ShardRouter::connect_groups(
        &[vec![fleet[0][1].addr.clone()], vec![fleet[1][0].addr.clone()]],
        Arc::new(Metrics::new()),
    )
    .unwrap();

    // From now on every new proxied connection garbles reply bytes; the
    // live startup connection is severed so the next request meets the
    // garbler, whose output can never parse as a protocol reply.
    proxy.set_fault(Fault::Garble);
    proxy.kill_connections();

    for (i, q) in queries().iter().enumerate() {
        let got = router.knn(q, 2, None).unwrap();
        let want = direct.knn(q, 2, None).unwrap();
        assert_knn_bits_eq(&got, &want, &format!("post-garble query {i}"));
    }
    assert_eq!(router.shards()[0].active_replica(), 1);
    let (_retries, failovers, _opens, _probes, _degraded) = metrics.fault_summary();
    assert!(failovers >= 1);

    drop(router);
    drop(direct);
    drop(proxy);
    shutdown(fleet.into_iter().flatten().collect());
}

#[test]
fn allow_partial_degrades_and_matches_single_node_over_surviving_union() {
    // One replica per shard: when shard 1 dies there is nothing to fail
    // over to, so the request must degrade instead.
    let fleet = spawn_replicated_fleet(1);
    let proxy = FaultProxy::spawn(&fleet[1][0].addr, FaultPlan::healthy()).unwrap();
    let groups = vec![
        vec![fleet[0][0].addr.clone()],
        vec![proxy.addr().to_string()],
    ];
    let metrics = Arc::new(Metrics::new());
    let router = ShardRouter::connect_groups(&groups, Arc::clone(&metrics)).unwrap();

    proxy.set_fault(Fault::Refuse);
    proxy.kill_connections();

    let (dbs, _) = shard_dbs();
    let surviving = &dbs[0]; // shard 0's base is 0: global indices align.
    let mrouter = Mutex::new(router);

    for (qi, q) in queries().iter().enumerate() {
        // Default strict mode: the dead shard fails the whole request.
        let strict = Request::Knn {
            series: q.clone(),
            k: 3,
            config: None,
            allow_partial: false,
        };
        let err = dispatch_routed(&strict, &mrouter).unwrap_err();
        assert_eq!(err.code, ErrorCode::ShardUnavailable, "query {qi}: {err}");

        // Partial mode: merged answer over the survivors, annotated.
        let partial = Request::Knn {
            series: q.clone(),
            k: 3,
            config: None,
            allow_partial: true,
        };
        let body = match dispatch_routed(&partial, &mrouter).unwrap() {
            Response::Knn(b) => b,
            other => panic!("{other:?}"),
        };
        assert_eq!(body.degraded, vec![1], "query {qi}: degraded annotation");

        let prepared = mrtuner::coordinator::batcher::prepare_query(q);
        let local = surviving.knn_batch(&[prepared.as_slice()], 3);
        let (local_nbs, _) = &local[0];
        assert_eq!(body.neighbors.len(), local_nbs.len(), "query {qi}");
        for (r, l) in body.neighbors.iter().zip(local_nbs) {
            assert_eq!(r.index, l.index, "query {qi}: surviving-union index");
            assert_eq!(
                r.distance.to_bits(),
                l.distance.to_bits(),
                "query {qi}: surviving-union distance bits"
            );
        }
    }

    // Keep hammering the dead slot: the breaker opens after its
    // consecutive-failure threshold and later admits half-open probes —
    // all visible in the fault counters, all still answering partially.
    for _ in 0..8 {
        let req = Request::Knn {
            series: queries()[0].clone(),
            k: 1,
            config: None,
            allow_partial: true,
        };
        match dispatch_routed(&req, &mrouter).unwrap() {
            Response::Knn(b) => assert_eq!(b.degraded, vec![1]),
            other => panic!("{other:?}"),
        }
    }
    let (_retries, _failovers, opens, probes, degraded) = metrics.fault_summary();
    assert!(opens >= 1, "circuit opened: {:?}", metrics.fault_summary());
    assert!(probes >= 1, "half-open probes admitted: {:?}", metrics.fault_summary());
    assert!(degraded as usize >= queries().len(), "{:?}", metrics.fault_summary());

    // The wire surface carries the annotation too (v2 envelope).
    let rm = Metrics::new();
    let tracer = mrtuner::trace::TraceHandle::disabled();
    let resp = route_line(
        r#"{"v":2,"id":9,"type":"knn","series":[1,2,3,4],"k":1,"allow_partial":true}"#,
        &mrouter,
        &rm,
        &tracer,
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    let degraded_wire = resp.get("degraded").and_then(Json::as_arr).unwrap();
    assert_eq!(degraded_wire.len(), 1);
    assert_eq!(degraded_wire[0].as_usize(), Some(1));

    drop(mrouter);
    drop(proxy);
    shutdown(fleet.into_iter().flatten().collect());
}

#[test]
fn slow_replies_burn_the_deadline_to_a_typed_error() {
    // Shard 0's only replica answers everything 500ms late — alive, just
    // far slower than the request's budget. No failover target exists,
    // so the deadline is the only thing that can end the wait.
    let fleet = spawn_replicated_fleet(1);
    let plan = FaultPlan::new(3).with_default(Fault::DelayReplyMs(500));
    let proxy = FaultProxy::spawn(&fleet[0][0].addr, plan).unwrap();
    let groups = vec![
        vec![proxy.addr().to_string()],
        vec![fleet[1][0].addr.clone()],
    ];
    let metrics = Arc::new(Metrics::new());
    // Startup handshake tolerates the delay (30s read timeout).
    let router = ShardRouter::connect_groups(&groups, Arc::clone(&metrics)).unwrap();
    let mrouter = Mutex::new(router);

    let rm = Metrics::new();
    let tracer = mrtuner::trace::TraceHandle::disabled();
    let resp = route_line(
        r#"{"v":2,"id":3,"type":"knn","series":[1,2,3,4],"k":1,"deadline_ms":8}"#,
        &mrouter,
        &rm,
        &tracer,
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
    assert_eq!(
        resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("deadline_exceeded"),
        "{resp:?}"
    );
    assert_eq!(rm.proto_error_count(ErrorCode::DeadlineExceeded), 1);

    // allow_partial does not rescue a spent deadline: a partial answer
    // you waited too long for helps nobody.
    let resp = route_line(
        r#"{"v":2,"id":4,"type":"knn","series":[1,2,3,4],"k":1,"deadline_ms":8,"allow_partial":true}"#,
        &mrouter,
        &rm,
        &tracer,
    );
    assert_eq!(
        resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("deadline_exceeded"),
        "{resp:?}"
    );

    // Without a deadline the same fleet still answers (slowly but
    // completely) — the fault is latency, not loss.
    let resp = route_line(
        r#"{"v":2,"id":5,"type":"knn","series":[1,2,3,4],"k":1}"#,
        &mrouter,
        &rm,
        &tracer,
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");

    drop(mrouter);
    drop(proxy);
    shutdown(fleet.into_iter().flatten().collect());
}
