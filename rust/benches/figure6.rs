//! E3 — regenerate the paper's **Figure 6**: sample de-noised CPU series of
//! Exim vs WordCount and Exim vs TeraSort under the same configuration set,
//! shown DTW-aligned (ASCII sketch + CSV).
//!
//! Run with: `cargo bench --bench figure6`

use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::{ConfigGrid, SystemConfig};
use mrtuner::dtw::{band_radius, banded::dtw_banded, corr::similarity_from_alignment};
use mrtuner::prelude::*;

fn sketch(s: &[f64]) -> String {
    let n = 72.min(s.len());
    (0..n)
        .map(|i| {
            let v = s[i * s.len() / n];
            char::from_digit((v * 9.99) as u32, 10).unwrap_or('?')
        })
        .collect()
}

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::paper_table1();
    let sc = SystemConfig::default();
    let p = Profiler::new(&sc, None);

    println!("== Figure 6: aligned sample series (same configuration set) ==");
    for cfg in &grid.configs {
        let exim = p.profile_one(AppId::EximParse, cfg);
        println!("\nconfig {} (exim len {}s):", cfg.label(), exim.raw_len);
        println!("  exim        {}", sketch(&exim.series));
        for app in [AppId::WordCount, AppId::TeraSort] {
            let r = p.profile_one(app, cfg);
            let align = dtw_banded(
                &exim.series,
                &r.series,
                band_radius(exim.series.len(), r.series.len()),
            );
            let warped = align.warp_onto_x(&r.series, exim.series.len());
            let sim = similarity_from_alignment(&align, &exim.series, &r.series);
            println!("  {:10}  {}  sim={sim:5.1}%", app.name(), sketch(&warped));
        }
    }
    println!(
        "\n(the paper's visual: Exim and WordCount curves nearly coincide; \
         TeraSort's shape deviates — the warped sketches above show the same)"
    );

    // CSV for plotting.
    let cfg = grid.configs[0];
    let exim = p.profile_one(AppId::EximParse, &cfg);
    println!("\ncsv (config {}):", cfg.label());
    println!("pair,t,exim,reference_warped");
    for app in [AppId::WordCount, AppId::TeraSort] {
        let r = p.profile_one(app, &cfg);
        let align = dtw_banded(
            &exim.series,
            &r.series,
            band_radius(exim.series.len(), r.series.len()),
        );
        let warped = align.warp_onto_x(&r.series, exim.series.len());
        for (t, (x, y)) in exim.series.iter().zip(&warped).enumerate() {
            println!("exim-vs-{},{t},{x:.5},{y:.5}", app.name());
        }
    }
}
