//! Thread-safe runtime access: a dedicated service thread owns the
//! non-`Send` [`Runtime`]; [`RuntimeHandle`] is a cheap, cloneable,
//! `Send + Sync` handle the coordinator's worker threads use.

use super::client::{BatchOutput, Padded};
#[cfg(feature = "pjrt")]
use super::client::Runtime;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::mpsc;
use std::thread;

enum Request {
    Preprocess {
        series: Padded,
        reply: mpsc::Sender<Result<Padded>>,
    },
    DtwBatch {
        query: Padded,
        refs: Vec<Padded>,
        reply: mpsc::Sender<Result<BatchOutput>>,
    },
    MatchOne {
        raw_query: Padded,
        refs: Vec<Padded>,
        reply: mpsc::Sender<Result<(Padded, BatchOutput)>>,
    },
    Shutdown,
}

/// Cloneable handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Request>,
    batch: usize,
    buckets: Vec<usize>,
}

/// Owns the service thread; dropping shuts it down.
pub struct RuntimeService {
    handle: RuntimeHandle,
    join: Option<thread::JoinHandle<()>>,
}

impl RuntimeService {
    /// Start the service, compiling artifacts from `dir`.
    ///
    /// Without the `pjrt` cargo feature there is no PJRT client to compile
    /// them on, so this always errors and callers fall back to pure Rust.
    #[cfg(not(feature = "pjrt"))]
    pub fn start(dir: &Path) -> Result<RuntimeService> {
        Err(anyhow!(
            "built without the `pjrt` feature; cannot load artifacts from {}",
            dir.display()
        ))
    }

    /// Start the service, compiling artifacts from `dir`.
    #[cfg(feature = "pjrt")]
    pub fn start(dir: &Path) -> Result<RuntimeService> {
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, Vec<usize>)>>();
        let join = thread::Builder::new()
            .name("mrtuner-runtime".into())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let m = rt.manifest();
                        let _ = ready_tx.send(Ok((m.batch, m.buckets.clone())));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::Preprocess { series, reply } => {
                            let _ = reply.send(runtime.preprocess(&series));
                        }
                        Request::DtwBatch { query, refs, reply } => {
                            let _ = reply.send(runtime.dtw_batch(&query, &refs));
                        }
                        Request::MatchOne {
                            raw_query,
                            refs,
                            reply,
                        } => {
                            let _ = reply.send(runtime.match_one(&raw_query, &refs));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn runtime thread");
        let (batch, buckets) = ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during startup"))??;
        Ok(RuntimeService {
            handle: RuntimeHandle {
                tx,
                batch,
                buckets,
            },
            join: Some(join),
        })
    }

    /// Start from the default artifact directory if it exists.
    pub fn try_default() -> Option<RuntimeService> {
        let dir = super::artifacts::Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match RuntimeService::start(&dir) {
            Ok(s) => Some(s),
            Err(e) => {
                log::warn!("artifacts present but unusable ({e:#}); using Rust fallback");
                None
            }
        }
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    /// Manifest batch size (lanes per dtw_batch/match_one execution).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Available shape buckets (sorted ascending).
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket fitting `len`, else the largest (resample case).
    pub fn bucket_for(&self, len: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .unwrap_or_else(|| *self.buckets.last().expect("nonempty buckets"))
    }

    fn call<T>(&self, build: impl FnOnce(mpsc::Sender<Result<T>>) -> Request) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(build(reply))
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread gone"))?
    }

    /// Chebyshev de-noise + normalize on the PJRT path.
    pub fn preprocess(&self, series: Padded) -> Result<Padded> {
        self.call(|reply| Request::Preprocess { series, reply })
    }

    /// Batched DTW on the PJRT path.
    pub fn dtw_batch(&self, query: Padded, refs: Vec<Padded>) -> Result<BatchOutput> {
        self.call(|reply| Request::DtwBatch { query, refs, reply })
    }

    /// Fused preprocess + batched DTW on the PJRT path.
    pub fn match_one(&self, raw_query: Padded, refs: Vec<Padded>) -> Result<(Padded, BatchOutput)> {
        self.call(|reply| Request::MatchOne {
            raw_query,
            refs,
            reply,
        })
    }
}
