//! Measurement-noise model for the simulated SysStat sampler.
//!
//! The paper's pipeline assumes the captured CPU series are "noisy due to
//! temporal changes coming from unknown devices states" (§3.1.1) and
//! de-noises them with the Chebyshev filter. The simulator reproduces that
//! property with a seeded model: white Gaussian jitter plus sparse positive
//! spikes (background daemons waking up).

use crate::util::rng::Rng;

/// Noise model parameters.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Standard deviation of the Gaussian jitter (utilization fraction).
    pub jitter_std: f64,
    /// Per-sample probability of a daemon spike.
    pub spike_prob: f64,
    /// Spike amplitude upper bound (uniform in [0, spike_max]).
    pub spike_max: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            jitter_std: 0.035,
            spike_prob: 0.04,
            spike_max: 0.22,
        }
    }
}

impl NoiseModel {
    /// No noise (for deterministic tests).
    pub fn none() -> NoiseModel {
        NoiseModel {
            jitter_std: 0.0,
            spike_prob: 0.0,
            spike_max: 0.0,
        }
    }

    /// Apply noise to a clean utilization series, clamping into `[0,1]`.
    pub fn apply(&self, clean: &[f64], rng: &mut Rng) -> Vec<f64> {
        clean
            .iter()
            .map(|&u| {
                let mut v = u + rng.normal_ms(0.0, self.jitter_std);
                if self.spike_prob > 0.0 && rng.chance(self.spike_prob) {
                    v += rng.range_f64(0.0, self.spike_max);
                }
                v.clamp(0.0, 1.0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let clean = vec![0.1, 0.5, 0.9];
        let mut rng = Rng::new(1);
        assert_eq!(NoiseModel::none().apply(&clean, &mut rng), clean);
    }

    #[test]
    fn output_clamped() {
        let clean = vec![0.0, 1.0, 0.5, 0.02, 0.98];
        let model = NoiseModel {
            jitter_std: 0.5,
            spike_prob: 0.5,
            spike_max: 1.0,
        };
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            for v in model.apply(&clean, &mut rng) {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let clean: Vec<f64> = (0..50).map(|i| (i as f64 / 50.0)).collect();
        let model = NoiseModel::default();
        let a = model.apply(&clean, &mut Rng::new(7));
        let b = model.apply(&clean, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn noise_is_roughly_unbiased_midrange() {
        let clean = vec![0.5; 20_000];
        let model = NoiseModel {
            jitter_std: 0.03,
            spike_prob: 0.0,
            spike_max: 0.0,
        };
        let noisy = model.apply(&clean, &mut Rng::new(11));
        let mean = crate::util::stats::mean(&noisy);
        assert!((mean - 0.5).abs() < 0.002, "mean={mean}");
    }

    #[test]
    fn chebyshev_recovers_clean_shape() {
        // End-to-end sanity: filter(noisy) correlates far better with clean
        // than noisy does — the premise of the paper's pre-processing.
        let clean: Vec<f64> = (0..300)
            .map(|i| 0.5 + 0.4 * ((i as f64) * 0.05).sin())
            .collect();
        let noisy = NoiseModel::default().apply(&clean, &mut Rng::new(3));
        let filtered = crate::signal::chebyshev::Sos::lowpass_default().filter(&noisy);
        // The IIR filter introduces a group delay, so compare at the best
        // lag (both series in a real comparison share the delay, so it
        // cancels there). Skip the settle-in transient.
        let best_lag_corr = |a: &[f64], b: &[f64]| -> f64 {
            (0..30)
                .map(|lag| crate::util::stats::pearson(&a[60..a.len() - 30], &b[60 + lag..b.len() - 30 + lag]))
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let c_noisy = best_lag_corr(&clean, &noisy);
        let c_filt = best_lag_corr(&clean, &filtered);
        // High-frequency noise energy must drop an order of magnitude.
        let hf_energy = |s: &[f64]| -> f64 {
            s.windows(2).map(|w| (w[1] - w[0]) * (w[1] - w[0])).sum::<f64>() / (s.len() - 1) as f64
        };
        assert!(
            hf_energy(&filtered[60..]) < hf_energy(&noisy[60..]) / 10.0,
            "noise not removed: {} vs {}",
            hf_energy(&filtered[60..]),
            hf_energy(&noisy[60..])
        );
        assert!(c_filt > 0.97, "filtered corr too low: {c_filt} (noisy {c_noisy})");
    }
}
