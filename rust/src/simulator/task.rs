//! Task phase models: how a workload's cost model and a job configuration
//! turn into concrete map/reduce task specifications.

use super::cluster::ClusterConfig;
use super::job::JobConfig;
use crate::util::rng::Rng;
use crate::workloads::{CostModel, Workload};

/// What kind of work a phase does (drives the utilization accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    /// JVM fork + task setup.
    Startup,
    /// Read input split and run the map function.
    MapProcess,
    /// Sort/spill/combine map output.
    Spill,
    /// Write intermediate data to local disk.
    MapWrite,
    /// Copy map outputs (gated on map completions).
    Shuffle,
    /// Merge-sort shuffled runs.
    MergeSort,
    /// Run the reduce function.
    ReduceProcess,
    /// Write final output to HDFS.
    OutputWrite,
}

/// One task phase: concurrent CPU work (dedicated-core seconds) and disk
/// work (MB); the phase completes when both are exhausted. While CPU work
/// remains the task consumes its CPU share; once only IO remains it
/// contributes `idle_cpu_frac` (iowait-ish overhead).
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub kind: PhaseKind,
    pub cpu_secs: f64,
    pub io_mb: f64,
    pub idle_cpu_frac: f64,
    /// Minimum wall-clock duration (heartbeat scheduling latency, JVM
    /// fork, shuffle fetch round-trips) — Hadoop 0.20's fixed overheads.
    pub fixed_secs: f64,
}

/// Memory footprint (MB) a task charges its node while in a given phase —
/// sort buffers dominate (io.sort.mb ≈ 100 MB in Hadoop 0.20).
pub fn phase_mem_mb(kind: PhaseKind, data_mb: f64) -> f64 {
    match kind {
        PhaseKind::Startup => 60.0,
        PhaseKind::MapProcess => 120.0 + 0.2 * data_mb,
        PhaseKind::Spill => 100.0 + 0.5 * data_mb,
        PhaseKind::MapWrite => 80.0,
        PhaseKind::Shuffle => 140.0 + 0.7 * data_mb,
        PhaseKind::MergeSort => 100.0 + 1.0 * data_mb,
        PhaseKind::ReduceProcess => 120.0 + 0.3 * data_mb,
        PhaseKind::OutputWrite => 80.0,
    }
}

/// Whether a task is a mapper or a reducer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Map { index: usize },
    Reduce { index: usize },
}

/// A fully specified simulated task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub kind: TaskKind,
    pub phases: Vec<Phase>,
    /// Per-task speed factor (lognormal straggler jitter, multiplies CPU).
    pub speed: f64,
    /// For reducers: total shuffle bytes expected from each map task (MB).
    pub shuffle_per_map_mb: f64,
}

/// Everything the engine needs to run one job.
#[derive(Debug, Clone)]
pub struct JobPlan {
    pub maps: Vec<TaskSpec>,
    pub reduces: Vec<TaskSpec>,
    /// Per-map intermediate output (MB).
    pub map_out_mb: f64,
    /// Partition weights assigning map-output shares to reducers
    /// (sums to 1; the engine needs them to credit shuffle bytes per
    /// completed map, and to re-partition on mid-run reconfiguration).
    pub weights: Vec<f64>,
}

/// Build one map task's phase model. Factored out of [`plan_job`] so the
/// engine can plan *replacement* maps mid-run (self-tuning reconfiguration)
/// with exactly the same cost expressions.
pub fn map_spec(
    index: usize,
    per_map_mb: f64,
    per_map_out: f64,
    costs: &CostModel,
    speed: f64,
) -> TaskSpec {
    TaskSpec {
        kind: TaskKind::Map { index },
        speed,
        shuffle_per_map_mb: 0.0,
        phases: vec![
            Phase {
                kind: PhaseKind::Startup,
                cpu_secs: costs.startup_cpu_s,
                io_mb: 2.0, // jar + split metadata
                idle_cpu_frac: 0.15,
                fixed_secs: 3.0, // heartbeat-paced task assignment
            },
            Phase {
                kind: PhaseKind::MapProcess,
                cpu_secs: per_map_mb * costs.map_cpu_s_per_mb,
                io_mb: per_map_mb,
                idle_cpu_frac: 0.08,
                fixed_secs: 0.0,
            },
            Phase {
                kind: PhaseKind::Spill,
                cpu_secs: per_map_out * costs.sort_cpu_s_per_mb,
                io_mb: per_map_out, // spill write passes
                idle_cpu_frac: 0.12,
                fixed_secs: 0.0,
            },
            Phase {
                kind: PhaseKind::MapWrite,
                cpu_secs: per_map_out * 0.02,
                io_mb: per_map_out,
                idle_cpu_frac: 0.06,
                fixed_secs: 1.0, // commit round trip
            },
        ],
    }
}

/// Build one reduce task's phase model from its expected partition bytes.
/// Shared by [`plan_job`] and the engine's mid-run re-partitioning.
pub fn reduce_spec(
    index: usize,
    part_mb: f64,
    shuffle_per_map_mb: f64,
    costs: &CostModel,
    speed: f64,
) -> TaskSpec {
    let out_mb = part_mb * costs.reduce_selectivity;
    TaskSpec {
        kind: TaskKind::Reduce { index },
        speed,
        shuffle_per_map_mb,
        phases: vec![
            Phase {
                kind: PhaseKind::Startup,
                cpu_secs: costs.startup_cpu_s,
                io_mb: 2.0,
                idle_cpu_frac: 0.15,
                fixed_secs: 3.0,
            },
            Phase {
                kind: PhaseKind::Shuffle,
                cpu_secs: part_mb * 0.08, // checksum + in-flight merge
                io_mb: part_mb,
                idle_cpu_frac: 0.05,
                fixed_secs: 5.0, // fetch round trips per map wave
            },
            Phase {
                kind: PhaseKind::MergeSort,
                cpu_secs: part_mb * costs.sort_cpu_s_per_mb,
                io_mb: part_mb * 1.4, // merge read+write passes
                idle_cpu_frac: 0.25,
                fixed_secs: 0.0,
            },
            Phase {
                kind: PhaseKind::ReduceProcess,
                cpu_secs: part_mb * costs.reduce_cpu_s_per_mb,
                io_mb: 0.0,
                idle_cpu_frac: 0.0,
                fixed_secs: 0.0,
            },
            Phase {
                kind: PhaseKind::OutputWrite,
                cpu_secs: out_mb * 0.02,
                io_mb: out_mb,
                idle_cpu_frac: 0.06,
                fixed_secs: 1.0,
            },
        ],
    }
}

/// Build the task plan for `(workload, config)` on `cluster`.
pub fn plan_job(
    workload: &dyn Workload,
    config: &JobConfig,
    cluster: &ClusterConfig,
    rng: &mut Rng,
) -> JobPlan {
    let costs: CostModel = workload.default_costs();
    let num_maps = config.num_map_tasks();
    let num_reduces = config.reducers.max(1);
    let per_map_mb = config.input_mb / num_maps as f64;
    let map_out_total = config.input_mb * costs.map_selectivity;
    let per_map_out = map_out_total / num_maps as f64;
    let weights = workload.partition_weights(num_reduces, rng);
    debug_assert!((weights.iter().sum::<f64>() - 1.0).abs() < 1e-6);

    let jitter = |rng: &mut Rng| {
        if cluster.task_jitter > 0.0 {
            rng.lognormal(0.0, cluster.task_jitter)
        } else {
            1.0
        }
    };

    let maps = (0..num_maps)
        .map(|index| map_spec(index, per_map_mb, per_map_out, &costs, jitter(rng)))
        .collect();

    let reduces = (0..num_reduces)
        .map(|index| {
            let part_mb = map_out_total * weights[index];
            let speed = jitter(rng);
            reduce_spec(index, part_mb, per_map_out * weights[index], &costs, speed)
        })
        .collect();

    JobPlan {
        maps,
        reduces,
        map_out_mb: per_map_out,
        weights,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{workload_for, AppId};

    fn plan(app: AppId, cfg: JobConfig) -> JobPlan {
        let w = workload_for(app);
        let cluster = ClusterConfig::pseudo_distributed();
        plan_job(w.as_ref(), &cfg, &cluster, &mut Rng::new(1))
    }

    #[test]
    fn plan_counts_follow_config() {
        let p = plan(AppId::WordCount, JobConfig::new(11, 6, 20.0, 30.0));
        assert_eq!(p.maps.len(), 11);
        assert_eq!(p.reduces.len(), 6);
    }

    #[test]
    fn shuffle_mass_conserved() {
        // Sum over reducers of expected shuffle equals total map output.
        let cfg = JobConfig::new(8, 5, 10.0, 40.0);
        let w = workload_for(AppId::TeraSort);
        let cluster = ClusterConfig::pseudo_distributed();
        let p = plan_job(w.as_ref(), &cfg, &cluster, &mut Rng::new(2));
        let per_map_total: f64 = p.reduces.iter().map(|r| r.shuffle_per_map_mb).sum();
        assert!(
            (per_map_total - p.map_out_mb).abs() < 1e-9,
            "{per_map_total} vs {}",
            p.map_out_mb
        );
        let shuffle_total: f64 = p
            .reduces
            .iter()
            .map(|r| r.shuffle_per_map_mb * p.maps.len() as f64)
            .sum();
        let expected = cfg.input_mb * w.default_costs().map_selectivity;
        assert!((shuffle_total - expected).abs() < 1e-6);
    }

    #[test]
    fn wordcount_maps_are_cpu_dominated() {
        let p = plan(AppId::WordCount, JobConfig::new(4, 2, 10.0, 40.0));
        let mp = &p.maps[0].phases[1];
        assert_eq!(mp.kind, PhaseKind::MapProcess);
        // CPU seconds far exceed what the disk needs (60 MB/s → io secs).
        assert!(mp.cpu_secs > 10.0 * mp.io_mb / 60.0);
    }

    #[test]
    fn terasort_reduces_dominate_maps() {
        let p = plan(AppId::TeraSort, JobConfig::new(4, 4, 10.0, 40.0));
        let map_cpu: f64 = p.maps.iter().flat_map(|t| &t.phases).map(|ph| ph.cpu_secs).sum();
        let red_cpu: f64 = p.reduces.iter().flat_map(|t| &t.phases).map(|ph| ph.cpu_secs).sum();
        // TeraSort sorts on both sides (map spill + reduce merge) but the
        // reduce side adds the merge + reduce-function cost on the full
        // data volume: reduce CPU must dominate.
        assert!(red_cpu > 1.2 * map_cpu, "map={map_cpu} red={red_cpu}");
    }

    #[test]
    fn jitter_disabled_gives_unit_speed() {
        let w = workload_for(AppId::Grep);
        let mut cluster = ClusterConfig::pseudo_distributed();
        cluster.task_jitter = 0.0;
        let p = plan_job(
            w.as_ref(),
            &JobConfig::new(3, 2, 10.0, 30.0),
            &cluster,
            &mut Rng::new(3),
        );
        assert!(p.maps.iter().all(|t| t.speed == 1.0));
    }

    #[test]
    fn phase_mem_positive() {
        for kind in [
            PhaseKind::Startup,
            PhaseKind::MapProcess,
            PhaseKind::Spill,
            PhaseKind::MapWrite,
            PhaseKind::Shuffle,
            PhaseKind::MergeSort,
            PhaseKind::ReduceProcess,
            PhaseKind::OutputWrite,
        ] {
            assert!(phase_mem_mb(kind, 10.0) > 0.0);
        }
    }
}
