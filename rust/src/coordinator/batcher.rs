//! Comparison batcher: packs (query, references) comparisons into full
//! `dtw_batch` PJRT executions and post-processes the traceback into the
//! paper's correlation similarity. This is the matching-phase hot loop.

use crate::runtime::{BatchOutput, Padded, RuntimeHandle};
use crate::util::stats::pearson;

/// Similarity (%) from one batch lane: backtrack the choice matrix, warp
/// the reference onto the query axis, correlate (paper eqn. 3).
/// Reuses caller-provided scratch to avoid allocation in the hot loop.
pub fn lane_similarity(
    query: &[f32],
    nx: usize,
    reference: &[f32],
    ny: usize,
    choices: &[i8],
    bucket: usize,
    warped: &mut Vec<f64>,
    qbuf: &mut Vec<f64>,
) -> f64 {
    debug_assert!(nx >= 1 && ny >= 1);
    debug_assert_eq!(choices.len(), bucket * bucket);
    // Backtrack over the valid sub-matrix; the choice matrix is row-major
    // over the full bucket, so index with the bucket stride.
    warped.clear();
    warped.resize(nx, 0.0);
    qbuf.clear();
    qbuf.extend(query[..nx].iter().map(|&v| v as f64));

    // Walk the path backwards. The forward construction keeps the *last*
    // (largest-j) visit per row, which is the first time the backward walk
    // touches a row — so only write on row change.
    let (mut i, mut j) = (nx - 1, ny - 1);
    let mut last_row = usize::MAX;
    loop {
        if i != last_row {
            warped[i] = reference[j] as f64;
            last_row = i;
        }
        if i == 0 && j == 0 {
            break;
        }
        if i == 0 {
            j -= 1;
            continue;
        }
        if j == 0 {
            i -= 1;
            continue;
        }
        match choices[i * bucket + j] as u8 {
            crate::dtw::CHOICE_DIAG => {
                i -= 1;
                j -= 1;
            }
            crate::dtw::CHOICE_UP => i -= 1,
            _ => j -= 1,
        }
    }
    (pearson(qbuf, warped).max(0.0) * 100.0).min(100.0)
}

/// Batched similarity computation against a set of references.
///
/// References are grouped by padded bucket; each group runs through the
/// fused `match_one` artifact in chunks of the manifest batch size (the
/// final chunk is padded with copies of the first reference and the
/// extra lanes discarded).
pub struct Batcher {
    runtime: RuntimeHandle,
}

impl Batcher {
    pub fn new(runtime: RuntimeHandle) -> Batcher {
        Batcher { runtime }
    }

    /// Similarities (%) of `raw_query` against each reference series.
    /// `raw_query` is the noisy capture; references are already
    /// preprocessed (as stored in the database).
    pub fn similarities(
        &self,
        raw_query: &[f64],
        references: &[Vec<f64>],
    ) -> anyhow::Result<Vec<f64>> {
        if references.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.runtime.batch();
        let max_ref = references.iter().map(|r| r.len()).max().unwrap_or(1);
        let bucket = self.runtime.bucket_for(raw_query.len().max(max_ref));
        let query = Padded::fit(raw_query, bucket);
        let refs: Vec<Padded> = references.iter().map(|r| Padded::fit(r, bucket)).collect();

        let mut sims = Vec::with_capacity(references.len());
        let mut warped = Vec::new();
        let mut qbuf = Vec::new();
        for chunk in refs.chunks(b) {
            let mut lane_refs: Vec<Padded> = chunk.to_vec();
            while lane_refs.len() < b {
                lane_refs.push(chunk[0].clone()); // discarded padding lane
            }
            let (q, out): (Padded, BatchOutput) =
                self.runtime.match_one(query.clone(), lane_refs)?;
            for (lane, r) in chunk.iter().enumerate() {
                let sim = lane_similarity(
                    &q.data,
                    q.len,
                    &refs[sims.len()].data,
                    r.len,
                    out.lane_choices(lane),
                    bucket,
                    &mut warped,
                    &mut qbuf,
                );
                sims.push(sim);
            }
        }
        Ok(sims)
    }
}

/// Execution-mode policy for the similarity hot path.
///
/// `MRTUNER_MODE` overrides: `pjrt` (always use the compiled artifacts),
/// `rust` (always the native fallback), `auto` (default — use PJRT for
/// small buckets where batch amortization keeps it competitive on the
/// CPU-interpret build, native Rust for the large ones; on a real TPU
/// deployment set `pjrt`). Decided per call from the padded bucket size.
/// §Perf in EXPERIMENTS.md records the measured crossover.
pub fn use_pjrt_for_bucket(bucket: usize) -> bool {
    match std::env::var("MRTUNER_MODE").as_deref() {
        Ok("pjrt") => true,
        Ok("rust") => false,
        _ => bucket <= 128,
    }
}

/// Route one similarity batch through PJRT or the native path per the
/// mode policy above.
pub fn similarities_auto(
    runtime: Option<&RuntimeHandle>,
    raw_query: &[f64],
    references: &[Vec<f64>],
) -> Vec<f64> {
    if references.is_empty() {
        return Vec::new();
    }
    if let Some(rt) = runtime {
        let max_ref = references.iter().map(|r| r.len()).max().unwrap_or(1);
        let bucket = rt.bucket_for(raw_query.len().max(max_ref));
        if use_pjrt_for_bucket(bucket) {
            match Batcher::new(rt.clone()).similarities(raw_query, references) {
                Ok(s) => return s,
                Err(e) => log::warn!("runtime matching failed ({e:#}); falling back"),
            }
        }
    }
    similarities_fallback(raw_query, references)
}

/// Matching-pipeline query preparation: cap a raw capture at 512 samples
/// (linear resample) and de-noise + normalize it — the exact
/// transformation stored references went through. Shared by the
/// brute-force fallback, the index-backed matcher path and the serve
/// loop's `knn` command so every route compares like with like.
pub fn prepare_query(raw_query: &[f64]) -> Vec<f64> {
    let capped = if raw_query.len() > 512 {
        crate::signal::resample::linear(raw_query, 512)
    } else {
        raw_query.to_vec()
    };
    crate::signal::preprocess(&capped)
}

/// Pure-Rust fallback with identical semantics (used when no artifacts are
/// available, and by the parity tests).
pub fn similarities_fallback(raw_query: &[f64], references: &[Vec<f64>]) -> Vec<f64> {
    let q = prepare_query(raw_query);
    references
        .iter()
        .map(|r| crate::dtw::corr::similarity_percent_banded(&q, r))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::full::dtw;

    #[test]
    fn lane_similarity_matches_fallback_path() {
        // Build a pair, run Rust DTW to get choices in the same encoding,
        // and check lane_similarity agrees with the reference pipeline.
        let q: Vec<f64> = (0..40).map(|i| 0.5 + 0.4 * ((i as f64) * 0.3).sin()).collect();
        let r: Vec<f64> = (0..30).map(|i| 0.5 + 0.4 * ((i as f64) * 0.4).sin()).collect();
        let res = dtw(&q, &r);
        let expected = crate::dtw::corr::similarity_from_alignment(&res, &q, &r);

        // Recreate a bucket-shaped choice matrix from the Rust DP.
        let bucket = 64usize;
        let (n, m) = (q.len(), r.len());
        let mut choices = vec![0i8; bucket * bucket];
        // Recompute with the full matrix to extract choices.
        let full = full_choices(&q, &r);
        for i in 0..n {
            for j in 0..m {
                choices[i * bucket + j] = full[i * m + j] as i8;
            }
        }
        let qf: Vec<f32> = q
            .iter()
            .map(|&v| v as f32)
            .chain(std::iter::repeat(0.0).take(bucket - n))
            .collect();
        let rf: Vec<f32> = r
            .iter()
            .map(|&v| v as f32)
            .chain(std::iter::repeat(0.0).take(bucket - m))
            .collect();
        let mut warped = Vec::new();
        let mut qbuf = Vec::new();
        let got = lane_similarity(&qf, n, &rf, m, &choices, bucket, &mut warped, &mut qbuf);
        assert!(
            (got - expected).abs() < 0.05,
            "lane {got} vs reference {expected}"
        );
    }

    /// Rust DP returning the full choice matrix (test helper).
    fn full_choices(x: &[f64], y: &[f64]) -> Vec<u8> {
        use crate::dtw::{local_cost, CHOICE_DIAG, CHOICE_LEFT, CHOICE_UP};
        let (n, m) = (x.len(), y.len());
        let mut choices = vec![0u8; n * m];
        let mut prev = vec![0.0f64; m];
        let mut cur = vec![0.0f64; m];
        cur[0] = local_cost(x[0], y[0]);
        for j in 1..m {
            cur[j] = cur[j - 1] + local_cost(x[0], y[j]);
            choices[j] = CHOICE_LEFT;
        }
        std::mem::swap(&mut prev, &mut cur);
        for i in 1..n {
            let row = i * m;
            cur[0] = prev[0] + local_cost(x[i], y[0]);
            choices[row] = CHOICE_UP;
            for j in 1..m {
                let d = local_cost(x[i], y[j]);
                let (vg, vchoice) = if prev[j - 1] <= prev[j] {
                    (prev[j - 1], CHOICE_DIAG)
                } else {
                    (prev[j], CHOICE_UP)
                };
                if cur[j - 1] < vg {
                    cur[j] = cur[j - 1] + d;
                    choices[row + j] = CHOICE_LEFT;
                } else {
                    cur[j] = vg + d;
                    choices[row + j] = vchoice;
                }
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        choices
    }

    #[test]
    fn fallback_identical_series_is_100() {
        let q: Vec<f64> = (0..60).map(|i| 0.5 + 0.4 * ((i as f64) * 0.2).sin()).collect();
        // The fallback preprocesses the query but not the reference, so
        // feed a reference that IS the preprocessed query.
        let qp = crate::signal::preprocess(&q);
        let sims = similarities_fallback(&q, &[qp]);
        assert!(sims[0] > 99.0, "self similarity {}", sims[0]);
    }
}
