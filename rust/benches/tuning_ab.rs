//! A/B payoff bench (tuning layer): tuned-mid-run vs untuned completion
//! time — the repo's headline number.
//!
//! For each of six synthetic workloads (the five reference applications
//! at 60 MB plus WordCount at 160 MB) a job is started from the Hadoop
//! 0.20 default configuration (2 mappers, 1 reducer, 64 MB blocks) and
//! run twice from the same seed: once untouched, and once under
//! [`mrtuner::tuning::run_tuned`] — the closed loop that classifies the
//! live CPU stream against a clean reference database and re-plans the
//! not-yet-scheduled work under the matched application's grid-searched
//! optimal once the hysteresis gate is satisfied.
//!
//! Acceptance: the tuned run beats the untuned run on >= 4 of the 6
//! workloads. Results go to stdout and `BENCH_tuning.json` (the perf
//! trajectory file). `MRTUNER_BENCH_SMOKE=1` shrinks the optimal-search
//! grid for CI.
//!
//! Run with: `cargo bench --bench tuning_ab`

use mrtuner::database::profile::ProfileEntry;
use mrtuner::database::store::OptimalConfig;
use mrtuner::index::IndexedDb;
use mrtuner::signal;
use mrtuner::signal::noise::NoiseModel;
use mrtuner::simulator::cluster::ClusterConfig;
use mrtuner::simulator::engine::simulate;
use mrtuner::simulator::job::JobConfig;
use mrtuner::simulator::profile_run;
use mrtuner::streaming::DecisionPolicy;
use mrtuner::tuning::{run_tuned, ControllerPolicy};
use mrtuner::util::json::Json;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::{workload_for, AppId};
use std::time::Instant;

/// Shared profiling configuration for the reference captures (distinct
/// from both the Hadoop default and any grid optimum, so matching is
/// doing real work).
const PROFILE_CFG: JobConfig = JobConfig {
    mappers: 4,
    reducers: 2,
    split_mb: 16.0,
    input_mb: 60.0,
};

/// Noise-free completion time of `app` under `cfg`.
fn measure(app: AppId, cfg: &JobConfig, cluster: &ClusterConfig, seed: u64) -> f64 {
    let w = workload_for(app);
    simulate(w.as_ref(), cfg, cluster, &NoiseModel::none(), &mut Rng::new(seed)).completion_secs
}

/// Grid-search the best (M, R, FS) for `app` at `input_mb` — the paper's
/// expensive per-reference-app procedure the loop then transfers for
/// free. The smoke grid is a subset of the full one.
fn find_optimal(app: AppId, input_mb: f64, cluster: &ClusterConfig, smoke: bool) -> OptimalConfig {
    let (ms, rs, fss): (&[usize], &[usize], &[f64]) = if smoke {
        (&[4, 8, 16], &[2, 4, 8], &[8.0, 16.0, 32.0])
    } else {
        (&[2, 4, 8, 12, 16, 24, 32], &[1, 2, 4, 8, 12], &[8.0, 16.0, 32.0, 64.0])
    };
    let mut best: Option<OptimalConfig> = None;
    for &m in ms {
        for &r in rs {
            for &fs in fss {
                let cfg = JobConfig::new(m, r, fs, input_mb);
                let secs = measure(app, &cfg, cluster, 0x7e57);
                if best.as_ref().map_or(true, |b| secs < b.completion_secs) {
                    best = Some(OptimalConfig { config: cfg, completion_secs: secs });
                }
            }
        }
    }
    best.expect("nonempty grid")
}

/// Clean reference database: one profiled capture per application under
/// [`PROFILE_CFG`], plus its grid-searched cached optimal.
fn reference_db(cluster: &ClusterConfig, smoke: bool) -> IndexedDb {
    let mut idx = IndexedDb::new();
    for &app in AppId::all() {
        let res = profile_run(app, &PROFILE_CFG, &NoiseModel::none(), 21);
        let raw_len = res.cpu_clean.len();
        idx.insert(ProfileEntry {
            app,
            config: PROFILE_CFG,
            series: signal::preprocess(&res.cpu_clean),
            raw_len,
            completion_secs: res.completion_secs,
        });
        let best = find_optimal(app, PROFILE_CFG.input_mb, cluster, smoke);
        println!(
            "  optimal for {}: {} ({:.1}s)",
            app.name(),
            best.config.label(),
            best.completion_secs
        );
        idx.set_optimal(app, best);
    }
    idx
}

struct AbRow {
    workload: &'static str,
    app: AppId,
    input_mb: f64,
    untuned_secs: f64,
    tuned_secs: f64,
    decided: Option<AppId>,
    reconfigured_at: Option<f64>,
    applied: Option<JobConfig>,
    suppressed_flaps: u64,
    wall_ms: f64,
}

impl AbRow {
    fn speedup(&self) -> f64 {
        if self.tuned_secs > 0.0 {
            self.untuned_secs / self.tuned_secs
        } else {
            f64::INFINITY
        }
    }

    fn won(&self) -> bool {
        self.tuned_secs < self.untuned_secs
    }
}

fn run_scenario(
    workload: &'static str,
    app: AppId,
    input_mb: f64,
    idx: &IndexedDb,
    cluster: &ClusterConfig,
    seed: u64,
) -> AbRow {
    // Hadoop 0.20 default: the mis-tuned starting point both runs share.
    let start = JobConfig::new(2, 1, 64.0, input_mb);
    let w = workload_for(app);
    let untuned =
        simulate(w.as_ref(), &start, cluster, &NoiseModel::none(), &mut Rng::new(seed));
    let t0 = Instant::now();
    let tuned = run_tuned(
        app,
        &start,
        cluster,
        idx,
        DecisionPolicy::default(),
        ControllerPolicy::default(),
        &NoiseModel::none(),
        seed,
    );
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    AbRow {
        workload,
        app,
        input_mb,
        untuned_secs: untuned.completion_secs,
        tuned_secs: tuned.result.completion_secs,
        decided: tuned.decided_app,
        reconfigured_at: tuned.reconfigured_at,
        applied: tuned.applied,
        suppressed_flaps: tuned.suppressed_flaps,
        wall_ms,
    }
}

fn main() {
    mrtuner::util::logging::init();
    let smoke = std::env::var("MRTUNER_BENCH_SMOKE").is_ok();
    let cluster = ClusterConfig::pseudo_distributed();

    println!("== reference database (clean profiles + grid optima) ==");
    let idx = reference_db(&cluster, smoke);

    let scenarios: &[(&str, AppId, f64)] = &[
        ("wordcount", AppId::WordCount, 60.0),
        ("terasort", AppId::TeraSort, 60.0),
        ("exim", AppId::EximParse, 60.0),
        ("grep", AppId::Grep, 60.0),
        ("invertedindex", AppId::InvertedIndex, 60.0),
        ("wordcount-xl", AppId::WordCount, 160.0),
    ];

    println!("== tuned-mid-run vs untuned, Hadoop-default start ==");
    let mut rows = Vec::new();
    for (i, &(name, app, input_mb)) in scenarios.iter().enumerate() {
        let row = run_scenario(name, app, input_mb, &idx, &cluster, 0xab5eed ^ (i as u64));
        println!(
            "  {:14} untuned={:7.1}s tuned={:7.1}s speedup={:.2}x decided={} reconf_at={} flaps={} [{}] ({:.1}ms)",
            row.workload,
            row.untuned_secs,
            row.tuned_secs,
            row.speedup(),
            row.decided.map_or("-", |a| a.name()),
            row.reconfigured_at.map_or("-".to_string(), |t| format!("{t:.0}s")),
            row.suppressed_flaps,
            if row.won() { "WIN" } else { "loss" },
            row.wall_ms,
        );
        rows.push(row);
    }

    let wins = rows.iter().filter(|r| r.won()).count();
    let pass = wins >= 4;
    println!(
        "  acceptance: tuned beats untuned on {wins}/{} workloads (need >= 4): {}",
        rows.len(),
        if pass { "PASS" } else { "FAIL" }
    );

    let workload_rows = rows
        .iter()
        .map(|r| {
            let mut pairs = vec![
                ("workload", Json::Str(r.workload.into())),
                ("app", Json::Str(r.app.name().into())),
                ("input_mb", Json::Num(r.input_mb)),
                ("untuned_secs", Json::Num(r.untuned_secs)),
                ("tuned_secs", Json::Num(r.tuned_secs)),
                ("speedup", Json::Num(r.speedup())),
                ("win", Json::Bool(r.won())),
                ("suppressed_flaps", Json::Num(r.suppressed_flaps as f64)),
                ("wall_ms", Json::Num(r.wall_ms)),
            ];
            if let Some(a) = r.decided {
                pairs.push(("decided_app", Json::Str(a.name().into())));
            }
            if let Some(t) = r.reconfigured_at {
                pairs.push(("reconfigured_at_secs", Json::Num(t)));
            }
            if let Some(c) = r.applied {
                pairs.push(("applied", Json::Str(c.label())));
            }
            Json::obj(pairs)
        })
        .collect();

    let report = Json::obj(vec![
        ("bench", Json::Str("tuning_ab".into())),
        ("smoke", Json::Bool(smoke)),
        ("wins", Json::Num(wins as f64)),
        ("workloads", Json::Num(rows.len() as f64)),
        ("pass", Json::Bool(pass)),
        ("per_workload", Json::arr(workload_rows)),
    ]);
    std::fs::write("BENCH_tuning.json", report.to_pretty()).expect("write BENCH_tuning.json");
    println!("wrote BENCH_tuning.json");
}
