"""AOT lowering: jit the L2 entry points, dump HLO text + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``;
the Rust side unwraps with ``to_tuple()``.

Usage:  python -m compile.aot --out ../artifacts
Writes one ``<entry>.hlo.txt`` per (entry, shape bucket) plus
``manifest.json`` describing shapes/dtypes for the Rust runtime.

Python runs only here, at build time — never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: Shape buckets: series seconds after resampling (see DESIGN.md §3).
BUCKETS = (128, 256, 512)
#: Batch size for dtw_batch / match_one entries.
BATCH = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entries():
    """Yield (name, fn, example_args, metadata) for every artifact."""
    for L in BUCKETS:
        yield (
            f"preprocess_{L}",
            model.preprocess,
            (f32(L), i32(1)),
            {"kind": "preprocess", "len": L},
        )
        yield (
            f"dtw_pair_{L}",
            model.dtw_pair,
            (f32(L), f32(L), i32(1), i32(1)),
            {"kind": "dtw_pair", "len": L},
        )
        yield (
            f"dtw_batch_{BATCH}x{L}",
            model.dtw_batch,
            (f32(L), f32(BATCH, L), i32(1), i32(BATCH)),
            {"kind": "dtw_batch", "len": L, "batch": BATCH},
        )
        yield (
            f"match_one_{BATCH}x{L}",
            model.match_one,
            (f32(L), f32(BATCH, L), i32(1), i32(BATCH)),
            {"kind": "match_one", "len": L, "batch": BATCH},
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"batch": BATCH, "buckets": list(BUCKETS), "entries": []}
    for name, fn, example_args, meta in entries():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "inputs": [
                    {"shape": list(a.shape), "dtype": a.dtype.name}
                    for a in example_args
                ],
                **meta,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
