//! Perf bench (L3): end-to-end coordinator throughput — profiling phase
//! rate, matching phase latency (vote over a grid), and serve-mode request
//! latency. The headline numbers for EXPERIMENTS.md §Perf.
//!
//! Run with: `cargo bench --bench pipeline_perf`

#[path = "harness.rs"]
mod harness;

use harness::bench;
use mrtuner::coordinator::matcher::Matcher;
use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::profiler::Profiler;
use mrtuner::coordinator::server::{handle_request, ServerState};
use mrtuner::coordinator::{ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::prelude::*;
use mrtuner::util::json::Json;

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::random(12, 9);
    let sc = SystemConfig::default();

    // Profiling-phase throughput (12 configs, parallel).
    let profiler = Profiler::new(&sc, None);
    let stats = bench("profile wordcount over 12 configs (par)", 1, 5, || {
        profiler.profile(AppId::WordCount, &grid)
    });
    println!(
        "    -> {:.1} profiles/s",
        12.0 / stats.mean_s
    );

    // Matching-phase latency (vote over the grid, 2-app db).
    let mut sys = TuningSystem::new(sc.clone());
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);
    let matcher = Matcher::new(&sys.config, sys.runtime());
    bench("match exim over 12 configs (vote)", 1, 5, || {
        matcher.match_app(AppId::EximParse, &grid, &sys.db)
    });

    // Serve-mode request latency (in-process dispatch; one query against
    // every same-config reference).
    let cfg = grid.configs[0];
    let raw = profiler.profile_one(AppId::EximParse, &cfg);
    let state = ServerState {
        db: {
            let mut db = IndexedDb::new();
            for e in sys.db.entries() {
                db.insert(e.clone());
            }
            db
        },
        runtime: sys.runtime(),
        metrics: Metrics::new(),
        sessions: mrtuner::streaming::SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    };
    let req = Json::obj(vec![
        ("cmd", Json::Str("match".into())),
        ("series", Json::nums(&raw.series)),
        (
            "config",
            Json::obj(vec![
                ("mappers", Json::Num(cfg.mappers as f64)),
                ("reducers", Json::Num(cfg.reducers as f64)),
                ("split_mb", Json::Num(cfg.split_mb)),
                ("input_mb", Json::Num(cfg.input_mb)),
            ]),
        ),
    ])
    .to_string();
    bench("serve: match request (same-config refs)", 3, 50, || {
        handle_request(&req, &state).expect("request ok")
    });
    println!("\nserver metrics: {}", state.metrics.report());
}
