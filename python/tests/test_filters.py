"""Filter design vs scipy and the reference implementations."""

import numpy as np
import pytest
import scipy.signal as sig
from hypothesis import given, settings, strategies as st

from compile import filters


def test_paper_design_matches_scipy():
    want = sig.cheby1(6, 0.5, 0.1, output="sos")
    np.testing.assert_allclose(filters.PAPER_SOS, want, atol=1e-12)


@pytest.mark.parametrize("order", [2, 4, 6, 8])
@pytest.mark.parametrize("ripple", [0.1, 0.5, 1.0, 3.0])
@pytest.mark.parametrize("cutoff", [0.05, 0.1, 0.3, 0.6])
def test_design_space_matches_scipy(order, ripple, cutoff):
    ours = filters.cheby1_sos(order, ripple, cutoff)
    want = sig.cheby1(order, ripple, cutoff, output="sos")
    np.testing.assert_allclose(ours, want, atol=1e-9)


def test_sosfilt_matches_scipy():
    rng = np.random.default_rng(0)
    x = rng.random(200)
    ours = filters.sosfilt(filters.PAPER_SOS, x)
    want = sig.sosfilt(filters.PAPER_SOS, x)
    np.testing.assert_allclose(ours, want, atol=1e-12)


def test_invalid_designs_rejected():
    with pytest.raises(ValueError):
        filters.cheby1_sos(5, 0.5, 0.1)  # odd order
    with pytest.raises(ValueError):
        filters.cheby1_sos(6, -1.0, 0.1)
    with pytest.raises(ValueError):
        filters.cheby1_sos(6, 0.5, 1.5)


@settings(max_examples=25, deadline=None)
@given(
    order=st.sampled_from([2, 4, 6]),
    ripple=st.floats(0.05, 3.0),
    cutoff=st.floats(0.02, 0.9),
)
def test_design_is_always_stable(order, ripple, cutoff):
    sos = filters.cheby1_sos(order, ripple, cutoff)
    for _, _, _, _, a1, a2 in sos:
        # Poles strictly inside the unit circle.
        assert a2 < 1.0 + 1e-12
        assert abs(a1) < 1.0 + a2 + 1e-12
