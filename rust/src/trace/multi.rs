//! [`MultiTracker`]: fan one span stream out to several sinks at once —
//! e.g. an [`super::InMemoryTracker`] for test assertions plus a
//! [`super::ChromeTracker`] for export, or a [`super::FlightRecorder`]
//! always-on beside an on-demand exporter.
//!
//! Each sink allocates its own span ids, so the fan-out keeps a mapping
//! from its public ids to the per-sink ones. Sinks are **error
//! isolated**: a panicking sink is disabled (its slot goes dead, the
//! panic is counted in [`MultiTracker::errors`]) and the remaining sinks
//! keep recording — a broken exporter must never take down the serving
//! path it observes.

use super::{SpanId, Tracker};
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

struct Sink {
    tracker: Arc<dyn Tracker>,
    dead: AtomicBool,
}

/// Fan-out span sink; see the module docs.
pub struct MultiTracker {
    sinks: Vec<Sink>,
    next: AtomicU64,
    /// Public span id → the id each sink returned for it (index-aligned
    /// with `sinks`; 0 where a sink was dead at begin time).
    ids: Mutex<HashMap<SpanId, Vec<SpanId>>>,
    errors: AtomicU64,
}

impl MultiTracker {
    pub fn new(sinks: Vec<Arc<dyn Tracker>>) -> MultiTracker {
        MultiTracker {
            sinks: sinks
                .into_iter()
                .map(|tracker| Sink { tracker, dead: AtomicBool::new(false) })
                .collect(),
            next: AtomicU64::new(0),
            ids: Mutex::new(HashMap::new()),
            errors: AtomicU64::new(0),
        }
    }

    /// Panics swallowed (and sinks disabled) so far.
    pub fn errors(&self) -> u64 {
        // relaxed: independent monotone counter.
        self.errors.load(Ordering::Relaxed)
    }

    /// Sinks still accepting spans.
    pub fn live_sinks(&self) -> usize {
        // relaxed: dead flags are one-way and independent.
        self.sinks.iter().filter(|s| !s.dead.load(Ordering::Relaxed)).count()
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, HashMap<SpanId, Vec<SpanId>>> {
        self.ids.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Run one sink call behind a panic shield; a panic kills that sink
    /// only. Returns `None` if the sink was already dead or just died.
    fn shielded<T>(&self, i: usize, f: impl FnOnce(&dyn Tracker) -> T) -> Option<T> {
        let sink = &self.sinks[i];
        // relaxed: the flag is advisory — a racing call at death time at
        // worst double-counts one error.
        if sink.dead.load(Ordering::Relaxed) {
            return None;
        }
        match std::panic::catch_unwind(AssertUnwindSafe(|| f(sink.tracker.as_ref()))) {
            Ok(v) => Some(v),
            Err(_) => {
                sink.dead.store(true, Ordering::Relaxed);
                self.errors.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }
}

impl std::fmt::Debug for MultiTracker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MultiTracker")
            .field("sinks", &self.sinks.len())
            .field("live", &self.live_sinks())
            .finish()
    }
}

impl Tracker for MultiTracker {
    fn is_enabled(&self) -> bool {
        self.sinks
            .iter()
            .enumerate()
            .any(|(i, _)| self.shielded(i, |t| t.is_enabled()).unwrap_or(false))
    }

    fn begin(
        &self,
        name: &'static str,
        parent: SpanId,
        remote_parent: SpanId,
        now_ns: u64,
    ) -> SpanId {
        // relaxed: monotone id counter — uniqueness is all that matters.
        let id = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        // Map the public parent to each sink's own id before fanning out;
        // don't hold the lock across sink calls.
        let parents: Vec<SpanId> = match parent {
            0 => vec![0; self.sinks.len()],
            p => self
                .guard()
                .get(&p)
                .cloned()
                .unwrap_or_else(|| vec![0; self.sinks.len()]),
        };
        let per_sink: Vec<SpanId> = (0..self.sinks.len())
            .map(|i| {
                self.shielded(i, |t| t.begin(name, parents[i], remote_parent, now_ns))
                    .unwrap_or(0)
            })
            .collect();
        self.guard().insert(id, per_sink);
        id
    }

    fn end(&self, span: SpanId, now_ns: u64) {
        let Some(per_sink) = self.guard().remove(&span) else {
            return;
        };
        for (i, &sid) in per_sink.iter().enumerate() {
            if sid != 0 {
                self.shielded(i, |t| t.end(sid, now_ns));
            }
        }
    }

    fn event(&self, span: SpanId, name: &'static str, value: u64, now_ns: u64) {
        let per_sink = match self.guard().get(&span) {
            Some(v) => v.clone(),
            None => return,
        };
        for (i, &sid) in per_sink.iter().enumerate() {
            if sid != 0 {
                self.shielded(i, |t| t.event(sid, name, value, now_ns));
            }
        }
    }

    fn note(&self, span: SpanId, key: &'static str, text: &str, now_ns: u64) {
        let per_sink = match self.guard().get(&span) {
            Some(v) => v.clone(),
            None => return,
        };
        for (i, &sid) in per_sink.iter().enumerate() {
            if sid != 0 {
                self.shielded(i, |t| t.note(sid, key, text, now_ns));
            }
        }
    }

    fn sample_root(&self, key: u64) -> bool {
        // A root records if *any* live sink wants it; per-sink rates are
        // not supported (compose a SamplingTracker *around* the fan-out
        // for a uniform policy instead).
        self.sinks
            .iter()
            .enumerate()
            .any(|(i, _)| self.shielded(i, |t| t.sample_root(key)).unwrap_or(false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{ChromeTracker, InMemoryTracker, TraceHandle, VirtualClock};
    use crate::util::json::Json;

    #[test]
    fn every_sink_sees_the_same_tree_under_its_own_ids() {
        let mem = Arc::new(InMemoryTracker::new());
        let chrome = Arc::new(ChromeTracker::new());
        let multi = Arc::new(MultiTracker::new(vec![
            mem.clone() as Arc<dyn Tracker>,
            chrome.clone() as Arc<dyn Tracker>,
        ]));
        let h = TraceHandle::with_clock(multi.clone(), Arc::new(VirtualClock::new(7)));
        assert!(h.enabled());
        {
            let root = h.root_linked("request", 55);
            let child = root.child("handle");
            child.event("queries", 3);
            child.note("config", "M=2");
        }
        // In-memory sink: full tree with stitched remote parent.
        let spans = mem.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "request");
        assert_eq!(spans[0].remote_parent, 55);
        assert_eq!(spans[1].parent, spans[0].id, "child nests under the sink's own root id");
        assert_eq!(spans[1].events, vec![("queries", 3)]);
        // Chrome sink: both spans finished with payloads intact.
        assert_eq!(chrome.len(), 2);
        let doc = chrome.to_json();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let handle = &events[0]; // child ends first
        assert_eq!(handle.get("name").and_then(Json::as_str), Some("handle"));
        assert_eq!(
            handle.get("args").and_then(|a| a.get("queries")).and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(multi.errors(), 0);
        assert_eq!(multi.live_sinks(), 2);
    }

    /// A sink that panics on every call after construction.
    struct HostileSink;
    impl Tracker for HostileSink {
        fn is_enabled(&self) -> bool {
            true
        }
        fn begin(&self, _: &'static str, _: SpanId, _: SpanId, _: u64) -> SpanId {
            panic!("hostile begin")
        }
        fn end(&self, _: SpanId, _: u64) {
            panic!("hostile end")
        }
        fn event(&self, _: SpanId, _: &'static str, _: u64, _: u64) {
            panic!("hostile event")
        }
        fn note(&self, _: SpanId, _: &'static str, _: &str, _: u64) {
            panic!("hostile note")
        }
    }

    #[test]
    fn a_panicking_sink_is_isolated_and_disabled() {
        let mem = Arc::new(InMemoryTracker::new());
        let multi = Arc::new(MultiTracker::new(vec![
            Arc::new(HostileSink) as Arc<dyn Tracker>,
            mem.clone() as Arc<dyn Tracker>,
        ]));
        // Quiet the default panic hook for the intentional panic.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let h = TraceHandle::with_clock(multi.clone(), Arc::new(VirtualClock::new(2)));
        {
            let root = h.root("request");
            root.event("n", 1);
        }
        std::panic::set_hook(prev);
        assert_eq!(multi.errors(), 1, "one panic, counted once (sink dead afterwards)");
        assert_eq!(multi.live_sinks(), 1);
        // The healthy sink recorded the whole span anyway.
        let spans = mem.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].events, vec![("n", 1)]);
        assert!(spans[0].end_ns > spans[0].start_ns);
    }

    #[test]
    fn empty_fanout_reports_disabled() {
        let multi = MultiTracker::new(Vec::new());
        assert!(!multi.is_enabled());
        assert!(!multi.sample_root(1), "no sink wants anything");
    }
}
