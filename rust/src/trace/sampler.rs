//! [`SamplingTracker`]: head-based 1-in-N sampling as a [`Tracker`]
//! decorator.
//!
//! The decision is made once, at the root, from the request's *identity*
//! (v2 envelope id, session id) — not from ambient entropy — so it is
//! deterministic and reproducible: the same `(seed, n, key)` always
//! samples the same way, on every process that shares the seed. Combined
//! with wire propagation ([`super::TRACE_SAMPLED_OUT`] /
//! [`super::TraceHandle::wire_trace`]) this is what keeps distributed
//! stitching intact under sampling: the router decides per request, the
//! shards inherit the decision, and a sampled-in request yields the
//! *complete* cross-process tree while a sampled-out one yields nothing
//! anywhere.
//!
//! Everything below the root is unaffected: once a root records, all of
//! its children record into the inner sink as usual; once it is sampled
//! out, the inert [`super::Span`] guard never reaches this tracker at
//! all.

use super::{SpanId, Tracker};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// SplitMix64 finalizer: a cheap, well-mixed hash so consecutive request
/// ids don't alias into the same residue pattern.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// The sampling decision function, exposed so tests (and peers that need
/// to predict a decision) can evaluate it directly: sample `key` iff
/// `splitmix64(seed ^ key) % n == 0`. `n <= 1` samples everything.
pub fn decide(seed: u64, n: u64, key: u64) -> bool {
    n <= 1 || splitmix64(seed ^ key) % n == 0
}

/// Decorator recording roughly 1-in-`n` root spans (and everything under
/// them) into an inner sink. See the module docs for the determinism and
/// wire-propagation contract.
pub struct SamplingTracker {
    inner: Arc<dyn Tracker>,
    n: u64,
    seed: u64,
    sampled_in: AtomicU64,
    sampled_out: AtomicU64,
}

impl SamplingTracker {
    /// Sample 1-in-`n` roots with the default seed (0). `n <= 1` records
    /// everything (the decorator becomes a pass-through).
    pub fn new(inner: Arc<dyn Tracker>, n: u64) -> SamplingTracker {
        SamplingTracker::with_seed(inner, n, 0)
    }

    /// Sample 1-in-`n` roots, keyed by `seed`. Processes that must agree
    /// on decisions for the *same keys* share the seed; processes with
    /// independent traffic pick distinct seeds so they don't sample
    /// correlated residues.
    pub fn with_seed(inner: Arc<dyn Tracker>, n: u64, seed: u64) -> SamplingTracker {
        SamplingTracker {
            inner,
            n,
            seed,
            sampled_in: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
        }
    }

    /// The configured 1-in-N rate.
    pub fn rate(&self) -> u64 {
        self.n
    }

    /// Roots this tracker decided to record.
    pub fn sampled_in(&self) -> u64 {
        // relaxed: independent monotone counter.
        self.sampled_in.load(Ordering::Relaxed)
    }

    /// Roots this tracker decided to drop.
    pub fn sampled_out(&self) -> u64 {
        // relaxed: independent monotone counter.
        self.sampled_out.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for SamplingTracker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SamplingTracker")
            .field("n", &self.n)
            .field("seed", &self.seed)
            .finish()
    }
}

impl Tracker for SamplingTracker {
    fn is_enabled(&self) -> bool {
        self.inner.is_enabled()
    }

    fn begin(
        &self,
        name: &'static str,
        parent: SpanId,
        remote_parent: SpanId,
        now_ns: u64,
    ) -> SpanId {
        self.inner.begin(name, parent, remote_parent, now_ns)
    }

    fn end(&self, span: SpanId, now_ns: u64) {
        self.inner.end(span, now_ns);
    }

    fn event(&self, span: SpanId, name: &'static str, value: u64, now_ns: u64) {
        self.inner.event(span, name, value, now_ns);
    }

    fn note(&self, span: SpanId, key: &'static str, text: &str, now_ns: u64) {
        self.inner.note(span, key, text, now_ns);
    }

    fn sample_root(&self, key: u64) -> bool {
        // The inner sink keeps a veto (a nested SamplingTracker composes
        // as the product of the two rates).
        let keep = decide(self.seed, self.n, key) && self.inner.sample_root(key);
        // relaxed: independent monotone counters.
        if keep {
            self.sampled_in.fetch_add(1, Ordering::Relaxed);
        } else {
            self.sampled_out.fetch_add(1, Ordering::Relaxed);
        }
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InMemoryTracker, TraceHandle, VirtualClock};

    #[test]
    fn decision_is_deterministic_and_seed_sensitive() {
        for key in 0..200u64 {
            assert_eq!(decide(7, 4, key), decide(7, 4, key), "same inputs, same answer");
        }
        assert!(decide(0, 1, 42), "n=1 keeps everything");
        assert!(decide(9, 0, 42), "n=0 degrades to keep-everything");
        // Different seeds disagree on at least one key in a small window.
        assert!(
            (0..64u64).any(|k| decide(1, 4, k) != decide(2, 4, k)),
            "seed must influence the decision"
        );
    }

    #[test]
    fn rate_is_roughly_one_in_n() {
        let kept = (0..4096u64).filter(|&k| decide(3, 4, k)).count();
        // Binomial(4096, 1/4): ~1024 ± a generous window.
        assert!((800..1250).contains(&kept), "kept {kept} of 4096 at 1-in-4");
    }

    #[test]
    fn sampled_roots_record_full_subtrees_and_counters_track() {
        let sink = Arc::new(InMemoryTracker::new());
        let sampler = Arc::new(SamplingTracker::with_seed(sink.clone(), 4, 11));
        let h = TraceHandle::with_clock(sampler.clone(), Arc::new(VirtualClock::new(3)));
        assert!(h.enabled());

        let mut kept_keys = Vec::new();
        for key in 0..32u64 {
            let root = h.root_sampled("request", 0, key);
            if root.active() {
                kept_keys.push(key);
                let child = root.child("handle");
                child.event("key", key);
            }
        }
        assert_eq!(kept_keys, (0..32).filter(|&k| decide(11, 4, k)).collect::<Vec<_>>());
        assert_eq!(sampler.sampled_in() as usize, kept_keys.len());
        assert_eq!(sampler.sampled_out() as usize, 32 - kept_keys.len());
        // Every kept root carries its child; dropped ones left nothing.
        assert_eq!(sink.roots().len(), kept_keys.len());
        assert_eq!(sink.find("handle").len(), kept_keys.len());
    }

    #[test]
    fn remote_decisions_bypass_the_local_policy() {
        let sink = Arc::new(InMemoryTracker::new());
        // Seed/rate chosen so key 5 would be sampled out locally.
        let seed = (0..u64::MAX).find(|&s| !decide(s, 4, 5)).unwrap();
        let sampler = Arc::new(SamplingTracker::with_seed(sink.clone(), 4, seed));
        let h = TraceHandle::with_clock(sampler, Arc::new(VirtualClock::new(3)));
        let root = h.root_sampled("request", 123, 5);
        assert!(root.active(), "an upstream sampled-in decision wins");
        drop(root);
        assert_eq!(sink.roots()[0].remote_parent, 123);
    }
}
