//! mrtuner CLI — leader entrypoint.
//!
//! ```text
//! mrtuner profile --app wordcount --grid table1|grid50|small --db db.json
//! mrtuner match   --app exim      --grid table1 --db db.json
//! mrtuner tune    --app exim      --grid small  --db db.json
//! mrtuner table1  [--seed N]                  # reproduce the paper's Table 1
//! mrtuner serve   --db db.json --port 7070    # match-as-a-service
//! mrtuner serve   --db db.json --port 7071 \
//!         --shard-of "M=11,R=6,FS=20M,I=30M;M=21,R=30,FS=10M,I=80M"
//!                                             # serve only those config sets
//! mrtuner route   --shards "127.0.0.1:7071;127.0.0.1:7072" --port 7070
//!                                             # route over shard servers
//! mrtuner route   --shards "127.0.0.1:7071,127.0.0.1:8071;127.0.0.1:7072" \
//!         --port 7070                         # slot 0 has a standby replica
//! mrtuner calibrate --app terasort            # re-measure cost model
//! ```
//!
//! `--shard-of` takes `;`-separated configuration-set labels (labels
//! contain commas); `route --shards` takes `;`-separated shard slots
//! whose order defines the composed database's global index space — each
//! slot is one address or a comma-separated replica set the router fails
//! over between (all replicas of a slot must serve the same shard data).

use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::router::{RouterServer, ShardRouter};
use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::coordinator::{matcher::Matcher, ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::database::store::ReferenceDb;
use mrtuner::util::cli::Args;
use mrtuner::workloads::{workload_for, AppId};
use std::path::PathBuf;
use std::sync::Arc;

/// Build the serving tracer (see `OBSERVABILITY.md`):
///
/// * a bounded [`FlightRecorder`](mrtuner::trace::FlightRecorder) ring is
///   always on — the black box behind the `trace_dump` command and the
///   read-loop dump-on-error path;
/// * `--trace FILE` fans spans out to a Chrome `trace_event` sink too
///   (written when the server stops), via
///   [`MultiTracker`](mrtuner::trace::MultiTracker);
/// * the whole stack sits behind a deterministic seeded 1-in-N head
///   sampler (`--trace-sample N`, default 64; `1` records everything);
/// * `--no-trace` turns all of it off (the zero-overhead disabled handle).
fn build_tracer(
    args: &Args,
) -> (
    mrtuner::trace::TraceHandle,
    Option<Arc<mrtuner::trace::FlightRecorder>>,
    Option<Arc<mrtuner::trace::ChromeTracker>>,
) {
    use mrtuner::trace::{
        ChromeTracker, FlightRecorder, MultiTracker, SamplingTracker, TraceHandle, Tracker,
    };
    if args.has_flag("no-trace") {
        return (TraceHandle::disabled(), None, None);
    }
    let capacity = args.opt::<usize>("flight-spans", mrtuner::trace::recorder::DEFAULT_CAPACITY);
    let recorder = Arc::new(FlightRecorder::new(capacity));
    let mut chrome: Option<Arc<ChromeTracker>> = None;
    let sink: Arc<dyn Tracker> = if args.opt_str("trace", "").is_empty() {
        Arc::clone(&recorder) as Arc<dyn Tracker>
    } else {
        let c = Arc::new(ChromeTracker::new());
        chrome = Some(Arc::clone(&c));
        Arc::new(MultiTracker::new(vec![
            Arc::clone(&recorder) as Arc<dyn Tracker>,
            c,
        ]))
    };
    let n = args.opt::<u64>("trace-sample", 64);
    let seed = args.opt::<u64>("seed", 1);
    let sampled = Arc::new(SamplingTracker::with_seed(sink, n, seed));
    (TraceHandle::new(sampled), Some(recorder), chrome)
}

/// Write the `--trace FILE` Chrome sink on clean shutdown.
fn write_trace_file(args: &Args, chrome: Option<Arc<mrtuner::trace::ChromeTracker>>) {
    if let Some(c) = chrome {
        let path = args.opt_str("trace", "");
        match c.write_to(&PathBuf::from(&path)) {
            Ok(()) => println!("trace written to {path}"),
            Err(e) => eprintln!("writing trace {path}: {e:#}"),
        }
    }
}

fn grid_from(args: &Args) -> ConfigGrid {
    let seed = args.opt::<u64>("seed", 1);
    match args.opt_str("grid", "small").as_str() {
        "table1" => ConfigGrid::paper_table1(),
        "grid50" => ConfigGrid::paper_grid50(seed),
        "small" => ConfigGrid::small(seed),
        other => {
            let n: usize = other.parse().unwrap_or_else(|_| {
                eprintln!("unknown grid {other:?}; use table1|grid50|small|<N>");
                std::process::exit(2);
            });
            ConfigGrid::random(n, seed)
        }
    }
}

fn app_from(args: &Args) -> AppId {
    let name = args.opt_str("app", "");
    AppId::from_name(&name).unwrap_or_else(|| {
        eprintln!(
            "unknown --app {name:?}; known: {}",
            AppId::all().iter().map(|a| a.name()).collect::<Vec<_>>().join(", ")
        );
        std::process::exit(2);
    })
}

fn system(args: &Args) -> TuningSystem {
    let mut config = SystemConfig {
        seed: args.opt::<u64>("seed", SystemConfig::default().seed),
        workers: args.opt::<usize>("workers", SystemConfig::default().workers),
        use_runtime: !args.has_flag("no-runtime"),
        ..SystemConfig::default()
    };
    if args.has_flag("no-noise") {
        config.noise = mrtuner::signal::noise::NoiseModel::none();
    }
    let mut sys = TuningSystem::new(config);
    let db_path = args.opt_str("db", "");
    if !db_path.is_empty() {
        if let Ok(db) = ReferenceDb::load(&PathBuf::from(&db_path)) {
            log::info!("loaded {} entries from {db_path}", db.len());
            sys.db = db;
        }
    }
    sys
}

fn save_db(sys: &TuningSystem, args: &Args) {
    let db_path = args.opt_str("db", "");
    if !db_path.is_empty() {
        sys.db.save(&PathBuf::from(&db_path)).expect("saving database");
        log::info!("saved {} entries to {db_path}", sys.db.len());
    }
}

fn main() -> anyhow::Result<()> {
    mrtuner::util::logging::init();
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("profile") => {
            let app = app_from(&args);
            let grid = grid_from(&args);
            let mut sys = system(&args);
            sys.profile_app(app, &grid);
            println!("profiled {} under {} configuration sets", app.name(), grid.len());
            save_db(&sys, &args);
        }
        Some("match") => {
            let app = app_from(&args);
            let grid = grid_from(&args);
            let sys = system(&args);
            let outcome = sys.match_app(app, &grid);
            for v in &outcome.votes {
                println!(
                    "{:28} best={:12} sim={:6.2}%",
                    v.config.label(),
                    v.best_app.map(|a| a.name()).unwrap_or("-"),
                    v.best_similarity
                );
            }
            println!("tally: {:?}", outcome.tally);
            match outcome.winner {
                Some(w) => println!("most similar application: {}", w.name()),
                None => println!("no application cleared the 90% threshold"),
            }
        }
        Some("tune") => {
            let app = app_from(&args);
            let grid = grid_from(&args);
            let mut sys = system(&args);
            let report = sys.tune_app(app, &grid);
            println!("matched: {:?}", report.matched_app.map(|a| a.name()));
            if let Some(cfg) = report.transferred {
                println!("transferred config: {}", cfg.label());
            }
            println!(
                "default {:.1}s -> tuned {:.1}s (speedup {:.2}x)",
                report.default_secs,
                report.tuned_secs,
                report.speedup()
            );
            save_db(&sys, &args);
        }
        Some("table1") => {
            let mut sys = system(&args);
            let grid = ConfigGrid::paper_table1();
            sys.profile_app(AppId::WordCount, &grid);
            sys.profile_app(AppId::TeraSort, &grid);
            let m = Matcher::new(&sys.config, sys.runtime());
            let table = m.similarity_table(AppId::EximParse, &grid, &sys.db);
            mrtuner::coordinator::print_table1(&table, &grid);
        }
        Some("serve") => {
            let mut sys = system(&args);
            let port = args.opt::<u16>("port", 7070);
            let runtime = sys.runtime();
            let mut db = std::mem::take(&mut sys.db);
            // Shard mode: keep only the entries of the owned config sets
            // (`;`-separated labels — the labels themselves contain commas).
            let shard_of = args.opt_str("shard-of", "");
            if !shard_of.is_empty() {
                let labels: std::collections::BTreeSet<String> = shard_of
                    .split(';')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                let total = db.len();
                let mut shard = ReferenceDb::new();
                for e in db.entries() {
                    if labels.contains(&e.config_key()) {
                        shard.insert(e.clone());
                    }
                }
                println!(
                    "shard owns {} of {total} entries across {} config sets",
                    shard.len(),
                    labels.len()
                );
                db = shard;
            }
            // Wrap the store in the similarity index once at startup; every
            // connection then shares the immutable envelope cache.
            let (tracer, recorder, chrome) = build_tracer(&args);
            // `--flight-rotate-secs N`: a detached 1 Hz ticker drives a
            // logrotate-style flight-dump rotation (time- or
            // pressure-triggered; see `FlightRotator`) off the tracer's
            // clock, so the black box lands on disk periodically instead
            // of only on read-loop errors.
            let rotate_secs = args.opt::<u64>("flight-rotate-secs", 0);
            if rotate_secs > 0 {
                if let Some(rec) = recorder.clone() {
                    let clock = tracer.clone();
                    let mut rotator = mrtuner::trace::FlightRotator::new(
                        rec,
                        format!("mrtuner-flight-{port}.json"),
                        rotate_secs.saturating_mul(1_000_000_000),
                        8,
                    );
                    std::thread::spawn(move || loop {
                        std::thread::sleep(std::time::Duration::from_secs(1));
                        if let Some(path) = rotator.tick(clock.now_ns()) {
                            println!("flight recorder rotated to {}", path.display());
                        }
                    });
                }
            }
            let state = ServerState {
                db: mrtuner::index::IndexedDb::from_db(db),
                runtime,
                metrics: Metrics::new(),
                // Sessions share the request tracer, so session-lifetime
                // bars and request trees land in one timeline.
                sessions: mrtuner::streaming::SessionManager::with_tracer(tracer.clone()),
                tracer,
                recorder,
                predictors: Default::default(),
            };
            let server = MatchServer::bind(&format!("127.0.0.1:{port}"), state)?;
            println!("serving on {}", server.local_addr()?);
            server.serve(args.opt::<usize>("workers", 4))?;
            write_trace_file(&args, chrome);
        }
        Some("route") => {
            let shards_arg = args.opt_str("shards", "");
            // `;` separates shard slots (same separator as `--shard-of`),
            // `,` separates a slot's replicas in failover order.
            let groups: Vec<Vec<String>> = shards_arg
                .split(';')
                .map(|slot| {
                    slot.split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect::<Vec<String>>()
                })
                .filter(|slot| !slot.is_empty())
                .collect();
            if groups.is_empty() {
                eprintln!(
                    "route: --shards \"host:port[,host:port...][;host:port...]\" is required \
                     (`;` between shard slots, `,` between a slot's replicas)"
                );
                std::process::exit(2);
            }
            let metrics = Arc::new(Metrics::new());
            let (tracer, _recorder, chrome) = build_tracer(&args);
            let router = match ShardRouter::connect_groups(&groups, metrics) {
                Ok(r) => r.with_tracer(tracer),
                Err(e) => {
                    eprintln!("route: {e}");
                    std::process::exit(1);
                }
            };
            println!(
                "routing over {} shards ({} replicas) / {} entries",
                router.shards().len(),
                groups.iter().map(Vec::len).sum::<usize>(),
                router.total_entries()
            );
            let port = args.opt::<u16>("port", 7070);
            let server = RouterServer::bind(&format!("127.0.0.1:{port}"), router)?;
            println!("routing on {}", server.local_addr()?);
            server.serve(args.opt::<usize>("workers", 4))?;
            write_trace_file(&args, chrome);
        }
        Some("calibrate") => {
            let app = app_from(&args);
            let w = workload_for(app);
            let measured = w.calibrate(
                args.opt::<usize>("sample-kb", 1024) * 1024,
                args.opt::<f64>("speed-factor", 4.0),
                args.opt::<u64>("seed", 1),
            );
            println!("calibrated cost model for {}: {measured:#?}", app.name());
            println!("shipped default:             {:#?}", w.default_costs());
        }
        _ => {
            println!(
                "usage: mrtuner <profile|match|tune|table1|serve|route|calibrate> \
                 [--app NAME] [--grid table1|grid50|small|N] [--db FILE] \
                 [--seed N] [--workers N] [--port N] [--no-runtime] [--no-noise] \
                 [--shard-of \"LABEL;LABEL...\"] [--shards \"host:port[,replica...];host:port\"] \
                 [--no-trace] [--trace FILE] [--trace-sample N] [--flight-spans N] \
                 [--flight-rotate-secs N]"
            );
        }
    }
    Ok(())
}
