//! TeraSort — the paper's second reference application (§5).
//!
//! Standard map/reduce sort with the custom range partitioner the paper
//! describes: a sorted list of `R-1` sampled keys defines per-reducer key
//! ranges, so reducer `i`'s output is entirely ≤ reducer `i+1`'s. Records
//! follow the teragen layout: 10-byte key + 90-byte payload, 100 bytes
//! fixed width. The map function is the identity on `(key, payload)` —
//! which is exactly why TeraSort's CPU profile differs so much from the
//! text-parsing applications: almost all its work is shuffle IO and
//! reduce-side merge sorting.

use super::traits::{record_splits, CostModel, Emit, Workload};
use super::AppId;
use crate::util::rng::Rng;

pub const RECORD: usize = 100;
pub const KEY_LEN: usize = 10;

pub struct TeraSort;

impl Default for TeraSort {
    fn default() -> Self {
        TeraSort
    }
}

impl Workload for TeraSort {
    fn id(&self) -> AppId {
        AppId::TeraSort
    }

    fn generate(&self, bytes: usize, rng: &mut Rng) -> Vec<u8> {
        let records = bytes.div_ceil(RECORD).max(1);
        let mut out = Vec::with_capacity(records * RECORD);
        for row in 0..records {
            // 10-byte printable random key (teragen uses 95 printable chars).
            for _ in 0..KEY_LEN {
                out.push(b' ' + rng.below(95) as u8);
            }
            // 10-byte row id + 80 bytes filler.
            out.extend_from_slice(format!("{row:010}").as_bytes());
            let filler = b'A' + (row % 26) as u8;
            out.extend(std::iter::repeat(filler).take(RECORD - KEY_LEN - 10));
        }
        out
    }

    fn split<'a>(&self, input: &'a [u8], n: usize) -> Vec<&'a [u8]> {
        record_splits(input, RECORD, n)
    }

    fn map(&self, split: &[u8], emit: &mut Emit) {
        for rec in split.chunks_exact(RECORD) {
            emit(&rec[..KEY_LEN], &rec[KEY_LEN..]);
        }
    }

    fn partition(&self, key: &[u8], r: usize) -> usize {
        // Range partitioner over the printable-byte key space [0x20, 0x7f):
        // equivalent to TotalOrderPartitioner with uniformly sampled keys,
        // since generated keys are uniform over the space.
        let b0 = key.first().copied().unwrap_or(b' ');
        let frac = (b0.saturating_sub(b' ')) as f64 / 95.0;
        ((frac * r as f64) as usize).min(r - 1)
    }

    fn reduce(&self, key: &[u8], values: &[Vec<u8>], out: &mut Vec<u8>) {
        for v in values {
            out.extend_from_slice(key);
            out.extend_from_slice(v);
        }
    }

    fn default_costs(&self) -> CostModel {
        // Identity map, no combiner (selectivity 1.0), heavy reduce-side
        // merge sort and full-volume shuffle — the IO-bound inverse of the
        // text workloads.
        CostModel {
            map_cpu_s_per_mb: 0.12,
            map_selectivity: 1.0,
            sort_cpu_s_per_mb: 0.35,
            reduce_cpu_s_per_mb: 0.30,
            reduce_selectivity: 1.0,
            startup_cpu_s: 1.2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mapreduce::run_job;

    #[test]
    fn generates_fixed_width_records() {
        let ts = TeraSort;
        let mut rng = Rng::new(1);
        let data = ts.generate(1000, &mut rng);
        assert_eq!(data.len() % RECORD, 0);
        assert!(data.len() >= 1000);
    }

    #[test]
    fn output_is_globally_sorted() {
        let ts = TeraSort;
        let mut rng = Rng::new(2);
        let data = ts.generate(50 * RECORD, &mut rng);
        let out = run_job(&ts, &data, 4, 3);
        // Within each reducer the keys are sorted; across reducers the last
        // key of reducer i ≤ first key of reducer i+1 (range partitioning).
        let mut last_overall: Option<Vec<u8>> = None;
        for ro in &out.reducer_outputs {
            for rec in ro.chunks_exact(RECORD) {
                let key = rec[..KEY_LEN].to_vec();
                if let Some(prev) = &last_overall {
                    assert!(*prev <= key, "sort order violated");
                }
                last_overall = Some(key);
            }
        }
    }

    #[test]
    fn output_is_permutation_of_input() {
        let ts = TeraSort;
        let mut rng = Rng::new(3);
        let data = ts.generate(30 * RECORD, &mut rng);
        let out = run_job(&ts, &data, 3, 4);
        let mut input_records: Vec<&[u8]> = data.chunks_exact(RECORD).collect();
        let all_out: Vec<u8> = out.reducer_outputs.concat();
        let mut output_records: Vec<&[u8]> = all_out.chunks_exact(RECORD).collect();
        input_records.sort();
        output_records.sort();
        assert_eq!(input_records, output_records);
    }

    #[test]
    fn no_combiner_full_shuffle() {
        let ts = TeraSort;
        let mut rng = Rng::new(4);
        let data = ts.generate(20 * RECORD, &mut rng);
        let out = run_job(&ts, &data, 2, 2);
        assert_eq!(out.counters.map_output_bytes, out.counters.combine_output_bytes);
        assert_eq!(out.counters.map_output_bytes, data.len() as u64);
    }

    #[test]
    fn partitioner_is_monotone_in_key() {
        let ts = TeraSort;
        for r in [1usize, 2, 5, 33] {
            let mut last = 0usize;
            for b in b' '..b'~' {
                let p = ts.partition(&[b; KEY_LEN], r);
                assert!(p < r);
                assert!(p >= last, "partition not monotone");
                last = p;
            }
        }
    }

    #[test]
    fn cost_model_plausible() {
        assert!(TeraSort.default_costs().is_plausible());
    }
}
