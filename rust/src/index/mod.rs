//! Similarity index: sublinear k-nearest-neighbour search over the
//! reference database under the production banded-DTW distance.
//!
//! The paper's matching phase compares the query against *every* stored
//! pattern with full DTW — fine for 3 apps × 6 configs, hopeless for a
//! reference service holding thousands of profiled patterns. This module
//! implements the standard lower-bound pruning cascade so that most
//! candidates are rejected in O(1)–O(n) time and the exact dynamic program
//! only runs on the few that could still win:
//!
//! 1. [`lb::lb_kim`] — constant-time endpoint bound (any warping path must
//!    pay the two corner cells);
//! 2. [`lb::lb_paa`] — PAA-summarized Sakoe–Chiba envelope bound using only
//!    the per-entry blockwise extrema cached in [`envelope::Envelope`]
//!    (O(n/B), used for long series);
//! 3. [`lb::lb_keogh`] — per-row envelope bound over the same band geometry
//!    the banded DTW uses ([`crate::dtw::band_edges`], O(n));
//! 4. [`crate::dtw::banded::dtw_banded_distance_cutoff`] — the exact
//!    early-abandoning fallback, bit-identical to `dtw_banded` when it
//!    completes.
//!
//! Every bound under-estimates the banded distance, so [`knn::knn`] returns
//! **exactly** the same neighbours (indices *and* distances) as a brute
//! force scan — the speedup is free of approximation. [`db::IndexedDb`]
//! wraps [`crate::database::store::ReferenceDb`], keeps the envelope cache
//! in sync on insert, and persists it alongside the JSON store.
//!
//! The execution layer is a zero-allocation query engine: every DP runs
//! on a reusable [`crate::dtw::DtwScratch`] arena, [`knn::knn_parallel`]
//! fans candidates over the cores with a shared atomic best-k cutoff
//! (result identical to the serial scan), and [`knn::knn_batch`] answers
//! many queries in one entry-major pass that shares envelope work across
//! same-length queries (per-query results and counters identical to
//! standalone searches).
//!
//! Integration points: `coordinator::matcher::Matcher::match_app_indexed`
//! and `match_apps_indexed` (index-backed matching phases), the `knn` and
//! `knn_batch` commands of `coordinator::server`, and the pruning/batch
//! counters in `coordinator::metrics::Metrics`. `benches/index_perf.rs`
//! measures the brute-force vs indexed crossover;
//! `benches/dtw_kernel_perf.rs` measures the engine against the
//! seed-grade path.

pub mod db;
pub mod envelope;
pub mod knn;
pub mod lb;

pub use db::IndexedDb;
pub use envelope::Envelope;
pub use knn::{brute_force_knn, knn, knn_batch, knn_parallel, Neighbor};

/// Block size (samples per envelope block) used for the cached envelopes
/// and the PAA-summarized bound. 16 keeps the cache ~12% of the series
/// size while still amortizing the per-row range queries.
pub const DEFAULT_BLOCK: usize = 16;

/// Where each candidate of one search was culled (or not). The counters
/// partition the candidate set:
/// `candidates = pruned_* + abandoned + dtw_evals`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SearchStats {
    /// Candidates examined.
    pub candidates: u64,
    /// Rejected by the O(1) endpoint bound.
    pub pruned_lb_kim: u64,
    /// Rejected by the PAA-summarized envelope bound.
    pub pruned_lb_paa: u64,
    /// Rejected by the per-row envelope bound.
    pub pruned_lb_keogh: u64,
    /// Entered the dynamic program but abandoned before completion.
    pub abandoned: u64,
    /// Full banded-DTW evaluations that ran to completion.
    pub dtw_evals: u64,
}

impl SearchStats {
    /// Accumulate another search's counters into this one.
    pub fn merge(&mut self, other: &SearchStats) {
        self.candidates += other.candidates;
        self.pruned_lb_kim += other.pruned_lb_kim;
        self.pruned_lb_paa += other.pruned_lb_paa;
        self.pruned_lb_keogh += other.pruned_lb_keogh;
        self.abandoned += other.abandoned;
        self.dtw_evals += other.dtw_evals;
    }

    /// Candidates rejected by a lower bound alone (no DP cell computed).
    pub fn pruned(&self) -> u64 {
        self.pruned_lb_kim + self.pruned_lb_paa + self.pruned_lb_keogh
    }

    /// Candidates on which the dynamic program was started at all.
    pub fn dtw_started(&self) -> u64 {
        self.abandoned + self.dtw_evals
    }

    /// Fraction of candidates that reached the dynamic program — the
    /// headline "full/banded DTW evaluations NOT avoided" ratio.
    pub fn dtw_fraction(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            self.dtw_started() as f64 / self.candidates as f64
        }
    }
}

impl std::fmt::Display for SearchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "candidates={} pruned[kim={} paa={} keogh={}] abandoned={} dtw_evals={} ({:.1}% reached DTW)",
            self.candidates,
            self.pruned_lb_kim,
            self.pruned_lb_paa,
            self.pruned_lb_keogh,
            self.abandoned,
            self.dtw_evals,
            self.dtw_fraction() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_partition_and_merge() {
        let mut a = SearchStats {
            candidates: 10,
            pruned_lb_kim: 3,
            pruned_lb_paa: 2,
            pruned_lb_keogh: 1,
            abandoned: 1,
            dtw_evals: 3,
        };
        assert_eq!(a.pruned() + a.dtw_started(), a.candidates);
        assert!((a.dtw_fraction() - 0.4).abs() < 1e-12);
        let b = a;
        a.merge(&b);
        assert_eq!(a.candidates, 20);
        assert_eq!(a.dtw_evals, 6);
        assert_eq!(SearchStats::default().dtw_fraction(), 0.0);
        let line = a.to_string();
        assert!(line.contains("candidates=20"), "{line}");
    }
}
