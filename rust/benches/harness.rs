//! Minimal measurement harness for the `harness = false` benches
//! (criterion is not vendorable offline): warmup + N timed samples,
//! reporting mean / p50 / p99.

use std::time::Instant;

/// Time `f` over `samples` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let stats = BenchStats::from_times(name, &times);
    println!("{stats}");
    stats
}

/// Summary of one bench run.
pub struct BenchStats {
    pub name: String,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub samples: usize,
}

impl BenchStats {
    pub fn from_times(name: &str, times: &[f64]) -> BenchStats {
        let mut sorted = times.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| sorted[((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)];
        BenchStats {
            name: name.to_string(),
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            p50_s: pct(0.50),
            p99_s: pct(0.99),
            samples: times.len(),
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:44} mean {:>9.3} ms  p50 {:>9.3} ms  p99 {:>9.3} ms  (n={})",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p99_s * 1e3,
            self.samples
        )
    }
}
