//! Distributed Grep — extra reference application (Dean & Ghemawat's
//! original MapReduce example). Scans syslog-style text for a pattern and
//! counts matches per matched string. Very low map selectivity: almost
//! nothing is shuffled, so the CPU series is one map-phase plateau with a
//! negligible reduce tail — a third distinct shape for the database.

use super::traits::{CostModel, Emit, Workload};
use super::AppId;
use crate::util::rng::Rng;
use regex::bytes::Regex;

pub struct Grep {
    pattern: Regex,
}

impl Default for Grep {
    fn default() -> Self {
        Grep {
            pattern: Regex::new(r"(ERROR|FATAL) [a-z]+").expect("static regex compiles"),
        }
    }
}

const FACILITIES: &[&str] = &["kernel", "sshd", "cron", "nfsd", "dhclient", "postfix"];
const LEVELS: &[(&str, f64)] = &[("INFO", 0.75), ("WARN", 0.15), ("ERROR", 0.08), ("FATAL", 0.02)];
const MESSAGES: &[&str] = &[
    "connection reset by peer",
    "timeout waiting for response",
    "disk quota exceeded",
    "segfault at address",
    "permission denied for user",
    "checksum mismatch detected",
];

impl Workload for Grep {
    fn id(&self) -> AppId {
        AppId::Grep
    }

    fn generate(&self, bytes: usize, rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::with_capacity(bytes + 128);
        let mut t = 0u64;
        while out.len() < bytes {
            t += rng.range_u64(1, 5);
            let u = rng.f64();
            let mut acc = 0.0;
            let mut level = "INFO";
            for (l, p) in LEVELS {
                acc += p;
                if u < acc {
                    level = l;
                    break;
                }
            }
            out.extend_from_slice(
                format!(
                    "May 26 {:02}:{:02}:{:02} host {}[{}]: {} {}\n",
                    (t / 3600) % 24,
                    (t / 60) % 60,
                    t % 60,
                    rng.choose(FACILITIES),
                    rng.range_u64(100, 32768),
                    level,
                    rng.choose(MESSAGES),
                )
                .as_bytes(),
            );
        }
        out
    }

    fn map(&self, split: &[u8], emit: &mut Emit) {
        for line in split.split(|&b| b == b'\n') {
            for m in self.pattern.find_iter(line) {
                emit(m.as_bytes(), b"1");
            }
        }
    }

    fn combine(&self, _key: &[u8], values: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        let sum: u64 = values.iter().map(|v| parse_count(v)).sum();
        vec![sum.to_string().into_bytes()]
    }

    fn reduce(&self, key: &[u8], values: &[Vec<u8>], out: &mut Vec<u8>) {
        let sum: u64 = values.iter().map(|v| parse_count(v)).sum();
        out.extend_from_slice(sum.to_string().as_bytes());
        out.push(b'\t');
        out.extend_from_slice(key);
        out.push(b'\n');
    }

    fn default_costs(&self) -> CostModel {
        CostModel {
            map_cpu_s_per_mb: 3.0,
            map_selectivity: 0.01,
            sort_cpu_s_per_mb: 0.3,
            reduce_cpu_s_per_mb: 0.4,
            reduce_selectivity: 1.2,
            startup_cpu_s: 1.2,
        }
    }
}

fn parse_count(v: &[u8]) -> u64 {
    std::str::from_utf8(v)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mapreduce::run_job;

    #[test]
    fn finds_only_matching_lines() {
        let g = Grep::default();
        let input = b"x INFO all good\ny ERROR disk quota exceeded\nz FATAL segfault now\n";
        let mut keys = Vec::new();
        g.map(input, &mut |k, _| keys.push(String::from_utf8_lossy(k).into_owned()));
        assert_eq!(keys, vec!["ERROR disk", "FATAL segfault"]);
    }

    #[test]
    fn selectivity_is_tiny() {
        let g = Grep::default();
        let mut rng = Rng::new(1);
        let data = g.generate(64 * 1024, &mut rng);
        let out = run_job(&g, &data, 3, 2);
        let ratio = out.counters.combine_output_bytes as f64 / data.len() as f64;
        assert!(ratio < 0.05, "ratio={ratio}");
        assert!(out.counters.reduce_groups > 0, "some matches exist");
    }

    #[test]
    fn counts_are_consistent() {
        let g = Grep::default();
        let mut rng = Rng::new(2);
        let data = g.generate(32 * 1024, &mut rng);
        let direct = g.pattern.find_iter(&data).count() as u64;
        let out = run_job(&g, &data, 4, 3);
        let mut total = 0u64;
        for ro in &out.reducer_outputs {
            for line in std::str::from_utf8(ro).unwrap().lines() {
                total += line.split('\t').next().unwrap().parse::<u64>().unwrap();
            }
        }
        assert_eq!(total, direct);
    }

    #[test]
    fn cost_model_plausible() {
        assert!(Grep::default().default_costs().is_plausible());
    }
}
