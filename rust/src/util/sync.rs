//! Loom-switchable atomic primitives for the concurrent scan paths.
//!
//! This module is deliberately self-contained (std only, no `crate::`
//! references): the workspace-excluded `tools/loom-models` crate includes
//! it textually via `#[path]` and compiles it with `--cfg loom`, swapping
//! the std atomics for loom's model-checked ones. That makes the exact
//! code running in production the code loom exhaustively interleaves —
//! not a hand-copied replica that can drift.

#[cfg(loom)]
use loom::sync::atomic::{AtomicU64, Ordering};
#[cfg(not(loom))]
use std::sync::atomic::{AtomicU64, Ordering};

/// A shared f64 that only ever decreases: CAS-min over the bit pattern.
///
/// This is the cross-thread best-so-far cutoff of
/// `crate::index::knn::knn_parallel`. Distances are finite and
/// non-negative, so their IEEE-754 bit patterns order like the values and
/// a `u64` compare-exchange implements min exactly. A NaN argument is
/// never published (`v < cur` is false), and the value read by [`load`]
/// is always either the initial value or something some thread passed to
/// [`fetch_min`] — never a torn mix.
///
/// All accesses are `Relaxed` on purpose: the cutoff is *advisory*. A
/// stale read can only make a bound check less aggressive (a candidate
/// survives that a fresher cutoff would have pruned); it can never prune
/// a true neighbour, because every published value is a genuine k-th-best
/// distance some thread proved. Correctness never rides on this cell's
/// ordering — only wasted work does.
///
/// [`load`]: AtomicF64Min::load
/// [`fetch_min`]: AtomicF64Min::fetch_min
#[derive(Debug)]
pub struct AtomicF64Min {
    bits: AtomicU64,
}

impl AtomicF64Min {
    /// A new cell holding `v` (normally `f64::INFINITY`).
    pub fn new(v: f64) -> AtomicF64Min {
        AtomicF64Min {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    /// Current value. May be stale by the time the caller uses it — see
    /// the type docs for why that is fine.
    pub fn load(&self) -> f64 {
        // relaxed: advisory cutoff — staleness costs work, not answers.
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Lower the cell to `v` if `v` is smaller than the current value.
    /// Lock-free CAS loop; concurrent calls converge to the global min.
    /// No other memory is released through this cell — the value is the
    /// whole payload — hence the relaxed orderings throughout.
    pub fn fetch_min(&self, v: f64) {
        // relaxed: advisory cutoff, the value is the whole payload.
        let mut cur = self.bits.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed, // relaxed: advisory cutoff (success)
                Ordering::Relaxed, // relaxed: advisory cutoff (failure)
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }
}

// Loom atomics panic when used outside `loom::model`, so these std-based
// unit tests must not compile under --cfg loom; the loom-models crate has
// the model-checked equivalents.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn starts_at_initial_value() {
        let m = AtomicF64Min::new(f64::INFINITY);
        assert_eq!(m.load(), f64::INFINITY);
    }

    #[test]
    fn keeps_the_minimum_of_published_values() {
        let m = AtomicF64Min::new(f64::INFINITY);
        m.fetch_min(3.5);
        assert_eq!(m.load(), 3.5);
        m.fetch_min(7.0);
        assert_eq!(m.load(), 3.5, "larger value must not raise the cell");
        m.fetch_min(1.25);
        assert_eq!(m.load(), 1.25);
    }

    #[test]
    fn nan_is_never_published() {
        let m = AtomicF64Min::new(2.0);
        m.fetch_min(f64::NAN);
        assert_eq!(m.load(), 2.0);
    }

    #[test]
    fn zero_and_negative_zero() {
        let m = AtomicF64Min::new(0.0);
        m.fetch_min(-0.0);
        // -0.0 < 0.0 is false, so the bit pattern stays +0.0.
        assert_eq!(m.load().to_bits(), 0.0_f64.to_bits());
    }

    #[test]
    fn concurrent_publishers_converge_to_global_min() {
        let m = Arc::new(AtomicF64Min::new(f64::INFINITY));
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u32 {
                    let v = f64::from(t * 1000 + i) + 1.0;
                    m.fetch_min(v);
                    assert!(m.load() <= v, "cell above a published value");
                }
            }));
        }
        for h in handles {
            h.join().expect("publisher thread");
        }
        assert_eq!(m.load(), 1.0, "global min is thread 0's first publish");
    }
}
