//! Typed responses: every reply body as a plain struct, with the v2 body
//! serialization, the byte-compatible legacy (v1) rendering, and the
//! client-side decoder.
//!
//! The v1 renderings reproduce the pre-envelope server's output **exactly**
//! (same keys, same values — object keys are `BTreeMap`-sorted either way),
//! which is what the golden tests in `rust/tests/server_protocol.rs` pin.
//! The v2 bodies carry strictly more information (k-NN rows gain the
//! database `entry` index the shard router needs for its deterministic
//! merge); v1 rendering simply drops the additions.

use super::request::{config_to_json, parse_config};
use crate::index::SearchStats;
use crate::simulator::job::JobConfig;
use crate::util::json::Json;

/// One k-NN result row. `index` is the entry's position in the answering
/// database — the shard router rebases it by the shard's offset so routed
/// results are comparable across shards.
#[derive(Debug, Clone, PartialEq)]
pub struct NeighborRow {
    pub index: usize,
    pub app: String,
    pub config: String,
    pub distance: f64,
    pub similarity: f64,
}

/// One `knn` answer: rows plus the cascade's pruning counters.
/// `degraded` lists the shard slots whose answers are missing from a
/// router's `allow_partial` merge — empty (and absent on the wire) for
/// every full answer, so non-degraded replies stay byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct KnnBody {
    pub neighbors: Vec<NeighborRow>,
    pub stats: SearchStats,
    pub degraded: Vec<usize>,
}

/// One `knn_batch` answer: per-query results (input order) plus merged
/// counters. `degraded` as in [`KnnBody`] — one annotation for the whole
/// batch, since a lost shard affects every query equally.
#[derive(Debug, Clone, PartialEq)]
pub struct KnnBatchBody {
    pub results: Vec<KnnBody>,
    pub stats: SearchStats,
    pub degraded: Vec<usize>,
}

/// One per-app similarity row of a `match` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct MatchRow {
    pub app: String,
    pub similarity: f64,
}

/// A `match` answer: all per-app similarities, the winner if it cleared
/// the threshold, and the best similarity either way.
#[derive(Debug, Clone, PartialEq)]
pub struct MatchBody {
    pub results: Vec<MatchRow>,
    pub matched: Option<String>,
    pub best_similarity: f64,
}

/// A `stats` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsBody {
    pub report: String,
    pub db_entries: usize,
    pub live_sessions: usize,
}

/// A `shard_info` answer: what this server owns.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardInfoBody {
    pub entries: usize,
    pub apps: Vec<String>,
    pub configs: Vec<String>,
    pub sessions: Vec<u64>,
}

/// An early decision, as reported over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionBody {
    pub app: String,
    pub config: String,
    pub entry: usize,
    pub distance: f64,
    pub similarity: f64,
    pub at_sample: usize,
    pub fraction: f64,
}

/// One anytime top-k row.
#[derive(Debug, Clone, PartialEq)]
pub struct TopRow {
    pub entry: usize,
    pub app: String,
    pub config: String,
    pub distance: Option<f64>,
    pub lower_bound: f64,
}

/// A `stream_open` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOpenBody {
    pub session: u64,
    pub candidates: usize,
}

/// A `stream_feed` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamFeedBody {
    pub observed: usize,
    pub live_candidates: usize,
    pub decision: Option<DecisionBody>,
}

/// A `stream_poll` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamPollBody {
    pub observed: usize,
    pub live_candidates: usize,
    pub culled: u64,
    pub top: Vec<TopRow>,
    pub decision: Option<DecisionBody>,
}

/// One row of a `stream_poll_all` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionPollBody {
    pub session: u64,
    pub poll: StreamPollBody,
}

/// The exact final answer of a closed session.
#[derive(Debug, Clone, PartialEq)]
pub struct FinalBody {
    pub app: String,
    pub config: String,
    pub entry: usize,
    pub distance: f64,
    pub similarity: f64,
    pub matched: bool,
}

/// A `stream_close` answer.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamCloseBody {
    pub observed: usize,
    pub final_match: Option<FinalBody>,
    pub decision: Option<DecisionBody>,
}

/// A `stream_tune` answer: the session's current best match and the
/// matched application's cached optimal configuration, when one exists.
/// `decided` distinguishes a frozen [`DecisionBody`]-backed answer from
/// an anytime leader that may still change.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamTunedBody {
    pub session: u64,
    pub decided: bool,
    /// The matched application, frozen or anytime leader.
    pub app: Option<String>,
    /// DTW similarity percent behind the match, when known.
    pub similarity: Option<f64>,
    /// The matched application's cached optimal configuration.
    pub optimal: Option<JobConfig>,
    /// Completion time measured for `optimal` when it was cached.
    pub optimal_secs: Option<f64>,
    /// Fraction of the expected final length observed so far.
    pub fraction: Option<f64>,
}

/// One typed response, whatever envelope it will be rendered into.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Stats(StatsBody),
    Apps(Vec<String>),
    ShardInfo(ShardInfoBody),
    Match(MatchBody),
    Knn(KnnBody),
    KnnBatch(KnnBatchBody),
    StreamOpened(StreamOpenBody),
    StreamFed(StreamFeedBody),
    StreamTop(StreamPollBody),
    Sessions(Vec<SessionPollBody>),
    StreamClosed(StreamCloseBody),
    StreamTuned(StreamTunedBody),
    /// Structured metrics snapshot (the object built by
    /// `coordinator::metrics::Metrics::snapshot`). Carried as opaque JSON so
    /// the wire layer never chases the metrics schema; field names are
    /// pinned by the metrics module's own tests.
    Metrics(Json),
    /// Flight-recorder snapshot: `{"spans": n, "dropped": n, "trace":
    /// <Chrome trace document>}`. Opaque JSON for the same reason as
    /// `Metrics` — the trace document's shape belongs to the recorder
    /// (`trace::FlightRecorder::dump`), not the wire layer.
    TraceDump(Json),
}

// ---------- field-level (de)serialization helpers ----------

/// Pruning counters as a response object (same keys in v1 and v2).
pub fn stats_to_json(stats: &SearchStats) -> Json {
    Json::obj(vec![
        ("candidates", Json::Num(stats.candidates as f64)),
        ("pruned_lb_kim", Json::Num(stats.pruned_lb_kim as f64)),
        ("pruned_lb_paa", Json::Num(stats.pruned_lb_paa as f64)),
        ("pruned_lb_keogh", Json::Num(stats.pruned_lb_keogh as f64)),
        ("abandoned", Json::Num(stats.abandoned as f64)),
        ("dtw_evals", Json::Num(stats.dtw_evals as f64)),
    ])
}

fn stats_from_json(v: &Json) -> Result<SearchStats, String> {
    let num = |k: &str| -> Result<u64, String> {
        v.get(k)
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("stats missing {k}"))
    };
    Ok(SearchStats {
        candidates: num("candidates")?,
        pruned_lb_kim: num("pruned_lb_kim")?,
        pruned_lb_paa: num("pruned_lb_paa")?,
        pruned_lb_keogh: num("pruned_lb_keogh")?,
        abandoned: num("abandoned")?,
        dtw_evals: num("dtw_evals")?,
    })
}

fn str_field(v: &Json, k: &str) -> Result<String, String> {
    v.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing {k}"))
}

fn f64_field(v: &Json, k: &str) -> Result<f64, String> {
    v.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing {k}"))
}

fn usize_field(v: &Json, k: &str) -> Result<usize, String> {
    v.get(k).and_then(Json::as_usize).ok_or_else(|| format!("missing {k}"))
}

fn neighbor_to_json(r: &NeighborRow, with_entry: bool) -> Json {
    let mut pairs = vec![
        ("app", Json::Str(r.app.clone())),
        ("config", Json::Str(r.config.clone())),
        ("distance", Json::Num(r.distance)),
        ("similarity", Json::Num(r.similarity)),
    ];
    if with_entry {
        pairs.push(("entry", Json::Num(r.index as f64)));
    }
    Json::obj(pairs)
}

fn neighbor_from_json(v: &Json) -> Result<NeighborRow, String> {
    Ok(NeighborRow {
        index: usize_field(v, "entry")?,
        app: str_field(v, "app")?,
        config: str_field(v, "config")?,
        distance: f64_field(v, "distance")?,
        similarity: f64_field(v, "similarity")?,
    })
}

fn degraded_to_json(shards: &[usize]) -> Json {
    Json::arr(shards.iter().map(|&s| Json::Num(s as f64)).collect())
}

fn degraded_from_json(v: Option<&Json>) -> Result<Vec<usize>, String> {
    match v {
        None => Ok(Vec::new()),
        Some(arr) => arr
            .as_arr()
            .ok_or_else(|| "degraded is not an array".to_string())?
            .iter()
            .map(|s| s.as_usize().ok_or_else(|| "bad degraded shard id".to_string()))
            .collect(),
    }
}

fn knn_to_json(b: &KnnBody, with_entry: bool) -> Json {
    let mut pairs = vec![
        (
            "neighbors",
            Json::arr(b.neighbors.iter().map(|r| neighbor_to_json(r, with_entry)).collect()),
        ),
        ("stats", stats_to_json(&b.stats)),
    ];
    // Emitted only for a router's partial merge (v2-only surface, like
    // `entry`): full answers stay byte-identical to pre-degradation ones.
    if with_entry && !b.degraded.is_empty() {
        pairs.push(("degraded", degraded_to_json(&b.degraded)));
    }
    Json::obj(pairs)
}

fn knn_from_json(v: &Json) -> Result<KnnBody, String> {
    let rows = v
        .get("neighbors")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing neighbors".to_string())?
        .iter()
        .map(neighbor_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(KnnBody {
        neighbors: rows,
        stats: stats_from_json(v.get("stats").ok_or_else(|| "missing stats".to_string())?)?,
        degraded: degraded_from_json(v.get("degraded"))?,
    })
}

fn decision_to_json(d: &DecisionBody) -> Json {
    Json::obj(vec![
        ("app", Json::Str(d.app.clone())),
        ("config", Json::Str(d.config.clone())),
        ("entry", Json::Num(d.entry as f64)),
        ("distance", Json::Num(d.distance)),
        ("similarity", Json::Num(d.similarity)),
        ("at_sample", Json::Num(d.at_sample as f64)),
        ("fraction", Json::Num(d.fraction)),
    ])
}

fn decision_from_json(v: &Json) -> Result<DecisionBody, String> {
    Ok(DecisionBody {
        app: str_field(v, "app")?,
        config: str_field(v, "config")?,
        entry: usize_field(v, "entry")?,
        distance: f64_field(v, "distance")?,
        similarity: f64_field(v, "similarity")?,
        at_sample: usize_field(v, "at_sample")?,
        fraction: f64_field(v, "fraction")?,
    })
}

fn opt_decision_json(d: &Option<DecisionBody>) -> Json {
    d.as_ref().map(decision_to_json).unwrap_or(Json::Null)
}

fn opt_decision_from_json(v: Option<&Json>) -> Result<Option<DecisionBody>, String> {
    match v {
        None | Some(Json::Null) => Ok(None),
        Some(d) => decision_from_json(d).map(Some),
    }
}

fn top_to_json(top: &[TopRow]) -> Json {
    Json::arr(
        top.iter()
            .map(|t| {
                Json::obj(vec![
                    ("app", Json::Str(t.app.clone())),
                    ("config", Json::Str(t.config.clone())),
                    ("entry", Json::Num(t.entry as f64)),
                    ("distance", t.distance.map(Json::Num).unwrap_or(Json::Null)),
                    ("lower_bound", Json::Num(t.lower_bound)),
                ])
            })
            .collect(),
    )
}

fn top_from_json(v: &Json) -> Result<Vec<TopRow>, String> {
    v.as_arr()
        .ok_or_else(|| "top is not an array".to_string())?
        .iter()
        .map(|t| {
            Ok(TopRow {
                entry: usize_field(t, "entry")?,
                app: str_field(t, "app")?,
                config: str_field(t, "config")?,
                distance: match t.get("distance") {
                    None | Some(Json::Null) => None,
                    Some(d) => Some(d.as_f64().ok_or_else(|| "bad distance".to_string())?),
                },
                lower_bound: f64_field(t, "lower_bound")?,
            })
        })
        .collect()
}

fn poll_pairs(p: &StreamPollBody) -> Vec<(&'static str, Json)> {
    vec![
        ("observed", Json::Num(p.observed as f64)),
        ("live_candidates", Json::Num(p.live_candidates as f64)),
        ("culled", Json::Num(p.culled as f64)),
        ("top", top_to_json(&p.top)),
        ("decision", opt_decision_json(&p.decision)),
    ]
}

fn poll_from_json(v: &Json) -> Result<StreamPollBody, String> {
    Ok(StreamPollBody {
        observed: usize_field(v, "observed")?,
        live_candidates: usize_field(v, "live_candidates")?,
        culled: v.get("culled").and_then(Json::as_u64).ok_or_else(|| "missing culled".to_string())?,
        top: top_from_json(v.get("top").ok_or_else(|| "missing top".to_string())?)?,
        decision: opt_decision_from_json(v.get("decision"))?,
    })
}

fn final_to_json(fb: &Option<FinalBody>) -> Json {
    match fb {
        Some(f) => Json::obj(vec![
            ("app", Json::Str(f.app.clone())),
            ("config", Json::Str(f.config.clone())),
            ("entry", Json::Num(f.entry as f64)),
            ("distance", Json::Num(f.distance)),
            ("similarity", Json::Num(f.similarity)),
            ("matched", Json::Bool(f.matched)),
        ]),
        None => Json::Null,
    }
}

fn final_from_json(v: Option<&Json>) -> Result<Option<FinalBody>, String> {
    match v {
        None | Some(Json::Null) => Ok(None),
        Some(f) => Ok(Some(FinalBody {
            app: str_field(f, "app")?,
            config: str_field(f, "config")?,
            entry: usize_field(f, "entry")?,
            distance: f64_field(f, "distance")?,
            similarity: f64_field(f, "similarity")?,
            matched: f.get("matched").and_then(Json::as_bool).ok_or_else(|| "missing matched".to_string())?,
        })),
    }
}

fn match_pairs(m: &MatchBody) -> Vec<(&'static str, Json)> {
    vec![
        (
            "results",
            Json::arr(
                m.results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("app", Json::Str(r.app.clone())),
                            ("similarity", Json::Num(r.similarity)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "match",
            m.matched
                .as_ref()
                .map(|a| Json::Str(a.clone()))
                .unwrap_or(Json::Null),
        ),
        ("best_similarity", Json::Num(m.best_similarity)),
    ]
}

fn match_from_json(v: &Json) -> Result<MatchBody, String> {
    let results = v
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing results".to_string())?
        .iter()
        .map(|r| {
            Ok(MatchRow {
                app: str_field(r, "app")?,
                similarity: f64_field(r, "similarity")?,
            })
        })
        .collect::<Result<Vec<_>, String>>()?;
    let matched = match v.get("match") {
        None | Some(Json::Null) => None,
        Some(a) => Some(a.as_str().ok_or_else(|| "bad match".to_string())?.to_string()),
    };
    Ok(MatchBody {
        results,
        matched,
        best_similarity: f64_field(v, "best_similarity")?,
    })
}

fn shard_info_to_json(s: &ShardInfoBody) -> Json {
    Json::obj(vec![
        ("entries", Json::Num(s.entries as f64)),
        (
            "apps",
            Json::arr(s.apps.iter().map(|a| Json::Str(a.clone())).collect()),
        ),
        (
            "configs",
            Json::arr(s.configs.iter().map(|c| Json::Str(c.clone())).collect()),
        ),
        (
            "sessions",
            Json::arr(s.sessions.iter().map(|&id| Json::Num(id as f64)).collect()),
        ),
    ])
}

fn shard_info_from_json(v: &Json) -> Result<ShardInfoBody, String> {
    let strings = |k: &str| -> Result<Vec<String>, String> {
        v.get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("missing {k}"))?
            .iter()
            .map(|s| s.as_str().map(str::to_string).ok_or_else(|| format!("bad {k} entry")))
            .collect()
    };
    Ok(ShardInfoBody {
        entries: usize_field(v, "entries")?,
        apps: strings("apps")?,
        configs: strings("configs")?,
        sessions: v
            .get("sessions")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing sessions".to_string())?
            .iter()
            .map(|s| s.as_u64().ok_or_else(|| "bad session id".to_string()))
            .collect::<Result<Vec<_>, _>>()?,
    })
}

fn tuned_pairs(t: &StreamTunedBody) -> Vec<(&'static str, Json)> {
    let mut pairs = vec![
        ("session", Json::Num(t.session as f64)),
        ("decided", Json::Bool(t.decided)),
    ];
    if let Some(app) = &t.app {
        pairs.push(("app", Json::Str(app.clone())));
    }
    if let Some(s) = t.similarity {
        pairs.push(("similarity", Json::Num(s)));
    }
    if let Some(cfg) = &t.optimal {
        pairs.push(("optimal", config_to_json(cfg)));
    }
    if let Some(s) = t.optimal_secs {
        pairs.push(("optimal_secs", Json::Num(s)));
    }
    if let Some(f) = t.fraction {
        pairs.push(("fraction", Json::Num(f)));
    }
    pairs
}

fn tuned_from_json(v: &Json) -> Result<StreamTunedBody, String> {
    Ok(StreamTunedBody {
        session: v
            .get("session")
            .and_then(Json::as_u64)
            .ok_or_else(|| "missing session".to_string())?,
        decided: v
            .get("decided")
            .and_then(Json::as_bool)
            .ok_or_else(|| "missing decided".to_string())?,
        app: match v.get("app") {
            None | Some(Json::Null) => None,
            Some(a) => Some(a.as_str().ok_or_else(|| "bad app".to_string())?.to_string()),
        },
        similarity: v.get("similarity").and_then(Json::as_f64),
        optimal: match v.get("optimal") {
            None | Some(Json::Null) => None,
            Some(c) => Some(parse_config(c).map_err(|e| e.message)?),
        },
        optimal_secs: v.get("optimal_secs").and_then(Json::as_f64),
        fraction: v.get("fraction").and_then(Json::as_f64),
    })
}

// ---------- Response-level rendering ----------

impl Response {
    /// The `type` tag this response serializes under.
    pub fn type_name(&self) -> &'static str {
        match self {
            Response::Pong => "pong",
            Response::Stats(_) => "stats",
            Response::Apps(_) => "apps",
            Response::ShardInfo(_) => "shard_info",
            Response::Match(_) => "match",
            Response::Knn(_) => "knn",
            Response::KnnBatch(_) => "knn_batch",
            Response::StreamOpened(_) => "stream_opened",
            Response::StreamFed(_) => "stream_fed",
            Response::StreamTop(_) => "stream_top",
            Response::Sessions(_) => "sessions",
            Response::StreamClosed(_) => "stream_closed",
            Response::StreamTuned(_) => "stream_tuned",
            Response::Metrics(_) => "metrics",
            Response::TraceDump(_) => "trace_dump",
        }
    }

    /// The v2 `body` object.
    pub fn to_body_json(&self) -> Json {
        match self {
            Response::Pong => Json::obj(vec![("pong", Json::Bool(true))]),
            Response::Stats(s) => Json::obj(vec![
                ("report", Json::Str(s.report.clone())),
                ("db_entries", Json::Num(s.db_entries as f64)),
                ("live_sessions", Json::Num(s.live_sessions as f64)),
            ]),
            Response::Apps(apps) => Json::obj(vec![(
                "apps",
                Json::arr(apps.iter().map(|a| Json::Str(a.clone())).collect()),
            )]),
            Response::ShardInfo(s) => shard_info_to_json(s),
            Response::Match(m) => Json::Obj(
                match_pairs(m)
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect(),
            ),
            Response::Knn(b) => knn_to_json(b, true),
            Response::KnnBatch(b) => {
                let mut pairs = vec![
                    (
                        "results",
                        Json::arr(b.results.iter().map(|r| knn_to_json(r, true)).collect()),
                    ),
                    ("stats", stats_to_json(&b.stats)),
                ];
                if !b.degraded.is_empty() {
                    pairs.push(("degraded", degraded_to_json(&b.degraded)));
                }
                Json::obj(pairs)
            }
            Response::StreamOpened(o) => Json::obj(vec![
                ("session", Json::Num(o.session as f64)),
                ("candidates", Json::Num(o.candidates as f64)),
            ]),
            Response::StreamFed(f) => Json::obj(vec![
                ("observed", Json::Num(f.observed as f64)),
                ("live_candidates", Json::Num(f.live_candidates as f64)),
                ("decision", opt_decision_json(&f.decision)),
            ]),
            Response::StreamTop(p) => Json::Obj(
                poll_pairs(p)
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect(),
            ),
            Response::Sessions(rows) => Json::obj(vec![(
                "sessions",
                Json::arr(
                    rows.iter()
                        .map(|r| {
                            let mut pairs = vec![("session", Json::Num(r.session as f64))];
                            pairs.extend(poll_pairs(&r.poll));
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            )]),
            Response::StreamClosed(c) => Json::obj(vec![
                ("observed", Json::Num(c.observed as f64)),
                ("final", final_to_json(&c.final_match)),
                ("decision", opt_decision_json(&c.decision)),
            ]),
            Response::StreamTuned(t) => Json::obj(tuned_pairs(t)),
            Response::Metrics(m) => m.clone(),
            Response::TraceDump(t) => t.clone(),
        }
    }

    /// The legacy rendering: exactly the object the pre-envelope server
    /// answered for this command (byte-compatible; pinned by golden tests).
    pub fn to_v1(&self) -> Json {
        let ok = ("ok", Json::Bool(true));
        match self {
            Response::Pong => Json::obj(vec![ok, ("pong", Json::Bool(true))]),
            Response::Stats(s) => Json::obj(vec![
                ok,
                ("report", Json::Str(s.report.clone())),
                ("db_entries", Json::Num(s.db_entries as f64)),
                ("live_sessions", Json::Num(s.live_sessions as f64)),
            ]),
            Response::Apps(apps) => Json::obj(vec![
                ok,
                (
                    "apps",
                    Json::arr(apps.iter().map(|a| Json::Str(a.clone())).collect()),
                ),
            ]),
            // v1 never had shard_info; render the v2 body plus "ok" so a
            // legacy-framed probe still gets a useful answer.
            Response::ShardInfo(s) => {
                let mut obj = match shard_info_to_json(s) {
                    Json::Obj(m) => m,
                    _ => unreachable!("shard info serializes as an object"),
                };
                obj.insert("ok".to_string(), Json::Bool(true));
                Json::Obj(obj)
            }
            Response::Match(m) => {
                let mut pairs = vec![ok];
                pairs.extend(match_pairs(m));
                Json::obj(pairs)
            }
            Response::Knn(b) => Json::obj(vec![
                ok,
                (
                    "neighbors",
                    Json::arr(b.neighbors.iter().map(|r| neighbor_to_json(r, false)).collect()),
                ),
                ("stats", stats_to_json(&b.stats)),
            ]),
            Response::KnnBatch(b) => Json::obj(vec![
                ok,
                (
                    "results",
                    Json::arr(b.results.iter().map(|r| knn_to_json(r, false)).collect()),
                ),
                ("stats", stats_to_json(&b.stats)),
            ]),
            Response::StreamOpened(o) => Json::obj(vec![
                ok,
                ("session", Json::Num(o.session as f64)),
                ("candidates", Json::Num(o.candidates as f64)),
            ]),
            Response::StreamFed(f) => Json::obj(vec![
                ok,
                ("observed", Json::Num(f.observed as f64)),
                ("live_candidates", Json::Num(f.live_candidates as f64)),
                ("decision", opt_decision_json(&f.decision)),
            ]),
            Response::StreamTop(p) => {
                let mut pairs = vec![ok];
                pairs.extend(poll_pairs(p));
                Json::obj(pairs)
            }
            Response::Sessions(rows) => Json::obj(vec![
                ok,
                (
                    "sessions",
                    Json::arr(
                        rows.iter()
                            .map(|r| {
                                let mut pairs = vec![("session", Json::Num(r.session as f64))];
                                pairs.extend(poll_pairs(&r.poll));
                                Json::obj(pairs)
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::StreamClosed(c) => Json::obj(vec![
                ok,
                ("observed", Json::Num(c.observed as f64)),
                ("final", final_to_json(&c.final_match)),
                ("decision", opt_decision_json(&c.decision)),
            ]),
            // v1 never had stream_tune; same treatment as shard_info — the
            // v2 body plus "ok" so a legacy-framed probe gets an answer.
            Response::StreamTuned(t) => {
                let mut pairs = vec![ok];
                pairs.extend(tuned_pairs(t));
                Json::obj(pairs)
            }
            // v1 never had metrics; same treatment as shard_info — the v2
            // body plus "ok" so a legacy-framed probe still gets an answer.
            Response::Metrics(m) => {
                let mut obj = match m.clone() {
                    Json::Obj(map) => map,
                    other => std::iter::once(("metrics".to_string(), other)).collect(),
                };
                obj.insert("ok".to_string(), Json::Bool(true));
                Json::Obj(obj)
            }
            // v1 never had trace_dump either; same ok-merged rendering.
            Response::TraceDump(t) => {
                let mut obj = match t.clone() {
                    Json::Obj(map) => map,
                    other => std::iter::once(("trace".to_string(), other)).collect(),
                };
                obj.insert("ok".to_string(), Json::Bool(true));
                Json::Obj(obj)
            }
        }
    }

    /// Decode a v2 body by its `type` tag (the client side).
    pub fn from_body(type_name: &str, body: &Json) -> Result<Response, String> {
        match type_name {
            "pong" => Ok(Response::Pong),
            "stats" => Ok(Response::Stats(StatsBody {
                report: str_field(body, "report")?,
                db_entries: usize_field(body, "db_entries")?,
                live_sessions: usize_field(body, "live_sessions")?,
            })),
            "apps" => Ok(Response::Apps(
                body.get("apps")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "missing apps".to_string())?
                    .iter()
                    .map(|a| a.as_str().map(str::to_string).ok_or_else(|| "bad app".to_string()))
                    .collect::<Result<Vec<_>, _>>()?,
            )),
            "shard_info" => shard_info_from_json(body).map(Response::ShardInfo),
            "match" => match_from_json(body).map(Response::Match),
            "knn" => knn_from_json(body).map(Response::Knn),
            "knn_batch" => {
                let results = body
                    .get("results")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "missing results".to_string())?
                    .iter()
                    .map(knn_from_json)
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(Response::KnnBatch(KnnBatchBody {
                    results,
                    stats: stats_from_json(
                        body.get("stats").ok_or_else(|| "missing stats".to_string())?,
                    )?,
                    degraded: degraded_from_json(body.get("degraded"))?,
                }))
            }
            "stream_opened" => Ok(Response::StreamOpened(StreamOpenBody {
                session: body
                    .get("session")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| "missing session".to_string())?,
                candidates: usize_field(body, "candidates")?,
            })),
            "stream_fed" => Ok(Response::StreamFed(StreamFeedBody {
                observed: usize_field(body, "observed")?,
                live_candidates: usize_field(body, "live_candidates")?,
                decision: opt_decision_from_json(body.get("decision"))?,
            })),
            "stream_top" => poll_from_json(body).map(Response::StreamTop),
            "sessions" => {
                let rows = body
                    .get("sessions")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "missing sessions".to_string())?
                    .iter()
                    .map(|r| {
                        Ok(SessionPollBody {
                            session: r
                                .get("session")
                                .and_then(Json::as_u64)
                                .ok_or_else(|| "missing session".to_string())?,
                            poll: poll_from_json(r)?,
                        })
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(Response::Sessions(rows))
            }
            "stream_closed" => Ok(Response::StreamClosed(StreamCloseBody {
                observed: usize_field(body, "observed")?,
                final_match: final_from_json(body.get("final"))?,
                decision: opt_decision_from_json(body.get("decision"))?,
            })),
            "stream_tuned" => tuned_from_json(body).map(Response::StreamTuned),
            "metrics" => Ok(Response::Metrics(body.clone())),
            "trace_dump" => Ok(Response::TraceDump(body.clone())),
            other => Err(format!("unknown response type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats() -> SearchStats {
        SearchStats {
            candidates: 10,
            pruned_lb_kim: 3,
            pruned_lb_paa: 2,
            pruned_lb_keogh: 1,
            abandoned: 1,
            dtw_evals: 3,
        }
    }

    fn sample_decision() -> DecisionBody {
        DecisionBody {
            app: "wordcount".into(),
            config: "M=4,R=2,FS=10M,I=20M".into(),
            entry: 2,
            distance: 0.5,
            similarity: 97.25,
            at_sample: 32,
            fraction: 0.5,
        }
    }

    fn sample_responses() -> Vec<Response> {
        let knn = KnnBody {
            neighbors: vec![
                NeighborRow {
                    index: 4,
                    app: "wordcount".into(),
                    config: "M=4,R=2,FS=10M,I=20M".into(),
                    distance: 0.25,
                    similarity: 98.5,
                },
                NeighborRow {
                    index: 0,
                    app: "terasort".into(),
                    config: "M=4,R=2,FS=10M,I=20M".into(),
                    distance: 1.5,
                    similarity: 40.0,
                },
            ],
            stats: sample_stats(),
            degraded: vec![],
        };
        vec![
            Response::Pong,
            Response::Stats(StatsBody {
                report: "requests=1".into(),
                db_entries: 24,
                live_sessions: 2,
            }),
            Response::Apps(vec!["terasort".into(), "wordcount".into()]),
            Response::ShardInfo(ShardInfoBody {
                entries: 12,
                apps: vec!["wordcount".into()],
                configs: vec!["M=4,R=2,FS=10M,I=20M".into()],
                sessions: vec![1, 3],
            }),
            Response::Match(MatchBody {
                results: vec![
                    MatchRow {
                        app: "wordcount".into(),
                        similarity: 95.5,
                    },
                    MatchRow {
                        app: "terasort".into(),
                        similarity: 41.25,
                    },
                ],
                matched: Some("wordcount".into()),
                best_similarity: 95.5,
            }),
            Response::Match(MatchBody {
                results: vec![],
                matched: None,
                best_similarity: 0.0,
            }),
            Response::Knn(knn.clone()),
            Response::Knn(KnnBody {
                degraded: vec![1, 2],
                ..knn.clone()
            }),
            Response::KnnBatch(KnnBatchBody {
                results: vec![knn.clone(), KnnBody {
                    neighbors: vec![],
                    stats: SearchStats::default(),
                    degraded: vec![],
                }],
                stats: sample_stats(),
                degraded: vec![],
            }),
            Response::KnnBatch(KnnBatchBody {
                results: vec![knn.clone()],
                stats: sample_stats(),
                degraded: vec![0],
            }),
            Response::StreamOpened(StreamOpenBody {
                session: 7,
                candidates: 12,
            }),
            Response::StreamFed(StreamFeedBody {
                observed: 48,
                live_candidates: 3,
                decision: Some(sample_decision()),
            }),
            Response::StreamFed(StreamFeedBody {
                observed: 8,
                live_candidates: 12,
                decision: None,
            }),
            Response::StreamTop(StreamPollBody {
                observed: 48,
                live_candidates: 3,
                culled: 9,
                top: vec![TopRow {
                    entry: 4,
                    app: "wordcount".into(),
                    config: "M=4,R=2,FS=10M,I=20M".into(),
                    distance: Some(0.5),
                    lower_bound: 0.25,
                }, TopRow {
                    entry: 1,
                    app: "terasort".into(),
                    config: "M=4,R=2,FS=10M,I=20M".into(),
                    distance: None,
                    lower_bound: 1.75,
                }],
                decision: None,
            }),
            Response::Sessions(vec![SessionPollBody {
                session: 1,
                poll: StreamPollBody {
                    observed: 16,
                    live_candidates: 2,
                    culled: 0,
                    top: vec![],
                    decision: Some(sample_decision()),
                },
            }]),
            Response::StreamClosed(StreamCloseBody {
                observed: 64,
                final_match: Some(FinalBody {
                    app: "wordcount".into(),
                    config: "M=4,R=2,FS=10M,I=20M".into(),
                    entry: 4,
                    distance: 0.125,
                    similarity: 99.5,
                    matched: true,
                }),
                decision: None,
            }),
            Response::StreamClosed(StreamCloseBody {
                observed: 0,
                final_match: None,
                decision: None,
            }),
            Response::StreamTuned(StreamTunedBody {
                session: 7,
                decided: true,
                app: Some("wordcount".into()),
                similarity: Some(97.25),
                optimal: Some(JobConfig::new(8, 4, 16.0, 20.0)),
                optimal_secs: Some(12.5),
                fraction: Some(0.5),
            }),
            Response::StreamTuned(StreamTunedBody {
                session: 9,
                decided: false,
                app: None,
                similarity: None,
                optimal: None,
                optimal_secs: None,
                fraction: None,
            }),
            Response::Metrics(Json::obj(vec![
                ("requests", Json::Num(12.0)),
                (
                    "latency",
                    Json::obj(vec![
                        ("n", Json::Num(12.0)),
                        ("p99_ms", Json::Num(3.0)),
                    ]),
                ),
                ("fanout", Json::arr(vec![])),
            ])),
            Response::TraceDump(Json::obj(vec![
                ("spans", Json::Num(2.0)),
                ("dropped", Json::Num(1.0)),
                (
                    "trace",
                    Json::obj(vec![
                        ("displayTimeUnit", Json::Str("ms".into())),
                        (
                            "traceEvents",
                            Json::arr(vec![Json::obj(vec![
                                ("name", Json::Str("request".into())),
                                ("ph", Json::Str("X".into())),
                                ("ts", Json::Num(2.0)),
                                ("dur", Json::Num(3.0)),
                            ])]),
                        ),
                    ]),
                ),
            ])),
        ]
    }

    #[test]
    fn v2_body_roundtrip_is_exact() {
        for (i, resp) in sample_responses().into_iter().enumerate() {
            let body = resp.to_body_json();
            // Through the serializer, like the real wire path.
            let reparsed = Json::parse(&body.to_string()).unwrap();
            let back = Response::from_body(resp.type_name(), &reparsed).unwrap();
            assert_eq!(back, resp, "case {i}");
        }
    }

    #[test]
    fn v1_rendering_has_legacy_shape() {
        let responses = sample_responses();
        for resp in &responses {
            let v1 = resp.to_v1();
            assert_eq!(v1.get("ok"), Some(&Json::Bool(true)), "{}", resp.type_name());
        }
        // v1 k-NN rows must NOT leak the v2 entry field.
        let knn = responses.iter().find(|r| matches!(r, Response::Knn(_))).unwrap();
        let rows = knn.to_v1();
        let row0 = &rows.get("neighbors").and_then(Json::as_arr).unwrap()[0];
        assert!(row0.get("entry").is_none());
        assert!(row0.get("app").is_some());
        // ...while the v2 body carries it.
        let row0v2 = &knn.to_body_json().get("neighbors").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(row0v2.get("entry").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn unknown_body_type_is_an_error() {
        assert!(Response::from_body("nope", &Json::obj(vec![])).is_err());
    }

    #[test]
    fn degraded_is_absent_unless_partial() {
        let full = KnnBody {
            neighbors: vec![],
            stats: SearchStats::default(),
            degraded: vec![],
        };
        // Empty degraded emits nothing: full answers are byte-identical
        // to pre-degradation replies (the compatibility guarantee).
        let line = Response::Knn(full.clone()).to_body_json().to_string();
        assert!(!line.contains("degraded"), "{line}");
        // A partial merge carries the lost shard slots, v2 body only.
        let partial = Response::Knn(KnnBody {
            degraded: vec![1],
            ..full
        });
        let line = partial.to_body_json().to_string();
        assert!(line.contains(r#""degraded":[1]"#), "{line}");
        assert!(!partial.to_v1().to_string().contains("degraded"), "v1 stays legacy");
    }
}
