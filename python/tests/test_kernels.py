"""L1 Pallas kernels vs the pure-jnp / numpy oracles.

The CORE correctness signal of the compiled path: the same kernels are
lowered into the HLO artifacts the Rust coordinator executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import filters
from compile.kernels import cheby, dtw, ref


def pad(x, L):
    out = np.zeros(L, np.float32)
    out[: len(x)] = x
    return out


def banded_dtw_numpy(x, y):
    """Float64 banded DTW with the shared band formula — independent oracle."""
    n, m = len(x), len(y)
    drift = (max(m, 2) - 1) / (max(n, 2) - 1)
    radius = np.ceil(max(0.1 * max(n, m), drift + 2.0))
    D = np.full((n, m), np.inf)
    for i in range(n):
        c = i * drift
        lo = max(0, int(np.floor(c - radius)))
        hi = min(m - 1, int(np.ceil(c + radius)))
        for j in range(lo, hi + 1):
            d = abs(x[i] - y[j])
            if i == 0 and j == 0:
                D[0, 0] = d
            elif i == 0:
                D[0, j] = D[0, j - 1] + d
            else:
                best = min(
                    D[i - 1, j],
                    D[i - 1, j - 1] if j > 0 else np.inf,
                    D[i, j - 1] if j > 0 else np.inf,
                )
                D[i, j] = best + d
    return D[n - 1, m - 1]


@pytest.mark.parametrize("L", [32, 64, 128])
def test_dtw_kernel_matches_numpy(L):
    rng = np.random.default_rng(L)
    for _ in range(4):
        nx = int(rng.integers(4, L + 1))
        ny = int(rng.integers(4, L + 1))
        x = rng.random(nx)
        y = rng.random(ny)
        want = banded_dtw_numpy(x, y)
        d, _ = dtw.dtw_pair(
            jnp.array(pad(x, L)),
            jnp.array(pad(y, L)),
            jnp.array([nx], jnp.int32),
            jnp.array([ny], jnp.int32),
        )
        assert abs(float(d) - want) < 1e-3 * max(want, 1.0)


def test_dtw_kernel_matches_jnp_reference():
    L = 48
    rng = np.random.default_rng(7)
    for _ in range(5):
        nx = int(rng.integers(4, L + 1))
        ny = int(rng.integers(4, L + 1))
        x = pad(rng.random(nx), L)
        y = pad(rng.random(ny), L)
        d_ref, _ = ref.dtw_reference(x, y, nx, ny)
        d_k, _ = dtw.dtw_pair(
            jnp.array(x), jnp.array(y), jnp.array([nx], jnp.int32), jnp.array([ny], jnp.int32)
        )
        np.testing.assert_allclose(float(d_k), float(d_ref), rtol=1e-4, atol=1e-4)


def test_dtw_traceback_path_is_optimal():
    # Backtracking the kernel's choice matrix reproduces the DTW distance.
    L = 64
    rng = np.random.default_rng(3)
    nx, ny = 50, 37
    x = rng.random(nx)
    y = rng.random(ny)
    d, ch = dtw.dtw_pair(
        jnp.array(pad(x, L)),
        jnp.array(pad(y, L)),
        jnp.array([nx], jnp.int32),
        jnp.array([ny], jnp.int32),
    )
    path = ref.backtrack_numpy(np.asarray(ch), nx, ny)
    cost = sum(abs(x[i] - y[j]) for i, j in path)
    assert abs(cost - float(d)) < 1e-3
    # Monotone, connected, endpoint-correct.
    assert path[0] == (0, 0) and path[-1] == (nx - 1, ny - 1)
    for (i0, j0), (i1, j1) in zip(path, path[1:]):
        assert 0 <= i1 - i0 <= 1 and 0 <= j1 - j0 <= 1 and (i1 - i0) + (j1 - j0) >= 1


def test_dtw_batch_equals_pairs():
    L, B = 64, 8
    rng = np.random.default_rng(5)
    x = rng.random(60)
    ys, nys = [], []
    for _ in range(B):
        n = int(rng.integers(4, L + 1))
        ys.append(pad(rng.random(n), L))
        nys.append(n)
    dists, _ = dtw.dtw_batch(
        jnp.array(pad(x, L)),
        jnp.array(np.stack(ys)),
        jnp.array([60], jnp.int32),
        jnp.array(nys, jnp.int32),
    )
    for b in range(B):
        d, _ = dtw.dtw_pair(
            jnp.array(pad(x, L)),
            jnp.array(ys[b]),
            jnp.array([60], jnp.int32),
            jnp.array([nys[b]], jnp.int32),
        )
        np.testing.assert_allclose(float(dists[b]), float(d), rtol=1e-5)


def test_dtw_self_distance_zero():
    L = 32
    x = pad(np.linspace(0, 1, 28), L)
    d, _ = dtw.dtw_pair(
        jnp.array(x), jnp.array(x), jnp.array([28], jnp.int32), jnp.array([28], jnp.int32)
    )
    assert abs(float(d)) < 1e-6


@settings(max_examples=15, deadline=None)
@given(
    nx=st.integers(3, 40),
    ny=st.integers(3, 40),
    seed=st.integers(0, 2**31),
)
def test_dtw_kernel_hypothesis_sweep(nx, ny, seed):
    L = 40
    rng = np.random.default_rng(seed)
    x = rng.random(nx)
    y = rng.random(ny)
    want = banded_dtw_numpy(x, y)
    d, _ = dtw.dtw_pair(
        jnp.array(pad(x, L)),
        jnp.array(pad(y, L)),
        jnp.array([nx], jnp.int32),
        jnp.array([ny], jnp.int32),
    )
    assert abs(float(d) - want) < 1e-3 * max(want, 1.0)


def test_preprocess_kernel_matches_references():
    L = 96
    rng = np.random.default_rng(11)
    n = 80
    x = pad(rng.random(n), L)
    got = np.asarray(cheby.preprocess(jnp.array(x), jnp.array([n], jnp.int32)))
    want_jnp = np.asarray(ref.preprocess_reference(filters.PAPER_SOS, x, n))
    np.testing.assert_allclose(got, want_jnp, atol=3e-5)
    # Against the float64 design path.
    y64 = filters.sosfilt(filters.PAPER_SOS, x[:n].astype(np.float64))
    want64 = (y64 - y64.min()) / (y64.max() - y64.min())
    np.testing.assert_allclose(got[:n], want64, atol=5e-4)
    assert np.all(got[n:] == 0.0)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 120), seed=st.integers(0, 2**31))
def test_preprocess_hypothesis_sweep(n, seed):
    L = 128
    rng = np.random.default_rng(seed)
    x = pad(rng.random(n), L)
    got = np.asarray(cheby.preprocess(jnp.array(x), jnp.array([n], jnp.int32)))
    assert got.shape == (L,)
    assert np.all(got >= 0.0) and np.all(got <= 1.0)
    assert np.all(got[n:] == 0.0)
