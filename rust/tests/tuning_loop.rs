//! The closed tuning loop, end to end: a live simulated job's
//! configuration actually changes mid-run in response to a streaming
//! match (with the hysteresis pinned: one flapping vote does not trigger
//! a second reconfiguration), and the server's `stream_tune` command
//! serves the same advice over the wire with its metrics visible.

use mrtuner::client::MrtunerClient;
use mrtuner::coordinator::metrics::Metrics;
use mrtuner::coordinator::server::{MatchServer, ServerState};
use mrtuner::database::profile::ProfileEntry;
use mrtuner::database::store::OptimalConfig;
use mrtuner::index::IndexedDb;
use mrtuner::signal::noise::NoiseModel;
use mrtuner::simulator::cluster::ClusterConfig;
use mrtuner::simulator::job::JobConfig;
use mrtuner::simulator::profile_run;
use mrtuner::streaming::{DecisionPolicy, SessionManager};
use mrtuner::tuning::{run_tuned, ControllerPolicy, TuningController};
use mrtuner::workloads::AppId;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Clean two-app reference database with *distinct* cached optimals, so
/// the applied configuration identifies which transfer fired.
fn tuned_db() -> IndexedDb {
    let profile_cfg = JobConfig::new(4, 2, 16.0, 60.0);
    let mut idx = IndexedDb::new();
    for (app, optimal) in [
        (AppId::WordCount, JobConfig::new(8, 4, 8.0, 60.0)),
        (AppId::TeraSort, JobConfig::new(16, 8, 32.0, 60.0)),
    ] {
        let res = profile_run(app, &profile_cfg, &NoiseModel::none(), 21);
        let raw_len = res.cpu_clean.len();
        idx.insert(ProfileEntry {
            app,
            config: profile_cfg,
            series: mrtuner::signal::preprocess(&res.cpu_clean),
            raw_len,
            completion_secs: res.completion_secs,
        });
        idx.set_optimal(app, OptimalConfig { config: optimal, completion_secs: 30.0 });
    }
    idx
}

#[test]
fn live_job_reconfigures_mid_run_from_a_streaming_match() {
    let idx = tuned_db();
    // Hadoop 0.20 default — the mis-tuned start both A/B arms share.
    let start = JobConfig::new(2, 1, 64.0, 60.0);
    let tuned = run_tuned(
        AppId::WordCount,
        &start,
        &ClusterConfig::pseudo_distributed(),
        &idx,
        DecisionPolicy::default(),
        ControllerPolicy::default(),
        &NoiseModel::none(),
        77,
    );
    // The engine itself counted a mid-run configuration change...
    assert!(
        tuned.result.counters.reconfigurations >= 1,
        "no mid-run reconfiguration fired"
    );
    // ...to one of the two cached optimals, input-corrected to the live job.
    let applied = tuned.applied.expect("a config was applied");
    assert!(
        [(8, 4), (16, 8)].contains(&(applied.mappers, applied.reducers)),
        "applied {applied:?} is not a cached optimal"
    );
    assert_eq!(applied.input_mb, 60.0);
    // The change happened strictly mid-run, not at either edge.
    let at = tuned.reconfigured_at.expect("reconfiguration timestamp");
    assert!(at > 0.0 && at < tuned.result.completion_secs, "at={at}");
    assert!(tuned.result.completion_secs.is_finite());
}

#[test]
fn one_flapping_vote_cannot_trigger_a_second_reconfiguration() {
    let a = JobConfig::new(8, 4, 8.0, 60.0);
    let b = JobConfig::new(16, 8, 32.0, 60.0);
    let start = JobConfig::new(2, 1, 64.0, 60.0);
    let mut gate = TuningController::new(ControllerPolicy::default());
    // Converge and fire the first reconfiguration.
    while gate.reconfigurations() == 0 {
        gate.vote(AppId::WordCount, Some(a), start);
    }
    // A single flap to the other app: suppressed, not applied.
    assert_eq!(gate.vote(AppId::TeraSort, Some(b), a), None);
    assert_eq!(gate.reconfigurations(), 1, "flap must not move the job");
    assert_eq!(gate.suppressed_flaps(), 1);
    // Returning to the winning app keeps the job where it is too.
    assert_eq!(gate.vote(AppId::WordCount, Some(a), a), None);
    assert_eq!(gate.reconfigurations(), 1);
}

#[test]
fn stream_tune_serves_cached_optimals_over_the_wire() {
    let idx = tuned_db();
    let profile_cfg = JobConfig::new(4, 2, 16.0, 60.0);
    let state = ServerState {
        db: idx,
        runtime: None,
        metrics: Metrics::new(),
        sessions: SessionManager::new(),
        tracer: mrtuner::trace::TraceHandle::disabled(),
        recorder: None,
        predictors: Default::default(),
    };
    let server = MatchServer::bind("127.0.0.1:0", state).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_flag();
    let handle = std::thread::spawn(move || server.serve_with(2, Duration::from_millis(50)));

    let mut client = MrtunerClient::connect(&addr.to_string()).unwrap();
    // A fresh WordCount capture at the profiled config, streamed in with
    // job-progress reports so the server-side predictor runs.
    let run = profile_run(AppId::WordCount, &profile_cfg, &NoiseModel::none(), 99);
    let total = run.cpu_clean.len().max(1);
    let opened = client.stream_open(Some(&profile_cfg), Some(total)).unwrap();
    let mut fed = 0usize;
    for chunk in run.cpu_clean.chunks(16) {
        fed += chunk.len();
        let progress = fed as f64 / total as f64;
        client
            .stream_feed_progress(opened.session, chunk, Some(progress))
            .unwrap();
    }

    let advice = client.stream_tune(opened.session).unwrap();
    assert_eq!(advice.session, opened.session);
    let app = advice.app.as_deref().expect("a leading app");
    assert!(["wordcount", "terasort"].contains(&app), "{app}");
    // Every app in this database has a cached optimal, so advice carries one.
    let optimal = advice.optimal.expect("cached optimal");
    assert!(
        [(8, 4), (16, 8)].contains(&(optimal.mappers, optimal.reducers)),
        "{optimal:?}"
    );
    assert!(advice.optimal_secs.unwrap() > 0.0);
    if advice.decided {
        assert!(advice.similarity.is_some() && advice.fraction.is_some());
    }

    // The pinned tuning metrics block saw the loop run.
    let metrics = client.metrics().unwrap();
    let num = |path: &[&str]| -> f64 {
        let mut v = &metrics;
        for k in path {
            v = v.get(k).unwrap_or_else(|| panic!("missing {path:?}"));
        }
        v.as_f64().unwrap()
    };
    assert!(num(&["tuning", "tunes_served"]) >= 1.0);
    assert!(num(&["tuning", "predictor_updates"]) >= 1.0);

    // Unknown sessions get the typed error, not a hang or a panic.
    let err = client.stream_tune(opened.session + 1000).unwrap_err();
    assert_eq!(err.code(), Some(mrtuner::protocol::ErrorCode::UnknownSession));

    client.stream_close(opened.session).unwrap();
    stop.store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
    handle.join().unwrap().unwrap();
}
