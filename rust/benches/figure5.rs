//! E2 — regenerate the paper's **Figure 5**: the Table 1 similarity data
//! plotted per query configuration (here: ASCII bars + CSV to stdout).
//!
//! Run with: `cargo bench --bench figure5`

use mrtuner::coordinator::{matcher::Matcher, ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::prelude::*;

fn bar(p: f64) -> String {
    let n = (p / 2.0).round() as usize;
    "#".repeat(n.min(50))
}

fn main() {
    mrtuner::util::logging::init();
    let grid = ConfigGrid::paper_table1();
    let mut sys = TuningSystem::new(SystemConfig::default());
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);
    let m = Matcher::new(&sys.config, sys.runtime());
    let table = m.similarity_table(AppId::EximParse, &grid, &sys.db);

    println!("== Figure 5: similarity of Exim vs reference apps, per query config ==");
    for q in &grid.configs {
        println!("\nquery config {}:", q.label());
        let mut cells: Vec<_> = table.iter().filter(|c| c.config.label() == q.label()).collect();
        cells.sort_by(|a, b| b.similarity.partial_cmp(&a.similarity).unwrap());
        for c in cells {
            let marker = if c.reference_config.label() == q.label() { "*" } else { " " };
            println!(
                "  {:12} {:24}{} {:5.1}% |{}",
                c.reference_app.name(),
                c.reference_config.label(),
                marker,
                c.similarity,
                bar(c.similarity)
            );
        }
    }

    println!("\ncsv:");
    println!("query_config,reference_app,reference_config,similarity_pct");
    for c in &table {
        println!(
            "{},{},{},{:.4}",
            c.config.label(),
            c.reference_app.name(),
            c.reference_config.label(),
            c.similarity
        );
    }
}
