//! Signal-processing pipeline used on CPU-utilization time series.
//!
//! The paper's pre-processing (§3.1.1) is a 6th-order low-pass Chebyshev
//! filter followed by magnitude normalization to `[0,1]`. This module holds
//! the pure-Rust implementations; the same computation is also lowered AOT
//! from JAX (see `python/compile/kernels/cheby.py`) and executed via PJRT on
//! the hot path — `rust/tests/parity.rs` pins the two against each other.

pub mod chebyshev;
pub mod noise;
pub mod normalize;
pub mod resample;
pub mod wavelet;

/// De-noise + normalize, exactly the paper's pre-processing step:
/// 6th-order type-I Chebyshev low-pass (0.5 dB ripple, 0.1 normalized
/// cutoff) followed by min-max normalization into `[0,1]`.
pub fn preprocess(series: &[f64]) -> Vec<f64> {
    let filt = chebyshev::Sos::lowpass_default();
    let smoothed = filt.filter(series);
    normalize::min_max(&smoothed)
}
