//! Self-tuning of *running* jobs: the closed control loop the paper
//! stops short of.
//!
//! The paper's pipeline (profile → match → transfer the matched app's
//! optimal configuration) tunes the *next* run of a job — classification
//! needs the completed CPU capture, by which point the run being
//! classified is over. This subsystem closes the loop mid-run:
//!
//! 1. [`predictor::LengthPredictor`] watches the job's task progress and
//!    fits a polynomial trend to predict the final capture length, with a
//!    confidence band that only ever tightens. Its
//!    [`predictor::LengthPredictor::final_len_hint`] feeds
//!    [`crate::streaming::StreamSession::set_final_len`], so the
//!    streaming classifier's prefix bounds work against an increasingly
//!    accurate final-length geometry instead of a loose worst case.
//! 2. [`controller::TuningController`] gates classification votes behind
//!    hysteresis — consecutive-vote thresholds and a reconfiguration cap
//!    — so a flapping anytime leader cannot thrash the job.
//! 3. [`controller::run_tuned`] wires both into
//!    [`crate::simulator::simulate_controlled`]: the live job's clean CPU
//!    stream is classified as it is produced, and once the gate opens the
//!    matched application's cached optimal configuration
//!    ([`crate::index::IndexedDb::optimal`]) is applied to the remaining
//!    work of the *same* run.
//!
//! `rust/benches/tuning_ab.rs` measures the payoff (tuned-mid-run vs
//! untuned completion across synthetic workloads, emitted as
//! `BENCH_tuning.json`); `rust/tests/tuning_loop.rs` pins the live
//! reconfiguration end-to-end. Over the wire, the blocking server serves
//! the same loop via `stream_tune` (see `PROTOCOL.md`).

pub mod controller;
pub mod predictor;

pub use controller::{run_tuned, ControllerPolicy, TunedRun, TuningController};
pub use predictor::{LengthPredictor, Prediction};
