//! Live mid-run tuning controller: closes the loop between the streaming
//! classifier and the running job.
//!
//! The paper tunes a job *after* classifying its completed CPU capture —
//! by which time the job is done and the optimal configuration helps only
//! its next run. With the simulator able to accept mid-run
//! reconfiguration ([`crate::simulator::simulate_controlled`]) and the
//! streaming layer able to classify a prefix
//! ([`crate::streaming::StreamSession`]), the two can be composed into a
//! closed loop: watch the live CPU stream, match it against the reference
//! database, and re-plan the not-yet-scheduled work under the matched
//! application's cached optimal configuration while the job is still
//! running.
//!
//! Two components:
//!
//! * [`TuningController`] — the hysteresis gate. Classification votes
//!   arrive every simulated second and the anytime leader can flap while
//!   the evidence is thin; reconfiguration, on the other hand, re-splits
//!   pending maps and may replace reducers, so thrashing is far worse
//!   than waiting. The controller requires a run of *consecutive*
//!   identical votes before acting ([`ControllerPolicy::first_after_votes`]),
//!   a longer run for any second move
//!   ([`ControllerPolicy::repeat_after_votes`]), and a hard cap on total
//!   reconfigurations ([`ControllerPolicy::max_reconfigs`]).
//! * [`run_tuned`] — the glue: drives one simulated job under a
//!   controller that feeds every tick's clean samples to a
//!   [`StreamSession`], keeps a [`LengthPredictor`] refining the
//!   session's final-length geometry, and applies the matched
//!   application's cached optimal (input size corrected to the live
//!   job's) through the hysteresis gate. `benches/tuning_ab.rs` measures
//!   the payoff against the untuned run.

use super::predictor::LengthPredictor;
use crate::index::IndexedDb;
use crate::signal::noise::NoiseModel;
use crate::simulator::cluster::ClusterConfig;
use crate::simulator::engine::{simulate_controlled, SimResult};
use crate::simulator::job::JobConfig;
use crate::streaming::{DecisionPolicy, FinalLen, StreamSession, MAX_RETAINED, MAX_STREAM_LEN};
use crate::util::rng::Rng;
use crate::workloads::{workload_for, AppId};

/// When the controller may act on classification votes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ControllerPolicy {
    /// Consecutive identical votes required before the first
    /// reconfiguration.
    pub first_after_votes: usize,
    /// Consecutive identical votes required before any *later*
    /// reconfiguration — stiffer, because a job that already moved once
    /// should rarely move again.
    pub repeat_after_votes: usize,
    /// Hard cap on mid-run reconfigurations.
    pub max_reconfigs: usize,
}

impl Default for ControllerPolicy {
    fn default() -> Self {
        ControllerPolicy {
            first_after_votes: 3,
            repeat_after_votes: 8,
            max_reconfigs: 2,
        }
    }
}

/// Hysteresis gate between classification votes and reconfigurations.
#[derive(Debug, Clone)]
pub struct TuningController {
    policy: ControllerPolicy,
    last_vote: Option<AppId>,
    streak: usize,
    reconfigs: usize,
    suppressed: u64,
}

impl TuningController {
    pub fn new(policy: ControllerPolicy) -> TuningController {
        TuningController {
            policy,
            last_vote: None,
            streak: 0,
            reconfigs: 0,
            suppressed: 0,
        }
    }

    /// Reconfigurations issued so far.
    pub fn reconfigurations(&self) -> usize {
        self.reconfigs
    }

    /// Flapping votes absorbed after the first reconfiguration — each one
    /// would have thrashed the job without the hysteresis.
    pub fn suppressed_flaps(&self) -> u64 {
        self.suppressed
    }

    /// Length of the current run of identical votes.
    pub fn streak(&self) -> usize {
        self.streak
    }

    /// The application the last vote named, if any.
    pub fn last_vote(&self) -> Option<AppId> {
        self.last_vote
    }

    /// Feed one classification vote: the current leading app, that app's
    /// cached optimal configuration (already corrected to the live job's
    /// input size), and the configuration currently in force. Returns the
    /// configuration to apply when — and only when — the hysteresis
    /// policy is satisfied.
    pub fn vote(
        &mut self,
        app: AppId,
        optimal: Option<JobConfig>,
        current: JobConfig,
    ) -> Option<JobConfig> {
        if self.last_vote == Some(app) {
            self.streak += 1;
        } else {
            if self.last_vote.is_some() && self.reconfigs > 0 {
                self.suppressed += 1;
            }
            self.last_vote = Some(app);
            self.streak = 1;
        }
        let cfg = optimal?;
        if cfg == current || self.reconfigs >= self.policy.max_reconfigs {
            return None;
        }
        let needed = if self.reconfigs == 0 {
            self.policy.first_after_votes
        } else {
            self.policy.repeat_after_votes
        };
        if self.streak < needed {
            return None;
        }
        self.reconfigs += 1;
        self.streak = 0;
        Some(cfg)
    }
}

/// Outcome of one self-tuned simulated run.
#[derive(Debug, Clone)]
pub struct TunedRun {
    pub result: SimResult,
    /// The frozen streaming decision the run converged on, if any.
    pub decided_app: Option<AppId>,
    /// Simulated second at which the first reconfiguration fired.
    pub reconfigured_at: Option<f64>,
    /// The configuration applied mid-run, if any.
    pub applied: Option<JobConfig>,
    /// Flapping votes the hysteresis absorbed.
    pub suppressed_flaps: u64,
}

/// Simulate `app` starting from `start`, classifying its live clean CPU
/// stream against `idx` and reconfiguring mid-run to the matched
/// application's cached optimal (`IndexedDb::optimal`) once the
/// controller's hysteresis is satisfied. Votes before the session's
/// frozen decision come from the anytime top-1, so the hysteresis gate is
/// doing real work; the final-length predictor keeps tightening the
/// session's band geometry from the job's task progress.
pub fn run_tuned(
    app: AppId,
    start: &JobConfig,
    cluster: &ClusterConfig,
    idx: &IndexedDb,
    decision_policy: DecisionPolicy,
    policy: ControllerPolicy,
    noise: &NoiseModel,
    seed: u64,
) -> TunedRun {
    let workload = workload_for(app);
    let mut session =
        StreamSession::open(idx, None, FinalLen::AtMost(MAX_STREAM_LEN), decision_policy);
    let mut predictor = LengthPredictor::new();
    let mut gate = TuningController::new(policy);
    let mut decided: Option<AppId> = None;
    let mut applied: Option<JobConfig> = None;
    let mut reconfigured_at: Option<f64> = None;
    let mut rng = Rng::new(seed);
    let result = simulate_controlled(
        workload.as_ref(),
        start,
        cluster,
        noise,
        &mut rng,
        &mut |tick| {
            predictor.observe(tick.progress(), tick.t);
            if let Some(hint) = predictor.final_len_hint(MAX_RETAINED) {
                session.set_final_len(idx, hint);
            }
            if let Some(d) = session.push(idx, tick.new_samples) {
                decided = Some(d.app);
            }
            let leader = match decided {
                Some(a) => a,
                None => session.top(idx, 1).first().map(|t| t.app)?,
            };
            let optimal = idx.optimal(leader).map(|o| {
                let mut cfg = o.config;
                cfg.input_mb = tick.config.input_mb;
                cfg
            });
            let cfg = gate.vote(leader, optimal, tick.config)?;
            applied = Some(cfg);
            if reconfigured_at.is_none() {
                reconfigured_at = Some(tick.t);
            }
            Some(cfg)
        },
    );
    TunedRun {
        result,
        decided_app: decided,
        reconfigured_at,
        applied,
        suppressed_flaps: gate.suppressed_flaps(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::database::profile::ProfileEntry;
    use crate::database::store::OptimalConfig;
    use crate::signal;
    use crate::simulator::profile_run;

    #[test]
    fn hysteresis_survives_single_flaps() {
        let a_cfg = JobConfig::new(8, 4, 16.0, 100.0);
        let b_cfg = JobConfig::new(16, 8, 32.0, 100.0);
        let start = JobConfig::new(2, 1, 64.0, 100.0);
        let mut c = TuningController::new(ControllerPolicy::default());
        // Three consistent votes fire the first reconfiguration.
        assert_eq!(c.vote(AppId::WordCount, Some(a_cfg), start), None);
        assert_eq!(c.vote(AppId::WordCount, Some(a_cfg), start), None);
        assert_eq!(c.vote(AppId::WordCount, Some(a_cfg), start), Some(a_cfg));
        assert_eq!(c.reconfigurations(), 1);
        // One flapping vote must NOT trigger a second reconfiguration.
        assert_eq!(c.vote(AppId::TeraSort, Some(b_cfg), a_cfg), None);
        assert_eq!(c.suppressed_flaps(), 1);
        assert_eq!(c.reconfigurations(), 1);
        // Even seven in a row stay below the repeat threshold of eight.
        for _ in 0..6 {
            assert_eq!(c.vote(AppId::TeraSort, Some(b_cfg), a_cfg), None);
        }
        // The eighth consecutive vote may finally move the job again.
        assert_eq!(c.vote(AppId::TeraSort, Some(b_cfg), a_cfg), Some(b_cfg));
        assert_eq!(c.reconfigurations(), 2);
        // The hard cap stops any further motion, however persistent.
        for _ in 0..20 {
            assert_eq!(c.vote(AppId::WordCount, Some(a_cfg), b_cfg), None);
        }
        assert_eq!(c.reconfigurations(), 2);
    }

    #[test]
    fn aligned_or_unknown_votes_never_fire() {
        let cur = JobConfig::new(8, 4, 16.0, 100.0);
        let mut c = TuningController::new(ControllerPolicy::default());
        for _ in 0..10 {
            // No cached optimal → nothing to transfer.
            assert_eq!(c.vote(AppId::Grep, None, cur), None);
            // Already running the optimal → nothing to change.
            assert_eq!(c.vote(AppId::Grep, Some(cur), cur), None);
        }
        assert_eq!(c.reconfigurations(), 0);
        assert_eq!(c.last_vote(), Some(AppId::Grep));
        assert!(c.streak() >= 10);
    }

    #[test]
    fn run_tuned_reconfigures_a_live_job() {
        // Reference database: clean profiles of two distinguishable apps
        // under a shared profiling config, with cached optimals.
        let profile_cfg = JobConfig::new(4, 2, 16.0, 60.0);
        let mut idx = IndexedDb::new();
        for app in [AppId::WordCount, AppId::TeraSort] {
            let res = profile_run(app, &profile_cfg, &NoiseModel::none(), 21);
            let raw_len = res.cpu_clean.len();
            idx.insert(ProfileEntry {
                app,
                config: profile_cfg,
                series: signal::preprocess(&res.cpu_clean),
                raw_len,
                completion_secs: res.completion_secs,
            });
            idx.set_optimal(
                app,
                OptimalConfig {
                    config: JobConfig::new(8, 4, 8.0, 60.0),
                    completion_secs: 0.0,
                },
            );
        }
        // Run WordCount from the Hadoop default: whichever app the stream
        // matches, a cached optimal exists and differs from the default,
        // so the controller must fire exactly through the hysteresis gate.
        let start = JobConfig::new(2, 1, 64.0, 60.0);
        let cluster = ClusterConfig::pseudo_distributed();
        let tuned = run_tuned(
            AppId::WordCount,
            &start,
            &cluster,
            &idx,
            DecisionPolicy::default(),
            ControllerPolicy::default(),
            &NoiseModel::none(),
            77,
        );
        assert!(
            tuned.result.counters.reconfigurations >= 1,
            "controller never fired"
        );
        assert_eq!(tuned.applied.map(|c| (c.mappers, c.reducers)), Some((8, 4)));
        assert_eq!(tuned.applied.map(|c| c.input_mb), Some(60.0));
        assert!(tuned.reconfigured_at.is_some());
        assert!(tuned.result.completion_secs.is_finite());
        assert!(!tuned.result.cpu_clean.is_empty());
    }

    #[test]
    fn run_tuned_with_empty_db_is_a_plain_run() {
        let idx = IndexedDb::new();
        let start = JobConfig::new(2, 1, 64.0, 40.0);
        let cluster = ClusterConfig::pseudo_distributed();
        let tuned = run_tuned(
            AppId::Grep,
            &start,
            &cluster,
            &idx,
            DecisionPolicy::default(),
            ControllerPolicy::default(),
            &NoiseModel::none(),
            5,
        );
        assert_eq!(tuned.result.counters.reconfigurations, 0);
        assert!(tuned.applied.is_none());
        assert!(tuned.decided_app.is_none());
    }
}
