//! WordCount — the paper's first reference application (§5).
//!
//! Reads text, emits `<word, 1>` per token, combiner/reducer sum the counts
//! and write `word \t count` lines. Input is a synthetic natural-text corpus
//! with Zipf(1.0)-distributed word frequencies over a generated vocabulary —
//! the statistic the combiner's selectivity (and hence the shuffle volume)
//! depends on.

use super::traits::{CostModel, Emit, Workload};
use super::AppId;
use crate::util::rng::{Rng, Zipf};

/// Vocabulary size for the synthetic corpus.
const VOCAB: usize = 5_000;
/// Words per generated line (min, max).
const LINE_WORDS: (usize, usize) = (6, 14);

pub struct WordCount {
    vocab: Vec<String>,
    zipf: Zipf,
}

impl Default for WordCount {
    fn default() -> Self {
        // Vocabulary is derived from a fixed seed so that every instance
        // (and every test) sees the same corpus statistics.
        let mut rng = Rng::new(0x0077_0c0d_e5ee_d001);
        let vocab = build_vocab(&mut rng, VOCAB);
        WordCount {
            vocab,
            zipf: Zipf::new(VOCAB, 1.0),
        }
    }
}

fn build_vocab(rng: &mut Rng, n: usize) -> Vec<String> {
    const CONSONANTS: &[u8] = b"bcdfghjklmnprstvwz";
    const VOWELS: &[u8] = b"aeiou";
    let mut seen = std::collections::BTreeSet::new();
    let mut vocab = Vec::with_capacity(n);
    while vocab.len() < n {
        let syllables = 1 + rng.below(4) as usize;
        let mut w = String::new();
        for _ in 0..syllables {
            w.push(*rng.choose(CONSONANTS) as char);
            w.push(*rng.choose(VOWELS) as char);
            if rng.chance(0.3) {
                w.push(*rng.choose(CONSONANTS) as char);
            }
        }
        if seen.insert(w.clone()) {
            vocab.push(w);
        }
    }
    vocab
}

impl Workload for WordCount {
    fn id(&self) -> AppId {
        AppId::WordCount
    }

    fn generate(&self, bytes: usize, rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::with_capacity(bytes + 64);
        while out.len() < bytes {
            let words = rng.range_u64(LINE_WORDS.0 as u64, LINE_WORDS.1 as u64 + 1) as usize;
            for i in 0..words {
                if i > 0 {
                    out.push(b' ');
                }
                out.extend_from_slice(self.vocab[self.zipf.sample(rng)].as_bytes());
            }
            out.push(b'\n');
        }
        out
    }

    fn map(&self, split: &[u8], emit: &mut Emit) {
        for line in split.split(|&b| b == b'\n') {
            for word in line
                .split(|&b| b == b' ' || b == b'\t')
                .filter(|w| !w.is_empty())
            {
                emit(word, b"1");
            }
        }
    }

    fn combine(&self, _key: &[u8], values: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        let sum: u64 = values.iter().map(|v| parse_count(v)).sum();
        vec![sum.to_string().into_bytes()]
    }

    fn reduce(&self, key: &[u8], values: &[Vec<u8>], out: &mut Vec<u8>) {
        let sum: u64 = values.iter().map(|v| parse_count(v)).sum();
        out.extend_from_slice(key);
        out.push(b'\t');
        out.extend_from_slice(sum.to_string().as_bytes());
        out.push(b'\n');
    }

    fn default_costs(&self) -> CostModel {
        // Calibrated on the reference core (see `calibrate`): tokenisation-
        // bound map, strong combiner, cheap summing reduce. The map-heavy
        // profile is what makes WordCount's CPU series resemble Exim's.
        CostModel {
            map_cpu_s_per_mb: 6.0,
            map_selectivity: 0.08,
            sort_cpu_s_per_mb: 0.6,
            reduce_cpu_s_per_mb: 0.9,
            reduce_selectivity: 0.9,
            startup_cpu_s: 1.2,
        }
    }

    fn partition_weights(&self, r: usize, rng: &mut Rng) -> Vec<f64> {
        // Zipf keys hash unevenly: weight each vocabulary word by its Zipf
        // mass and accumulate per hash bucket.
        let mut w = vec![0.0f64; r];
        let _ = rng;
        for (rank, word) in self.vocab.iter().enumerate() {
            let mass = 1.0 / (rank as f64 + 1.0);
            let b = (super::mapreduce::fnv1a(word.as_bytes()) % r as u64) as usize;
            w[b] += mass;
        }
        let total: f64 = w.iter().sum();
        for v in &mut w {
            *v /= total;
        }
        w
    }
}

fn parse_count(v: &[u8]) -> u64 {
    std::str::from_utf8(v)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mapreduce::run_job;

    #[test]
    fn counts_small_known_input() {
        let wc = WordCount::default();
        let input = b"a b a\nc a b\n".to_vec();
        let out = run_job(&wc, &input, 2, 1);
        let text = String::from_utf8(out.reducer_outputs[0].clone()).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        lines.sort();
        assert_eq!(lines, vec!["a\t3", "b\t2", "c\t1"]);
    }

    #[test]
    fn generated_corpus_is_text_lines() {
        let wc = WordCount::default();
        let mut rng = Rng::new(1);
        let data = wc.generate(8 * 1024, &mut rng);
        assert!(data.len() >= 8 * 1024);
        let text = std::str::from_utf8(&data).expect("ascii corpus");
        for line in text.lines().take(50) {
            assert!(!line.trim().is_empty());
            assert!(line.split(' ').count() >= LINE_WORDS.0);
        }
    }

    #[test]
    fn zipf_corpus_is_skewed() {
        let wc = WordCount::default();
        let mut rng = Rng::new(2);
        let data = wc.generate(64 * 1024, &mut rng);
        let out = run_job(&wc, &data, 1, 1);
        let text = String::from_utf8(out.reducer_outputs[0].clone()).unwrap();
        let mut counts: Vec<u64> = text
            .lines()
            .map(|l| l.split('\t').nth(1).unwrap().parse().unwrap())
            .collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Top word ≫ median word.
        let median = counts[counts.len() / 2];
        assert!(counts[0] > median * 20, "top={} median={median}", counts[0]);
    }

    #[test]
    fn combiner_shrinks_shuffle() {
        let wc = WordCount::default();
        let mut rng = Rng::new(3);
        let data = wc.generate(32 * 1024, &mut rng);
        let out = run_job(&wc, &data, 2, 2);
        assert!(
            out.counters.combine_output_bytes < out.counters.map_output_bytes / 2,
            "combiner ineffective: {} vs {}",
            out.counters.combine_output_bytes,
            out.counters.map_output_bytes
        );
    }

    #[test]
    fn total_count_equals_tokens() {
        let wc = WordCount::default();
        let mut rng = Rng::new(4);
        let data = wc.generate(16 * 1024, &mut rng);
        let tokens = data
            .split(|&b| b == b' ' || b == b'\n')
            .filter(|w| !w.is_empty())
            .count() as u64;
        let out = run_job(&wc, &data, 3, 4);
        let mut sum = 0u64;
        for ro in &out.reducer_outputs {
            for line in std::str::from_utf8(ro).unwrap().lines() {
                sum += line.split('\t').nth(1).unwrap().parse::<u64>().unwrap();
            }
        }
        assert_eq!(sum, tokens);
    }

    #[test]
    fn partition_weights_normalized_and_skewed() {
        let wc = WordCount::default();
        let mut rng = Rng::new(5);
        let w = wc.partition_weights(8, &mut rng);
        assert_eq!(w.len(), 8);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let max = w.iter().cloned().fold(0.0, f64::max);
        let min = w.iter().cloned().fold(1.0, f64::min);
        assert!(max / min > 1.05, "expected hash skew from zipf keys");
    }

    #[test]
    fn cost_model_plausible() {
        assert!(WordCount::default().default_costs().is_plausible());
    }
}
