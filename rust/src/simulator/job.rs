//! Job configuration — the four MapReduce parameters the paper tunes (§1):
//! number of mappers, number of reducers, file-system split size, input size.

/// One configuration-parameter set `{M, R, FS, I}`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobConfig {
    /// Requested number of map tasks (`mapred.map.tasks` hint).
    pub mappers: usize,
    /// Number of reduce tasks (`mapred.reduce.tasks`).
    pub reducers: usize,
    /// Split / block size in MB (`dfs.block.size` analogue).
    pub split_mb: f64,
    /// Input size in MB.
    pub input_mb: f64,
}

impl JobConfig {
    pub fn new(mappers: usize, reducers: usize, split_mb: f64, input_mb: f64) -> JobConfig {
        JobConfig {
            mappers,
            reducers,
            split_mb,
            input_mb,
        }
    }

    /// Actual number of map tasks: Hadoop 0.20's FileInputFormat produces
    /// one split per block, but honours a larger `mapred.map.tasks` hint by
    /// shrinking the goal split size — net effect `max(M, ceil(I/FS))`.
    pub fn num_map_tasks(&self) -> usize {
        let by_splits = (self.input_mb / self.split_mb).ceil() as usize;
        self.mappers.max(by_splits).max(1)
    }

    /// Stable compact label, e.g. `M=11,R=6,FS=20M,I=30M` (Table 1 headers).
    pub fn label(&self) -> String {
        format!(
            "M={},R={},FS={}M,I={}M",
            self.mappers, self.reducers, self.split_mb, self.input_mb
        )
    }

    /// The four configuration sets printed in the paper's Table 1.
    pub fn paper_table1() -> Vec<JobConfig> {
        vec![
            JobConfig::new(11, 6, 20.0, 30.0),
            JobConfig::new(21, 30, 10.0, 80.0),
            JobConfig::new(32, 21, 30.0, 80.0),
            JobConfig::new(42, 33, 20.0, 60.0),
        ]
    }

    /// Validity guard for property sweeps.
    pub fn is_valid(&self) -> bool {
        self.mappers >= 1
            && self.reducers >= 1
            && self.split_mb > 0.0
            && self.input_mb > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_rule_matches_hadoop() {
        // M hint dominates when larger than the block count.
        assert_eq!(JobConfig::new(11, 6, 20.0, 30.0).num_map_tasks(), 11);
        // Block count dominates when larger than the hint.
        assert_eq!(JobConfig::new(2, 6, 10.0, 100.0).num_map_tasks(), 10);
        // Exact division.
        assert_eq!(JobConfig::new(1, 1, 25.0, 100.0).num_map_tasks(), 4);
        // Remainder rounds up.
        assert_eq!(JobConfig::new(1, 1, 30.0, 100.0).num_map_tasks(), 4);
    }

    #[test]
    fn paper_table1_sets() {
        let sets = JobConfig::paper_table1();
        assert_eq!(sets.len(), 4);
        assert!(sets.iter().all(|c| c.is_valid()));
        assert_eq!(sets[0].label(), "M=11,R=6,FS=20M,I=30M");
        assert_eq!(sets[3].label(), "M=42,R=33,FS=20M,I=60M");
    }

    #[test]
    fn at_least_one_map_task() {
        assert_eq!(JobConfig::new(1, 1, 100.0, 1.0).num_map_tasks(), 1);
    }
}
