//! The reference database: profiled patterns plus known-optimal
//! configurations, persisted as JSON.

use super::profile::ProfileEntry;
use crate::simulator::job::JobConfig;
use crate::util::json::Json;
use crate::workloads::AppId;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Known-optimal configuration for an application (found by the tuner's
/// grid search; transferred to matched applications).
#[derive(Debug, Clone, PartialEq)]
pub struct OptimalConfig {
    pub config: JobConfig,
    pub completion_secs: f64,
}

/// In-memory reference database with JSON persistence.
#[derive(Debug, Default)]
pub struct ReferenceDb {
    entries: Vec<ProfileEntry>,
    optimal: BTreeMap<AppId, OptimalConfig>,
}

impl ReferenceDb {
    pub fn new() -> ReferenceDb {
        ReferenceDb::default()
    }

    /// Add a profiled run (replacing any previous entry for the same
    /// app + config set). Returns the position the replaced entry occupied,
    /// if any — every entry at a later position shifted down by one and the
    /// new entry went to the back, which is exactly what sidecar caches
    /// (e.g. `index::IndexedDb`) need to stay in sync.
    pub fn insert(&mut self, entry: ProfileEntry) -> Option<usize> {
        let replaced = self
            .entries
            .iter()
            .position(|e| e.app == entry.app && e.config_key() == entry.config_key());
        if let Some(p) = replaced {
            self.entries.remove(p);
        }
        self.entries.push(entry);
        replaced
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[ProfileEntry] {
        &self.entries
    }

    /// Applications present in the database.
    pub fn apps(&self) -> Vec<AppId> {
        let mut apps: Vec<AppId> = self.entries.iter().map(|e| e.app).collect();
        apps.sort_unstable();
        apps.dedup();
        apps
    }

    /// All entries captured under a given configuration set.
    pub fn by_config(&self, key: &str) -> Vec<&ProfileEntry> {
        self.entries.iter().filter(|e| e.config_key() == key).collect()
    }

    /// All entries for one application.
    pub fn by_app(&self, app: AppId) -> Vec<&ProfileEntry> {
        self.entries.iter().filter(|e| e.app == app).collect()
    }

    /// Record the tuner's optimal configuration for an application.
    pub fn set_optimal(&mut self, app: AppId, best: OptimalConfig) {
        self.optimal.insert(app, best);
    }

    pub fn optimal(&self, app: AppId) -> Option<&OptimalConfig> {
        self.optimal.get(&app)
    }

    pub fn to_json(&self) -> Json {
        let optimal = self
            .optimal
            .iter()
            .map(|(app, o)| {
                (
                    app.name().to_string(),
                    Json::obj(vec![
                        ("mappers", Json::Num(o.config.mappers as f64)),
                        ("reducers", Json::Num(o.config.reducers as f64)),
                        ("split_mb", Json::Num(o.config.split_mb)),
                        ("input_mb", Json::Num(o.config.input_mb)),
                        ("completion_secs", Json::Num(o.completion_secs)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            (
                "entries",
                Json::arr(self.entries.iter().map(ProfileEntry::to_json).collect()),
            ),
            ("optimal", Json::Obj(optimal)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ReferenceDb> {
        let mut db = ReferenceDb::new();
        for e in v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("db: missing entries"))?
        {
            db.insert(ProfileEntry::from_json(e)?);
        }
        if let Some(Json::Obj(map)) = v.get("optimal") {
            for (name, o) in map {
                let app = AppId::from_name(name)
                    .ok_or_else(|| anyhow!("db: unknown app {name}"))?;
                let num = |k: &str| -> Result<f64> {
                    o.get(k)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("db optimal: missing {k}"))
                };
                db.set_optimal(
                    app,
                    OptimalConfig {
                        config: JobConfig::new(
                            num("mappers")? as usize,
                            num("reducers")? as usize,
                            num("split_mb")?,
                            num("input_mb")?,
                        ),
                        completion_secs: num("completion_secs")?,
                    },
                );
            }
        }
        Ok(db)
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<ReferenceDb> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        ReferenceDb::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(app: AppId, m: usize) -> ProfileEntry {
        ProfileEntry {
            app,
            config: JobConfig::new(m, 2, 10.0, 20.0),
            series: vec![0.5; 4],
            raw_len: 4,
            completion_secs: 10.0 * m as f64,
        }
    }

    #[test]
    fn insert_replaces_same_key() {
        let mut db = ReferenceDb::new();
        assert_eq!(db.insert(entry(AppId::WordCount, 4)), None);
        assert_eq!(db.insert(entry(AppId::WordCount, 4)), Some(0));
        assert_eq!(db.len(), 1);
        assert_eq!(db.insert(entry(AppId::WordCount, 8)), None);
        assert_eq!(db.len(), 2);
        // Replacing the first entry reports its slot; the survivor shifts
        // down and the replacement goes to the back.
        assert_eq!(db.insert(entry(AppId::WordCount, 4)), Some(0));
        assert_eq!(db.entries()[0].config.mappers, 8);
        assert_eq!(db.entries()[1].config.mappers, 4);
    }

    #[test]
    fn queries() {
        let mut db = ReferenceDb::new();
        db.insert(entry(AppId::WordCount, 4));
        db.insert(entry(AppId::TeraSort, 4));
        db.insert(entry(AppId::TeraSort, 8));
        assert_eq!(db.apps(), vec![AppId::WordCount, AppId::TeraSort]);
        assert_eq!(db.by_app(AppId::TeraSort).len(), 2);
        assert_eq!(db.by_config("M=4,R=2,FS=10M,I=20M").len(), 2);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = ReferenceDb::new();
        db.insert(entry(AppId::WordCount, 4));
        db.insert(entry(AppId::EximParse, 6));
        db.set_optimal(
            AppId::WordCount,
            OptimalConfig {
                config: JobConfig::new(16, 4, 30.0, 20.0),
                completion_secs: 42.25,
            },
        );
        let path = std::env::temp_dir().join("mrtuner_db_test.json");
        db.save(&path).unwrap();
        let back = ReferenceDb::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.optimal(AppId::WordCount), db.optimal(AppId::WordCount));
        assert_eq!(back.entries()[0], db.entries()[0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(ReferenceDb::load(Path::new("/nonexistent/db.json")).is_err());
    }
}
