//! Exact k-nearest-neighbour search with the lower-bound cascade.
//!
//! Three execution strategies over one candidate contract:
//!
//! * [`knn`] — the serial scan. Same neighbours (same indices, same
//!   distances) as [`brute_force_knn`] over the same candidates: the
//!   cascade only ever skips candidates that provably cannot enter the
//!   result. Ties on distance resolve to the lower candidate id, exactly
//!   like the linear scan.
//! * [`knn_parallel`] — candidates fanned over `crate::util::pool::par_map`
//!   workers that **share one best-k cutoff** through
//!   [`crate::util::sync::AtomicF64Min`] (CAS-min over the f64 bit
//!   pattern; exhaustively model-checked by `tools/loom-models`), so a
//!   tight distance found on one core abandons hopeless DPs on every
//!   other core. The deterministic
//!   `(distance, index)` merge makes the result equal the serial top-k
//!   *exactly* (bit-identical distances; pinned by
//!   `rust/tests/query_engine.rs`).
//! * [`knn_batch`] — many queries against one candidate set, walked
//!   entry-major: per reference entry, all same-length queries share a
//!   single envelope pass ([`lb::keogh_rows_into`]) instead of paying one
//!   per (query, entry). Per query the candidate order, cutoffs and
//!   arithmetic are identical to [`knn`], so every result (and its
//!   [`SearchStats`]) equals the per-query search exactly.
//!
//! All DPs run through a [`DtwScratch`] arena — zero steady-state heap
//! allocations on the candidate scan.

use super::envelope::Envelope;
use super::lb::{keogh_rows_into, lb_keogh, lb_keogh_rows, lb_kim, lb_paa, query_extrema_into};
use super::{SearchStats, DEFAULT_BLOCK};
use crate::dtw::band_radius;
use crate::dtw::banded::dtw_banded_distance_cutoff_with;
use crate::dtw::scratch::{with_thread_scratch, DtwScratch};
use crate::util::pool::par_map;
use crate::util::sync::AtomicF64Min;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One search result: candidate id (position in the candidate set / the
/// database) and its exact banded-DTW distance to the query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub index: usize,
    pub distance: f64,
}

/// Queries shorter than this skip the PAA stage — the O(n) Keogh bound is
/// already nearly free there.
const PAA_MIN_LEN: usize = 64;

/// Below this candidate count [`knn_parallel`] falls back to the serial
/// scan: spinning up scoped workers costs more than the whole search.
const PARALLEL_MIN_CANDIDATES: usize = 32;

/// Absolute + relative slack added to the best-so-far cutoff so f64
/// rounding in the (mathematically admissible) bounds can never prune a
/// true neighbour.
fn cutoff(bsf: f64) -> f64 {
    if bsf.is_finite() {
        bsf + 1e-9 * (1.0 + bsf.abs())
    } else {
        bsf
    }
}

/// Insert into a (distance, index)-sorted top-k list; a linear scan that
/// updates on strict improvement keeps exactly the same set.
fn push_neighbor(best: &mut Vec<Neighbor>, k: usize, nb: Neighbor) {
    let pos = best
        .partition_point(|b| (b.distance, b.index) <= (nb.distance, nb.index));
    if pos < k {
        best.insert(pos, nb);
        best.truncate(k);
    }
}

/// Under `--features audit`, assert the cascade's admissibility for one
/// candidate that survived to an exact DP evaluation: every lower bound
/// that let it through must be ≤ the exact banded distance (plus the same
/// f64 slack [`cutoff`] grants the pruning direction). An inadmissible
/// bound here means some *other* candidate may have been wrongly pruned —
/// this tripwire fires on real query traffic, not just synthetic tests.
#[cfg(feature = "audit")]
fn audit_admissible(query: &[f64], series: &[f64], env: &Envelope, r: usize, distance: f64) {
    let slack = 1e-9 * (1.0 + distance.abs());
    let kim = lb_kim(query, series);
    debug_assert!(
        kim <= distance + slack,
        "audit: LB_Kim {kim} exceeds exact banded DTW {distance}"
    );
    let keogh = lb_keogh(query, env, r);
    debug_assert!(
        keogh <= distance + slack,
        "audit: LB_Keogh {keogh} exceeds exact banded DTW {distance}"
    );
    let n = query.len();
    if n >= PAA_MIN_LEN {
        let qext = super::lb::query_extrema(query, DEFAULT_BLOCK);
        let paa = lb_paa(&qext, n, DEFAULT_BLOCK, env, r);
        debug_assert!(
            paa <= distance + slack,
            "audit: LB_PAA {paa} exceeds exact banded DTW {distance}"
        );
    }
}

/// Exact top-`k` under banded DTW via the pruning cascade
/// (LB_Kim → LB_PAA → LB_Keogh → early-abandoning DP). Candidates are
/// `(id, series, envelope)`; empty series are skipped.
pub fn knn<'a>(
    query: &[f64],
    candidates: impl IntoIterator<Item = (usize, &'a [f64], &'a Envelope)>,
    k: usize,
) -> (Vec<Neighbor>, SearchStats) {
    with_thread_scratch(|scratch| knn_with(scratch, query, candidates, k))
}

/// [`knn`] with caller-provided scratch buffers (identical results).
pub fn knn_with<'a>(
    scratch: &mut DtwScratch,
    query: &[f64],
    candidates: impl IntoIterator<Item = (usize, &'a [f64], &'a Envelope)>,
    k: usize,
) -> (Vec<Neighbor>, SearchStats) {
    let mut stats = SearchStats::default();
    let mut best: Vec<Neighbor> = Vec::new();
    if k == 0 || query.is_empty() {
        return (best, stats);
    }
    let n = query.len();
    // The PAA stage is skipped for short queries, so don't pay its
    // query-side summary there either.
    let mut qext = scratch.extrema_buf();
    if n >= PAA_MIN_LEN {
        query_extrema_into(query, DEFAULT_BLOCK, &mut qext);
    }

    for (index, series, env) in candidates {
        if series.is_empty() {
            continue;
        }
        debug_assert_eq!(env.len(), series.len(), "envelope out of sync");
        stats.candidates += 1;
        let bsf = if best.len() == k {
            best[k - 1].distance
        } else {
            f64::INFINITY
        };
        let cut = cutoff(bsf);

        if lb_kim(query, series) > cut {
            stats.pruned_lb_kim += 1;
            continue;
        }
        let r = band_radius(n, series.len());
        if n >= PAA_MIN_LEN && lb_paa(&qext, n, DEFAULT_BLOCK, env, r) > cut {
            stats.pruned_lb_paa += 1;
            continue;
        }
        if lb_keogh(query, env, r) > cut {
            stats.pruned_lb_keogh += 1;
            continue;
        }
        match dtw_banded_distance_cutoff_with(scratch, query, series, r, cut) {
            None => stats.abandoned += 1,
            Some(distance) => {
                stats.dtw_evals += 1;
                #[cfg(feature = "audit")]
                audit_admissible(query, series, env, r, distance);
                push_neighbor(&mut best, k, Neighbor { index, distance });
            }
        }
    }
    scratch.put_extrema_buf(qext);
    (best, stats)
}

/// Exact top-`k` scored across up to `workers` threads. Each worker
/// claims candidate ranges off a shared counter and scans them with its
/// own scratch arena and a local top-k that **persists across claims**
/// (so its cutoff accumulates over its whole share, exactly like the
/// serial scan's does), while the tightest k-th-best distance any worker
/// has proven is published through a shared atomic — early-abandoning
/// cutoffs tighten *across* threads, not just within one scan. The
/// published value is always the k-th smallest of `k` actually-evaluated
/// candidate distances, hence an upper bound on the true k-th-best: no
/// true neighbour can be pruned, and the `(distance, index)` merge
/// returns exactly the serial [`knn`] result. [`SearchStats`] keep their
/// partition invariant but the per-stage split depends on thread timing
/// (a luckier cutoff prunes more).
pub fn knn_parallel<'a>(
    query: &[f64],
    candidates: &[(usize, &'a [f64], &'a Envelope)],
    k: usize,
    workers: usize,
) -> (Vec<Neighbor>, SearchStats) {
    if k == 0 || query.is_empty() {
        return (Vec::new(), SearchStats::default());
    }
    let workers = workers.max(1);
    if workers == 1 || candidates.len() < PARALLEL_MIN_CANDIDATES {
        return knn(query, candidates.iter().copied(), k);
    }
    let n = query.len();
    let qext: Vec<(f64, f64)> = if n >= PAA_MIN_LEN {
        super::lb::query_extrema(query, DEFAULT_BLOCK)
    } else {
        Vec::new()
    };
    let shared = AtomicF64Min::new(f64::INFINITY);
    let next = AtomicUsize::new(0);
    // Small claim ranges keep the load balanced when candidate costs vary;
    // each claim is one atomic increment.
    let chunk = candidates.len().div_ceil(workers * 4).max(1);
    let worker_ids: Vec<usize> = (0..workers).collect();

    let parts: Vec<(Vec<Neighbor>, SearchStats)> = par_map(&worker_ids, workers, |_| {
        with_thread_scratch(|scratch| {
            let mut stats = SearchStats::default();
            let mut best: Vec<Neighbor> = Vec::new();
            loop {
                // relaxed: monotone claim counter — the fetch_add itself
                // is what makes claims disjoint; candidate data is shared
                // read-only, so no release/acquire pairing is needed.
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= candidates.len() {
                    break;
                }
                let end = (start + chunk).min(candidates.len());
                for &(index, series, env) in &candidates[start..end] {
                    if series.is_empty() {
                        continue;
                    }
                    debug_assert_eq!(env.len(), series.len(), "envelope out of sync");
                    stats.candidates += 1;
                    let local = if best.len() == k {
                        best[k - 1].distance
                    } else {
                        f64::INFINITY
                    };
                    let bsf = shared.load().min(local);
                    let cut = cutoff(bsf);

                    if lb_kim(query, series) > cut {
                        stats.pruned_lb_kim += 1;
                        continue;
                    }
                    let r = band_radius(n, series.len());
                    if n >= PAA_MIN_LEN && lb_paa(&qext, n, DEFAULT_BLOCK, env, r) > cut {
                        stats.pruned_lb_paa += 1;
                        continue;
                    }
                    if lb_keogh(query, env, r) > cut {
                        stats.pruned_lb_keogh += 1;
                        continue;
                    }
                    match dtw_banded_distance_cutoff_with(scratch, query, series, r, cut) {
                        None => stats.abandoned += 1,
                        Some(distance) => {
                            stats.dtw_evals += 1;
                            #[cfg(feature = "audit")]
                            audit_admissible(query, series, env, r, distance);
                            push_neighbor(&mut best, k, Neighbor { index, distance });
                            if best.len() == k {
                                shared.fetch_min(best[k - 1].distance);
                            }
                        }
                    }
                }
            }
            (best, stats)
        })
    });

    let mut stats = SearchStats::default();
    let mut all: Vec<Neighbor> = Vec::new();
    for (part, s) in parts {
        all.extend(part);
        stats.merge(&s);
    }
    // Deterministic merge: the same (distance, index) order push_neighbor
    // maintains, over the union of the per-worker survivors.
    all.sort_by(|a, b| {
        (a.distance, a.index)
            .partial_cmp(&(b.distance, b.index))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    all.truncate(k);
    (all, stats)
}

/// Exact top-`k` for every query of a batch in one entry-major pass over
/// the candidates. Queries are ordered by length so all same-length
/// queries reuse a single precomputed envelope pass per reference entry
/// ([`lb::keogh_rows_into`]); per query, candidates are still seen in slice
/// order with the query's own best-so-far cutoff, so each result and its
/// counters are exactly what [`knn`] returns for that query alone.
/// Results come back in input order (empty queries yield empty results).
pub fn knn_batch<'a>(
    queries: &[&[f64]],
    candidates: &[(usize, &'a [f64], &'a Envelope)],
    k: usize,
) -> Vec<(Vec<Neighbor>, SearchStats)> {
    let mut out: Vec<(Vec<Neighbor>, SearchStats)> = queries
        .iter()
        .map(|_| (Vec::new(), SearchStats::default()))
        .collect();
    if k == 0 || queries.is_empty() {
        return out;
    }
    // Length-sorted walk order (stable within a length by input position).
    let mut order: Vec<usize> = (0..queries.len()).filter(|&i| !queries[i].is_empty()).collect();
    order.sort_by_key(|&i| (queries[i].len(), i));
    // Per-query PAA summaries, computed once for the whole batch.
    let qexts: Vec<Vec<(f64, f64)>> = queries
        .iter()
        .map(|q| {
            if q.len() >= PAA_MIN_LEN {
                super::lb::query_extrema(q, DEFAULT_BLOCK)
            } else {
                Vec::new()
            }
        })
        .collect();

    with_thread_scratch(|scratch| {
        let mut rows = scratch.extrema_buf();
        for &(index, series, env) in candidates {
            if series.is_empty() {
                continue;
            }
            debug_assert_eq!(env.len(), series.len(), "envelope out of sync");
            let mut gi = 0;
            while gi < order.len() {
                // One run of same-length queries shares this entry's
                // envelope pass; the pass itself is computed lazily, only
                // if some query in the run reaches the Keogh stage.
                let len = queries[order[gi]].len();
                let mut ge = gi;
                while ge < order.len() && queries[order[ge]].len() == len {
                    ge += 1;
                }
                let r = band_radius(len, series.len());
                let mut rows_ready = false;
                for &qi in &order[gi..ge] {
                    let query = queries[qi];
                    let (best, stats) = &mut out[qi];
                    stats.candidates += 1;
                    let bsf = if best.len() == k {
                        best[k - 1].distance
                    } else {
                        f64::INFINITY
                    };
                    let cut = cutoff(bsf);

                    if lb_kim(query, series) > cut {
                        stats.pruned_lb_kim += 1;
                        continue;
                    }
                    if len >= PAA_MIN_LEN && lb_paa(&qexts[qi], len, DEFAULT_BLOCK, env, r) > cut
                    {
                        stats.pruned_lb_paa += 1;
                        continue;
                    }
                    if !rows_ready {
                        keogh_rows_into(env, len, r, &mut rows);
                        rows_ready = true;
                    }
                    if lb_keogh_rows(query, &rows) > cut {
                        stats.pruned_lb_keogh += 1;
                        continue;
                    }
                    match dtw_banded_distance_cutoff_with(scratch, query, series, r, cut) {
                        None => stats.abandoned += 1,
                        Some(distance) => {
                            stats.dtw_evals += 1;
                            #[cfg(feature = "audit")]
                            audit_admissible(query, series, env, r, distance);
                            push_neighbor(best, k, Neighbor { index, distance });
                        }
                    }
                }
                gi = ge;
            }
        }
        scratch.put_extrema_buf(rows);
    });
    out
}

/// Reference implementation: evaluate the banded DTW on every candidate.
/// Same result contract as [`knn`]; used by the property tests and the
/// `index_perf` bench as the baseline.
pub fn brute_force_knn<'a>(
    query: &[f64],
    candidates: impl IntoIterator<Item = (usize, &'a [f64])>,
    k: usize,
) -> Vec<Neighbor> {
    let mut best: Vec<Neighbor> = Vec::new();
    if k == 0 || query.is_empty() {
        return best;
    }
    with_thread_scratch(|scratch| {
        for (index, series) in candidates {
            if series.is_empty() {
                continue;
            }
            let r = band_radius(query.len(), series.len());
            let distance =
                dtw_banded_distance_cutoff_with(scratch, query, series, r, f64::INFINITY)
                    .expect("infinite cutoff never abandons");
            push_neighbor(&mut best, k, Neighbor { index, distance });
        }
    });
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.25).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    fn corpus(g: &mut Pcg32, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| series(g, 40 + g.below(160) as usize)).collect()
    }

    fn with_envelopes(corpus: &[Vec<f64>]) -> Vec<Envelope> {
        corpus.iter().map(|s| Envelope::build(s, DEFAULT_BLOCK)).collect()
    }

    fn candidates<'a>(
        refs: &'a [Vec<f64>],
        envs: &'a [Envelope],
    ) -> Vec<(usize, &'a [f64], &'a Envelope)> {
        refs.iter()
            .zip(envs)
            .enumerate()
            .map(|(i, (s, e))| (i, s.as_slice(), e))
            .collect()
    }

    #[test]
    fn knn_matches_brute_force_exactly() {
        let mut g = Pcg32::new(60, 1);
        for round in 0..8 {
            let refs = corpus(&mut g, 30);
            let envs = with_envelopes(&refs);
            let q = series(&mut g, 30 + g.below(200) as usize);
            for k in [1usize, 3, 7] {
                let (fast, stats) = knn(
                    &q,
                    refs.iter()
                        .zip(&envs)
                        .enumerate()
                        .map(|(i, (s, e))| (i, s.as_slice(), e)),
                    k,
                );
                let slow =
                    brute_force_knn(&q, refs.iter().enumerate().map(|(i, s)| (i, s.as_slice())), k);
                assert_eq!(fast.len(), slow.len());
                for (a, b) in fast.iter().zip(&slow) {
                    assert_eq!(a.index, b.index, "round {round} k={k}");
                    assert_eq!(
                        a.distance.to_bits(),
                        b.distance.to_bits(),
                        "round {round} k={k}: {} vs {}",
                        a.distance,
                        b.distance
                    );
                }
                assert_eq!(stats.candidates, 30);
                assert_eq!(stats.pruned() + stats.dtw_started(), stats.candidates);
            }
        }
    }

    #[test]
    fn self_neighbour_is_found_with_distance_zero() {
        let mut g = Pcg32::new(61, 2);
        let refs = corpus(&mut g, 20);
        let envs = with_envelopes(&refs);
        let q = refs[13].clone();
        let (top, _) = knn(
            &q,
            refs.iter()
                .zip(&envs)
                .enumerate()
                .map(|(i, (s, e))| (i, s.as_slice(), e)),
            1,
        );
        assert_eq!(top[0].index, 13);
        assert_eq!(top[0].distance, 0.0);
    }

    #[test]
    fn pruning_actually_happens_on_a_spread_corpus() {
        // Corpus of well-separated constant levels: once the first close
        // candidate is seen, the far levels must die in the bounds.
        let refs: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![(i % 10) as f64 / 10.0; 128])
            .collect();
        let envs = with_envelopes(&refs);
        let q = vec![0.02_f64; 128];
        let (top, stats) = knn(
            &q,
            refs.iter()
                .zip(&envs)
                .enumerate()
                .map(|(i, (s, e))| (i, s.as_slice(), e)),
            1,
        );
        assert_eq!(top[0].index, 0, "level 0.0 is closest to 0.02");
        assert!(
            stats.pruned() + stats.abandoned > stats.candidates / 2,
            "no pruning on an easy corpus: {stats}"
        );
    }

    #[test]
    fn degenerate_inputs() {
        let refs: Vec<Vec<f64>> = vec![vec![0.5; 10], Vec::new()];
        let envs = with_envelopes(&refs);
        let cands = || {
            refs.iter()
                .zip(&envs)
                .enumerate()
                .map(|(i, (s, e))| (i, s.as_slice(), e))
        };
        let (empty_k, _) = knn(&[0.1, 0.2], cands(), 0);
        assert!(empty_k.is_empty());
        let (empty_q, _) = knn(&[], cands(), 3);
        assert!(empty_q.is_empty());
        // Empty candidate series is skipped, not an error.
        let (top, stats) = knn(&[0.1, 0.2, 0.3], cands(), 5);
        assert_eq!(top.len(), 1);
        assert_eq!(stats.candidates, 1);
        assert!(brute_force_knn(&[0.5], refs.iter().enumerate().map(|(i, s)| (i, s.as_slice())), 2).len() == 1);
    }

    #[test]
    fn parallel_equals_serial_and_respects_fallback() {
        let mut g = Pcg32::new(62, 3);
        let refs = corpus(&mut g, 80);
        let envs = with_envelopes(&refs);
        let cands = candidates(&refs, &envs);
        let q = series(&mut g, 120);
        for k in [1usize, 4] {
            let (serial, sstats) = knn(&q, cands.iter().copied(), k);
            for workers in [1usize, 2, 8] {
                let (par, pstats) = knn_parallel(&q, &cands, k, workers);
                assert_eq!(par.len(), serial.len(), "k={k} w={workers}");
                for (a, b) in par.iter().zip(&serial) {
                    assert_eq!(a.index, b.index, "k={k} w={workers}");
                    assert_eq!(a.distance.to_bits(), b.distance.to_bits());
                }
                assert_eq!(pstats.candidates, sstats.candidates);
                assert_eq!(pstats.pruned() + pstats.dtw_started(), pstats.candidates);
            }
        }
        // Below the fallback threshold the parallel entry point is the
        // serial scan (identical stats included).
        let few = &cands[..8];
        let (a, astats) = knn_parallel(&q, few, 2, 8);
        let (b, bstats) = knn(&q, few.iter().copied(), 2);
        assert_eq!(a.len(), b.len());
        assert_eq!(astats, bstats);
        // Degenerate parallel inputs.
        assert!(knn_parallel(&q, &cands, 0, 4).0.is_empty());
        assert!(knn_parallel(&[], &cands, 3, 4).0.is_empty());
    }

    #[test]
    fn batch_equals_per_query_including_stats() {
        let mut g = Pcg32::new(63, 4);
        let refs = corpus(&mut g, 40);
        let envs = with_envelopes(&refs);
        let cands = candidates(&refs, &envs);
        // Duplicate lengths on purpose: the shared envelope pass must not
        // perturb any query's cascade.
        let lens = [80usize, 80, 40, 120, 80, 120, 200, 64, 40];
        let queries: Vec<Vec<f64>> = lens.iter().map(|&l| series(&mut g, l)).collect();
        let mut qrefs: Vec<&[f64]> = queries.iter().map(Vec::as_slice).collect();
        qrefs.push(&[]); // empty query rides along harmlessly
        for k in [1usize, 3] {
            let batch = knn_batch(&qrefs, &cands, k);
            assert_eq!(batch.len(), qrefs.len());
            for (qi, q) in qrefs.iter().enumerate() {
                let (want, wstats) = knn(q, cands.iter().copied(), k);
                let (got, gstats) = &batch[qi];
                assert_eq!(got.len(), want.len(), "query {qi} k={k}");
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.index, b.index, "query {qi} k={k}");
                    assert_eq!(a.distance.to_bits(), b.distance.to_bits());
                }
                assert_eq!(*gstats, wstats, "query {qi} k={k}");
            }
        }
        // k = 0 returns one empty row per query.
        let empty = knn_batch(&qrefs, &cands, 0);
        assert!(empty.iter().all(|(nbs, s)| nbs.is_empty() && s.candidates == 0));
    }
}
