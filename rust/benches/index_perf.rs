//! Perf bench (index layer): brute-force matcher vs the lower-bound-cascade
//! index.
//!
//! Part 1 — the paper's §5 scenario (`paper_grid50`, WordCount + TeraSort
//! references, Exim query): the indexed matching phase must return the same
//! winning application as the brute-force matcher while paying one
//! correlation per configuration set.
//!
//! Part 2 — reference-DB scaling at sizes {50, 500, 5000}: exact top-1
//! retrieval, brute force vs cascade, reporting how many full/banded DTW
//! evaluations the lower bounds avoided. The acceptance bar is <= 50% of
//! candidates reaching the DTW at DB size 500; in practice the cascade
//! prunes far more.
//!
//! Run with: `cargo bench --bench index_perf`

#[path = "harness.rs"]
mod harness;

use harness::bench;
use mrtuner::coordinator::matcher::Matcher;
use mrtuner::coordinator::{ConfigGrid, SystemConfig, TuningSystem};
use mrtuner::database::profile::ProfileEntry;
use mrtuner::prelude::*;
use mrtuner::signal;
use mrtuner::util::rng::Rng;
use mrtuner::workloads::AppId;

/// Synthetic CPU-like pattern family: noisy sine, preprocessed exactly like
/// stored profiles.
fn wave(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let f = 0.04 + rng.f64() * 0.12;
    let phase = rng.f64() * 6.28;
    signal::preprocess(
        &(0..len)
            .map(|i| {
                (0.55 + 0.35 * ((i as f64) * f + phase).sin() + rng.normal_ms(0.0, 0.04))
                    .clamp(0.0, 1.0)
            })
            .collect::<Vec<_>>(),
    )
}

fn synthetic_db(n: usize) -> IndexedDb {
    let mut db = ReferenceDb::new();
    for i in 0..n {
        // Unique (M, R, FS) triple for every i < 42*40*50.
        let cfg = JobConfig::new(
            i % 42 + 1,
            (i / 42) % 40 + 1,
            (i / (42 * 40) + 1) as f64,
            100.0,
        );
        let len = 64 + (i * 37) % 256;
        db.insert(ProfileEntry {
            app: AppId::all()[i % AppId::all().len()],
            config: cfg,
            series: wave(len, i as u64),
            raw_len: len,
            completion_secs: 100.0,
        });
    }
    IndexedDb::from_db(db)
}

fn paper_scenario() {
    println!("== paper_grid50 scenario: brute-force matcher vs indexed kNN ==");
    let grid = ConfigGrid::paper_grid50(1);
    let sc = SystemConfig {
        use_runtime: false,
        ..SystemConfig::default()
    };
    let mut sys = TuningSystem::new(sc);
    sys.profile_app(AppId::WordCount, &grid);
    sys.profile_app(AppId::TeraSort, &grid);
    let m = Matcher::new(&sys.config, None);

    let brute = bench("brute-force match_app   (50 cfgs x 2 refs)", 0, 3, || {
        m.match_app(AppId::EximParse, &grid, &sys.db)
    });
    let brute_outcome = m.match_app(AppId::EximParse, &grid, &sys.db);

    let idx = IndexedDb::from_db(std::mem::take(&mut sys.db));
    let indexed = bench("indexed  match_app_indexed (rerank=1)     ", 0, 3, || {
        m.match_app_indexed(AppId::EximParse, &grid, &idx, 1)
    });
    let (indexed_outcome, stats) = m.match_app_indexed(AppId::EximParse, &grid, &idx, 1);

    let bw = brute_outcome.winner.map(|a| a.name()).unwrap_or("none");
    let iw = indexed_outcome.winner.map(|a| a.name()).unwrap_or("none");
    println!(
        "    winner: brute={bw} indexed={iw} -> {}",
        if bw == iw { "AGREE" } else { "DISAGREE" }
    );
    println!(
        "    correlations evaluated: brute={} indexed={}",
        brute_outcome.cells.len(),
        indexed_outcome.cells.len()
    );
    println!("    pruning: {stats}");
    println!(
        "    matcher speedup: {:.2}x (profiling dominates both; see part 2 for search-only numbers)",
        brute.mean_s / indexed.mean_s
    );
}

fn scaling() {
    println!("\n== reference-DB scaling: exact top-1, brute vs cascade ==");
    for &n in &[50usize, 500, 5000] {
        let idx = synthetic_db(n);
        let queries: Vec<Vec<f64>> = (0..5)
            .map(|qi| wave(96 + qi * 40, (qi * 7 + 3) as u64))
            .collect();

        let samples = if n >= 5000 { 3 } else { 10 };
        let b = bench(&format!("brute-force top-1   DB={n}"), 1, samples, || {
            queries.iter().map(|q| idx.brute_force(q, 1)).collect::<Vec<_>>()
        });
        let f = bench(&format!("indexed     top-1   DB={n}"), 1, samples, || {
            queries.iter().map(|q| idx.knn(q, 1)).collect::<Vec<_>>()
        });

        let mut total = SearchStats::default();
        for q in &queries {
            let (fast, stats) = idx.knn(q, 1);
            let slow = idx.brute_force(q, 1);
            assert_eq!(fast[0].index, slow[0].index, "index/brute winner mismatch");
            assert_eq!(
                fast[0].distance.to_bits(),
                slow[0].distance.to_bits(),
                "index/brute distance mismatch"
            );
            total.merge(&stats);
        }
        let started = total.dtw_fraction() * 100.0;
        let completed = if total.candidates == 0 {
            0.0
        } else {
            total.dtw_evals as f64 / total.candidates as f64 * 100.0
        };
        println!("    exact: indexed top-1 == brute-force top-1 on all {} queries", queries.len());
        println!("    pruning: {total}");
        println!(
            "    full DTW completed on {completed:.1}% of candidates (started on {started:.1}%){} — search speedup {:.2}x",
            if n == 500 {
                if completed <= 50.0 {
                    " — target <= 50% at DB=500: PASS"
                } else {
                    " — target <= 50% at DB=500: FAIL"
                }
            } else {
                ""
            },
            b.mean_s / f.mean_s
        );
    }
}

fn main() {
    mrtuner::util::logging::init();
    paper_scenario();
    scaling();
}
