//! Dynamic Time Warping and the paper's similarity measure.
//!
//! [`full`] is the exact O(N·M) algorithm of paper eqn. (1)–(2) with
//! traceback; [`banded`] adds a Sakoe–Chiba constraint; [`fastdtw`] is the
//! multiresolution approximation of the paper's reference [20]
//! (Salvador & Chan, *Toward accurate dynamic time warping in linear time
//! and space*). [`corr`] computes the correlation-coefficient similarity of
//! eqn. (3) on the DTW-aligned series.
//!
//! The traceback **choice encoding is shared with the Pallas kernel**
//! (`python/compile/kernels/dtw.py`) and with [`crate::runtime`]:
//! `0` = diagonal `(i-1,j-1)`, `1` = up `(i-1,j)`, `2` = left `(i,j-1)`;
//! ties resolve vertical-group-first, diagonal-within-group (see
//! [`full::dtw`]). `rust/tests/parity.rs` pins the two implementations.
//!
//! Every dynamic program here comes in two flavours: the seed signature
//! (allocation behaviour hidden behind a thread-local arena) and a
//! `*_with` variant taking an explicit [`scratch::DtwScratch`] so hot
//! loops — the k-NN engine, stream sessions — reuse DP buffers across
//! calls with zero steady-state heap allocations.

pub mod banded;
pub mod corr;
pub mod fastdtw;
pub mod full;
pub mod scratch;

pub use scratch::DtwScratch;

/// Traceback choice: predecessor of a DP cell.
pub const CHOICE_DIAG: u8 = 0;
pub const CHOICE_UP: u8 = 1;
pub const CHOICE_LEFT: u8 = 2;

/// Local cost: absolute difference of utilization samples (paper eqn. (2)).
#[inline]
pub fn local_cost(a: f64, b: f64) -> f64 {
    (a - b).abs()
}

/// Sakoe–Chiba band radius used by the similarity pipeline: 10% of the
/// longer series (the textbook default), floored so the slope-following
/// band always stays connected. Shared with the Pallas kernel
/// (`python/compile/kernels/dtw.py`) — keep the two formulas in sync.
pub fn band_radius(n: usize, m: usize) -> usize {
    let drift = band_slope(n, m);
    let r = (0.1 * n.max(m) as f64).max(drift + 2.0);
    r.ceil() as usize
}

/// Warping slope for unequal lengths: the band is centered on the line
/// `j = slope * i` so it always connects `(0,0)` to `(n-1,m-1)`.
pub fn band_slope(n: usize, m: usize) -> f64 {
    (m.max(2) - 1) as f64 / (n.max(2) - 1) as f64
}

/// Column range (inclusive) of row `i` inside the slope-corrected
/// Sakoe–Chiba band of radius `r` against a series of length `m`.
///
/// This is THE band geometry: [`banded::dtw_banded`], the early-abandoning
/// [`banded::dtw_banded_distance_cutoff`] and the index lower bounds
/// (`crate::index::lb`) all use it, which is what makes the pruning
/// cascade an exact filter for the banded distance.
#[inline]
pub fn band_edges(i: usize, slope: f64, r: usize, m: usize) -> (usize, usize) {
    let c = i as f64 * slope;
    let lo = (c - r as f64).floor().max(0.0) as usize;
    let hi = ((c + r as f64).ceil() as usize).min(m - 1);
    (lo, hi)
}
