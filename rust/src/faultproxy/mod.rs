//! Deterministic fault-injecting TCP proxy for chaos tests.
//!
//! A [`FaultProxy`] sits between a client (usually a
//! [`ShardRouter`](crate::coordinator::router::ShardRouter)) and one
//! upstream shard server, forwarding bytes both ways while injecting
//! scripted faults on the reply path. Faults are scheduled by *accepted
//! connection index* — the proxy counts connections as it accepts them
//! and looks each one up in its [`FaultPlan`] — so a test's fault
//! trajectory is a pure function of its connection order, not of wall
//! time. The fleet-level chaos tests in `tests/chaos.rs` drive a
//! 2-shard × 2-replica fleet through such schedules and assert on
//! outcomes (error codes, counters, merged results), never on timing.
//!
//! The proxy is test infrastructure, but it lives in the library (not
//! `tests/`) so integration tests, benches, and examples can all reuse
//! it — and so its own invariants are unit-tested.

use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One scripted fault, applied to a single proxied connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Forward bytes untouched.
    None,
    /// Accept, then close immediately: the client's first read sees EOF
    /// (the closest a userspace proxy gets to a refused connection).
    Refuse,
    /// Forward this many reply bytes, then cut both directions — a
    /// mid-line disconnect.
    DisconnectAfter(usize),
    /// Sleep this long before forwarding each reply chunk (requests pass
    /// through immediately). Models a slow, not dead, replica: every
    /// reply on the connection arrives late.
    DelayReplyMs(u64),
    /// Flip bits in every reply byte except newlines (the line framing
    /// survives; the JSON inside does not), with a per-connection mask
    /// derived from the plan seed. The client sees structured garbage —
    /// a parse/shape error, never a hang.
    Garble,
    /// Accept and swallow requests forever without replying: a stuck-open
    /// socket. The client's read timeout is the only way out.
    StuckOpen,
}

/// A deterministic fault schedule: per-connection-index faults over a
/// default, plus the seed the byte-garbler draws its masks from.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    default: Fault,
    schedule: BTreeMap<usize, Fault>,
    seed: u64,
}

impl FaultPlan {
    /// A plan that injects nothing (pure pass-through proxy).
    pub fn healthy() -> FaultPlan {
        FaultPlan::new(0)
    }

    /// An empty plan (default [`Fault::None`]) with the given seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            default: Fault::None,
            schedule: BTreeMap::new(),
            seed,
        }
    }

    /// Set the fault applied to connections with no scheduled entry.
    pub fn with_default(mut self, fault: Fault) -> FaultPlan {
        self.default = fault;
        self
    }

    /// Schedule a fault for the `index`-th accepted connection
    /// (0-based).
    pub fn on_connection(mut self, index: usize, fault: Fault) -> FaultPlan {
        self.schedule.insert(index, fault);
        self
    }

    fn fault_for(&self, index: usize) -> Fault {
        self.schedule.get(&index).copied().unwrap_or(self.default)
    }

    /// The garble mask for one connection: seeded, per-connection, never
    /// zero (a zero mask would garble nothing) and never flipping the
    /// newline bit pattern itself.
    fn garble_mask(&self, index: usize) -> u8 {
        let mut rng = Rng::new(self.seed ^ (index as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        // Always flip bit 5 so ASCII structure characters change class;
        // mix in seeded low bits for variety across connections.
        0x20 | (rng.next_u64() as u8 & 0x1f) | 0x01
    }
}

/// A fault-injecting TCP proxy in front of one upstream address. See the
/// module docs; constructed with [`FaultProxy::spawn`], stopped on drop.
pub struct FaultProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    plan: Arc<Mutex<FaultPlan>>,
    accepted: Arc<AtomicUsize>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<JoinHandle<()>>,
}

impl FaultProxy {
    /// Bind an ephemeral local port and start proxying to `upstream`
    /// under `plan`.
    pub fn spawn(upstream: &str, plan: FaultPlan) -> std::io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let plan = Arc::new(Mutex::new(plan));
        let accepted = Arc::new(AtomicUsize::new(0));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let upstream = upstream.to_string();
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let plan = Arc::clone(&plan);
            let accepted = Arc::clone(&accepted);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(client) = conn else { continue };
                    let index = accepted.fetch_add(1, Ordering::SeqCst);
                    let (fault, mask) = {
                        let p = plan.lock().unwrap_or_else(|e| e.into_inner());
                        (p.fault_for(index), p.garble_mask(index))
                    };
                    register(&conns, &client);
                    let upstream = upstream.clone();
                    let conns = Arc::clone(&conns);
                    let stop = Arc::clone(&stop);
                    std::thread::spawn(move || {
                        run_connection(client, &upstream, fault, mask, &conns, &stop);
                    });
                }
            })
        };
        Ok(FaultProxy {
            addr,
            stop,
            plan,
            accepted,
            conns,
            accept_thread: Some(accept_thread),
        })
    }

    /// The proxy's listening address (point clients/routers here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections accepted so far (the next connection gets this
    /// index).
    pub fn accepted(&self) -> usize {
        self.accepted.load(Ordering::SeqCst)
    }

    /// Replace the plan's default fault at runtime (scheduled
    /// per-connection entries keep winning). Affects connections accepted
    /// after the call.
    pub fn set_fault(&self, fault: Fault) {
        self.plan.lock().unwrap_or_else(|e| e.into_inner()).default = fault;
    }

    /// Hard-kill every live proxied connection (both directions). The
    /// scripted way to "crash" a replica mid-conversation without
    /// touching the upstream process.
    pub fn kill_connections(&self) {
        let mut conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        for c in conns.drain(..) {
            let _ = c.shutdown(Shutdown::Both);
        }
    }

    /// Stop accepting, kill live connections, and join the accept loop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Self-connect once to unblock the blocking accept.
        let _ = TcpStream::connect(self.addr);
        self.kill_connections();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Track a connection's streams for [`FaultProxy::kill_connections`].
fn register(conns: &Mutex<Vec<TcpStream>>, stream: &TcpStream) {
    if let Ok(clone) = stream.try_clone() {
        conns.lock().unwrap_or_else(|e| e.into_inner()).push(clone);
    }
}

/// Serve one proxied connection under its scripted fault.
fn run_connection(
    client: TcpStream,
    upstream: &str,
    fault: Fault,
    mask: u8,
    conns: &Mutex<Vec<TcpStream>>,
    stop: &AtomicBool,
) {
    match fault {
        Fault::Refuse => {
            let _ = client.shutdown(Shutdown::Both);
        }
        Fault::StuckOpen => {
            // Swallow requests, never answer. Bounded reads so the
            // thread notices stop/kill instead of blocking forever.
            let mut client = client;
            let _ = client.set_read_timeout(Some(Duration::from_millis(50)));
            let mut buf = [0u8; 4096];
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match client.read(&mut buf) {
                    Ok(0) => break,
                    Ok(_) => {}
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut => {}
                    Err(_) => break,
                }
            }
            let _ = client.shutdown(Shutdown::Both);
        }
        _ => {
            let Ok(server) = TcpStream::connect(upstream) else {
                let _ = client.shutdown(Shutdown::Both);
                return;
            };
            register(conns, &server);
            let (Ok(client_r), Ok(server_r)) = (client.try_clone(), server.try_clone()) else {
                return;
            };
            // Request direction: always a clean copy.
            let up = std::thread::spawn(move || pump(client_r, server, Fault::None, 0));
            // Reply direction: where the fault bites.
            pump(server_r, client, fault, mask);
            let _ = up.join();
        }
    }
}

/// Copy bytes `from` → `to`, applying the reply-path fault. Any error or
/// EOF tears down both directions.
fn pump(mut from: TcpStream, mut to: TcpStream, fault: Fault, mask: u8) {
    let mut buf = [0u8; 65536];
    let mut forwarded = 0usize;
    loop {
        let n = match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        let chunk = &mut buf[..n];
        match fault {
            Fault::DelayReplyMs(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            Fault::Garble => {
                for b in chunk.iter_mut() {
                    if *b != b'\n' {
                        *b ^= mask;
                        // A garbled byte must never fabricate framing.
                        if *b == b'\n' {
                            *b ^= 0x01;
                        }
                    }
                }
            }
            Fault::DisconnectAfter(limit) => {
                if forwarded + n >= limit {
                    let keep = limit.saturating_sub(forwarded);
                    let _ = to.write_all(&chunk[..keep]);
                    break;
                }
            }
            _ => {}
        }
        forwarded += n;
        if to.write_all(chunk).is_err() {
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    /// A tiny upstream: echoes each line back, uppercased marker added.
    fn spawn_echo() -> (SocketAddr, Arc<AtomicBool>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut line = String::new();
                    while let Ok(n) = reader.read_line(&mut line) {
                        if n == 0 {
                            break;
                        }
                        let reply = format!("echo:{}", line.trim_end());
                        let mut w = stream.try_clone().unwrap();
                        if w.write_all(reply.as_bytes()).is_err()
                            || w.write_all(b"\n").is_err()
                        {
                            break;
                        }
                        line.clear();
                    }
                });
            }
        });
        (addr, stop)
    }

    fn roundtrip(addr: SocketAddr, line: &str) -> std::io::Result<String> {
        let mut s = TcpStream::connect(addr)?;
        s.set_read_timeout(Some(Duration::from_secs(5)))?;
        s.write_all(line.as_bytes())?;
        s.write_all(b"\n")?;
        let mut reader = BufReader::new(s);
        let mut reply = String::new();
        let n = reader.read_line(&mut reply)?;
        // A line is only a reply once its newline arrives (same framing
        // rule as the real protocol): EOF mid-line is a dead connection,
        // not a short answer.
        if n == 0 || !reply.ends_with('\n') {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "closed",
            ));
        }
        Ok(reply.trim_end().to_string())
    }

    #[test]
    fn healthy_proxy_is_transparent() {
        let (up, stop) = spawn_echo();
        let proxy = FaultProxy::spawn(&up.to_string(), FaultPlan::healthy()).unwrap();
        assert_eq!(roundtrip(proxy.addr(), "hello").unwrap(), "echo:hello");
        assert_eq!(proxy.accepted(), 1);
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }

    #[test]
    fn refuse_closes_without_answering() {
        let (up, stop) = spawn_echo();
        let plan = FaultPlan::new(7).with_default(Fault::Refuse);
        let proxy = FaultProxy::spawn(&up.to_string(), plan).unwrap();
        let err = roundtrip(proxy.addr(), "hello").unwrap_err();
        // EOF or reset depending on write/read interleaving — an error
        // either way, never a reply.
        let _ = err;
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }

    #[test]
    fn garble_breaks_payload_but_keeps_framing() {
        let (up, stop) = spawn_echo();
        let plan = FaultPlan::new(42).with_default(Fault::Garble);
        let proxy = FaultProxy::spawn(&up.to_string(), plan).unwrap();
        let got = roundtrip(proxy.addr(), "hello").unwrap();
        // One whole line arrives (framing preserved), contents mangled.
        assert_ne!(got, "echo:hello");
        assert!(!got.is_empty());
        // Deterministic: the same plan garbles the same way. Connection
        // index differs (1 vs 0), so only assert self-consistency via a
        // fresh proxy at index 0 again.
        let proxy2 = FaultProxy::spawn(&up.to_string(), FaultPlan::new(42).with_default(Fault::Garble)).unwrap();
        let got2 = roundtrip(proxy2.addr(), "hello").unwrap();
        assert_eq!(got, got2, "same seed + same connection index = same bytes");
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }

    #[test]
    fn disconnect_after_cuts_mid_line() {
        let (up, stop) = spawn_echo();
        let plan = FaultPlan::new(1).with_default(Fault::DisconnectAfter(3));
        let proxy = FaultProxy::spawn(&up.to_string(), plan).unwrap();
        let err = roundtrip(proxy.addr(), "hello").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "{err}");
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }

    #[test]
    fn stuck_open_never_replies_and_read_times_out() {
        let (up, stop) = spawn_echo();
        let plan = FaultPlan::new(1).with_default(Fault::StuckOpen);
        let mut proxy = FaultProxy::spawn(&up.to_string(), plan).unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        s.write_all(b"hello\n").unwrap();
        let mut buf = [0u8; 16];
        let err = s.read(&mut buf).unwrap_err();
        assert!(
            err.kind() == std::io::ErrorKind::WouldBlock
                || err.kind() == std::io::ErrorKind::TimedOut,
            "{err}"
        );
        proxy.stop();
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }

    #[test]
    fn scheduled_connection_wins_over_default() {
        let (up, stop) = spawn_echo();
        let plan = FaultPlan::new(5)
            .with_default(Fault::None)
            .on_connection(1, Fault::Refuse);
        let proxy = FaultProxy::spawn(&up.to_string(), plan).unwrap();
        assert_eq!(roundtrip(proxy.addr(), "a").unwrap(), "echo:a");
        assert!(roundtrip(proxy.addr(), "b").is_err());
        assert_eq!(roundtrip(proxy.addr(), "c").unwrap(), "echo:c");
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }

    #[test]
    fn kill_connections_severs_live_streams() {
        let (up, stop) = spawn_echo();
        let proxy = FaultProxy::spawn(&up.to_string(), FaultPlan::healthy()).unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(b"one\n").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "echo:one");
        proxy.kill_connections();
        // The severed socket yields EOF (or an error), never a reply.
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {}
            Ok(_) => panic!("reply after kill: {line:?}"),
            Err(_) => {}
        }
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(up);
    }
}
