//! Minimal JSON value model, serializer and parser.
//!
//! Used for reference-database persistence, the AOT artifact manifest
//! (`artifacts/manifest.json`), experiment CSV/JSON emission and the TCP
//! service protocol. Implements RFC 8259 minus `\u` surrogate-pair edge
//! cases beyond the BMP (sufficient: all our payloads are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (stable golden files in tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from values.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Array of f64 values.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array of f32 values (stored as f64).
    pub fn nums_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Decode an array of numbers into `Vec<f32>`.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        const PAD: &str = "  ";
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    for _ in 0..=depth {
                        out.push_str(PAD);
                    }
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push_str(PAD);
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    for _ in 0..=depth {
                        out.push_str(PAD);
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push_str(PAD);
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document. Errors carry the byte offset. Nesting is
    /// bounded at [`MAX_PARSE_DEPTH`] so hostile input (e.g. a request
    /// line of 100k `[`s) is a parse error, never a recursion blow-up.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no inf/nan; persist as null (round-trips to Num? no —
        // callers must not persist non-finite values; guarded in tests).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x:e}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

/// Maximum container nesting accepted by [`Json::parse`]. The parser is
/// recursive-descent, so unbounded depth is unbounded stack; every sane
/// payload of ours is < 10 levels deep.
pub const MAX_PARSE_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let width = match b {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    if start + width > self.bytes.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + width])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = start + width;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_PARSE_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "src={src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "[1] x"] {
            assert!(Json::parse(src).is_err(), "src={src}");
        }
    }

    #[test]
    fn nesting_is_bounded_not_a_stack_overflow() {
        // Hostile depth: must be a clean parse error however deep.
        for deep in ["[".repeat(100_000), "{\"a\":".repeat(50_000)] {
            let err = Json::parse(&deep).unwrap_err();
            assert!(err.msg.contains("nesting too deep"), "{err}");
        }
        // Sane nesting (well inside the bound) still parses, and the depth
        // counter unwinds correctly across siblings.
        let mut src = String::new();
        for _ in 0..MAX_PARSE_DEPTH / 2 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..MAX_PARSE_DEPTH / 2 {
            src.push(']');
        }
        assert!(Json::parse(&src).is_ok());
        let siblings = format!("[{}]", vec![src; 4].join(","));
        assert!(Json::parse(&siblings).is_ok(), "siblings must not accumulate depth");
    }

    #[test]
    fn float_roundtrip_precision() {
        let xs = [0.1, -2.5e-8, 123456.789, 1.0 / 3.0];
        let v = Json::nums(&xs);
        let back = Json::parse(&v.to_string()).unwrap();
        let ys: Vec<f64> = back.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn integers_serialize_without_exponent() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-7.0).to_string(), "-7");
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("series", Json::nums(&[0.5, 1.0])),
            ("app", Json::Str("wordcount".into())),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn deterministic_key_order() {
        let a = Json::obj(vec![("b", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(a.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs = vec![0.25f32, -1.5, 3.75];
        let v = Json::nums_f32(&xs);
        let back = Json::parse(&v.to_string()).unwrap().to_f32_vec().unwrap();
        assert_eq!(xs, back);
    }
}
