//! Anytime banded-DTW: the exact dynamic program over only the rows
//! observed so far.
//!
//! [`prefix_dtw`] runs the same recurrence as
//! [`crate::dtw::banded::dtw_banded_distance_cutoff`] — same band
//! geometry, same value-selection order — but stops after the prefix's
//! rows and reports the minimum of the last computed row. That minimum is
//! the cost of the cheapest band-legal partial path covering every
//! observed row, so (for a fixed normalization of the prefix) it lower
//! bounds the full distance and is monotone in the number of rows. When
//! the prefix *is* the whole query it degenerates to the exact banded
//! distance, bit-identical to `dtw_banded`.
//!
//! Unlike the envelope bound in [`super::prefix_lb`], the DP must be
//! re-run from row 0 whenever online normalization re-scales the prefix,
//! so sessions reserve it for the few lowest-bound finalists per batch
//! (with early abandoning against the best so far).

use crate::dtw::scratch::{with_thread_scratch, DtwScratch};
use crate::dtw::{band_edges, band_radius, band_slope, local_cost};

/// Result of one prefix DP.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixDp {
    /// Minimum over the last observed row — the anytime distance.
    pub row_min: f64,
    /// Exact banded distance (the corner cell), present only when the
    /// prefix spans the whole assumed final length.
    pub exact: Option<f64>,
}

/// Banded-DTW DP over the first `qp.len()` rows of the final
/// `(n_final × y.len())` alignment, abandoning as soon as every cell of
/// some row exceeds `cutoff` (returns `None`; no completion below the row
/// minimum is possible). `n_final < qp.len()` self-corrects to
/// `qp.len()`.
pub fn prefix_dtw(qp: &[f64], y: &[f64], n_final: usize, cutoff: f64) -> Option<PrefixDp> {
    with_thread_scratch(|scratch| prefix_dtw_with(scratch, qp, y, n_final, cutoff))
}

/// [`prefix_dtw`] with caller-provided scratch buffers (bit-identical) —
/// sessions hold one arena and refresh every finalist through it without
/// re-allocating DP rows each batch.
pub fn prefix_dtw_with(
    scratch: &mut DtwScratch,
    qp: &[f64],
    y: &[f64],
    n_final: usize,
    cutoff: f64,
) -> Option<PrefixDp> {
    let m = y.len();
    assert!(!qp.is_empty() && m > 0, "prefix_dtw: empty series");
    let mut prev = scratch.row(m, f64::INFINITY);
    let mut cur = scratch.row(m, f64::INFINITY);
    let out = prefix_dp(qp, y, n_final, cutoff, &mut prev, &mut cur);
    scratch.put_row(prev);
    scratch.put_row(cur);
    out
}

/// The prefix DP over caller-provided rows (both pre-filled with `+inf`);
/// split out so every early abandon still recycles the rows.
fn prefix_dp(
    qp: &[f64],
    y: &[f64],
    n_final: usize,
    cutoff: f64,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> Option<PrefixDp> {
    let (p, m) = (qp.len(), y.len());
    let n = n_final.max(p);
    let slope = band_slope(n, m);
    let r = band_radius(n, m);
    let inf = f64::INFINITY;

    let (lo0, hi0) = band_edges(0, slope, r, m);
    debug_assert_eq!(lo0, 0);
    cur[0] = local_cost(qp[0], y[0]);
    let mut row_min = cur[0];
    for j in lo0.max(1)..=hi0 {
        cur[j] = cur[j - 1] + local_cost(qp[0], y[j]);
        row_min = row_min.min(cur[j]);
    }
    if row_min > cutoff {
        return None;
    }
    std::mem::swap(prev, cur);
    let mut last_row_min = row_min;

    for i in 1..p {
        let (lo, hi) = band_edges(i, slope, r, m);
        cur.iter_mut().for_each(|v| *v = inf);
        let mut row_min = inf;
        for j in lo..=hi {
            let d = local_cost(qp[i], y[j]);
            let diag = if j > 0 { prev[j - 1] } else { inf };
            let up = prev[j];
            let left = if j > lo { cur[j - 1] } else { inf };
            // Same value selection as dtw_banded (vertical group then left).
            let vg = if diag <= up { diag } else { up };
            let best = if left < vg { left } else { vg };
            cur[j] = best + d;
            row_min = row_min.min(cur[j]);
        }
        if row_min > cutoff {
            return None;
        }
        std::mem::swap(prev, cur);
        last_row_min = row_min;
    }

    Some(PrefixDp {
        row_min: last_row_min,
        exact: if p == n { Some(prev[m - 1]) } else { None },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtw::banded::dtw_banded;
    use crate::util::rng::Pcg32;

    fn series(g: &mut Pcg32, len: usize) -> Vec<f64> {
        let mut v = 0.5;
        (0..len)
            .map(|_| {
                v = (v + (g.f64() - 0.5) * 0.25).clamp(0.0, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn full_prefix_is_bit_identical_to_banded() {
        let mut g = Pcg32::new(150, 1);
        for _ in 0..20 {
            let n = 4 + g.below(120) as usize;
            let m = 4 + g.below(120) as usize;
            let x = series(&mut g, n);
            let y = series(&mut g, m);
            let exact = dtw_banded(&x, &y, band_radius(n, m)).distance;
            let dp = prefix_dtw(&x, &y, n, f64::INFINITY).expect("no cutoff");
            assert_eq!(dp.exact.unwrap().to_bits(), exact.to_bits());
        }
    }

    #[test]
    fn row_min_is_monotone_and_bounds_the_final_distance() {
        let mut g = Pcg32::new(151, 2);
        for _ in 0..10 {
            let n = 20 + g.below(100) as usize;
            let m = 20 + g.below(100) as usize;
            let x = series(&mut g, n);
            let y = series(&mut g, m);
            let exact = dtw_banded(&x, &y, band_radius(n, m)).distance;
            let mut last = 0.0;
            for p in 1..=n {
                let dp = prefix_dtw(&x[..p], &y, n, f64::INFINITY).unwrap();
                assert!(dp.row_min >= last - 1e-12, "row_min fell at p={p}");
                assert!(dp.row_min <= exact + 1e-9, "row_min {p}: {} > {exact}", dp.row_min);
                last = dp.row_min;
                assert_eq!(dp.exact.is_some(), p == n);
            }
        }
    }

    #[test]
    fn cutoff_abandons_far_pairs() {
        let x = vec![0.0; 100];
        let y = vec![1.0; 100];
        assert!(prefix_dtw(&x[..40], &y, 100, 1.0).is_none());
        assert!(prefix_dtw(&x[..40], &y, 100, f64::INFINITY).is_some());
    }
}
