//! [`FlightRecorder`]: an always-on black box for the serving path — a
//! fixed-capacity ring buffer of the most recently *finished* spans, at
//! bounded memory, dumped on demand (the `trace_dump` wire command) or on
//! read-loop error.
//!
//! Finished spans are stored pre-rendered in the same Chrome
//! `trace_event` shape as [`super::ChromeTracker`], so
//! [`FlightRecorder::dump`] is a snapshot that loads directly in
//! `chrome://tracing` / <https://ui.perfetto.dev>. When the ring is full
//! the oldest span is evicted and counted in
//! [`FlightRecorder::dropped`] — the recorder never grows and never
//! blocks the hot path on anything but a short mutex.
//!
//! Memory bound: the ring holds at most `capacity` finished spans; the
//! open-span table only ever holds spans that are currently live, which
//! the serving layers bound by construction (one tree per in-flight
//! request, one long-lived span per streaming session).

use super::{SpanId, Tracker};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default ring capacity: enough for the last few hundred requests'
/// trees without mattering next to the index itself.
pub const DEFAULT_CAPACITY: usize = 4096;

struct Open {
    name: &'static str,
    parent: SpanId,
    remote_parent: SpanId,
    start_ns: u64,
    /// Track id: the id of this span's local root (Chrome renders one
    /// row per tid).
    tid: u64,
    args: Vec<(String, Json)>,
}

#[derive(Default)]
struct Inner {
    open: HashMap<SpanId, Open>,
    ring: VecDeque<Json>,
}

/// Bounded last-N-spans sink; see the module docs.
pub struct FlightRecorder {
    capacity: usize,
    next: AtomicU64,
    inner: Mutex<Inner>,
    dropped: AtomicU64,
    dumps: AtomicU64,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new(DEFAULT_CAPACITY)
    }
}

impl FlightRecorder {
    /// A recorder keeping the last `capacity` finished spans
    /// (`capacity == 0` is clamped to 1).
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            capacity: capacity.max(1),
            next: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
            dropped: AtomicU64::new(0),
            dumps: AtomicU64::new(0),
        }
    }

    /// Configured ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Finished spans currently held.
    pub fn len(&self) -> usize {
        self.guard().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans evicted from the ring to make room for newer ones.
    pub fn dropped(&self) -> u64 {
        // relaxed: independent monotone counter.
        self.dropped.load(Ordering::Relaxed)
    }

    /// Snapshots taken ([`FlightRecorder::dump`] calls).
    pub fn dumps(&self) -> u64 {
        // relaxed: independent monotone counter.
        self.dumps.load(Ordering::Relaxed)
    }

    /// Snapshot the ring as a Chrome-loadable trace document (oldest
    /// first). Recording continues; the ring is not cleared.
    pub fn dump(&self) -> Json {
        // relaxed: independent monotone counter.
        self.dumps.fetch_add(1, Ordering::Relaxed);
        let inner = self.guard();
        Json::obj(vec![
            ("displayTimeUnit", Json::Str("ms".to_string())),
            ("traceEvents", Json::arr(inner.ring.iter().cloned().collect())),
        ])
    }

    /// Write a [`FlightRecorder::dump`] snapshot to `path`
    /// (pretty-printed; open in a trace viewer).
    pub fn write_to(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.dump().to_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Periodic dump rotation for a [`FlightRecorder`]: logrotate-style
/// numbered snapshots (`base.0`, `base.1`, ...) written whenever the
/// configured interval has elapsed — or early, when the ring has started
/// evicting spans since the last snapshot (time *or* size triggered), so
/// a burst that overruns the ring still lands on disk before it is gone.
///
/// The rotator is clock-free like the trackers: callers drive
/// [`FlightRotator::tick`] from any periodic loop and pass timestamps
/// from the injected [`Clock`](super::Clock), so tests rotate under a
/// [`VirtualClock`](super::VirtualClock) without sleeping. The first tick
/// only anchors the interval; at most `keep` rotated files are retained
/// (older ones are pruned as new snapshots land).
#[derive(Debug)]
pub struct FlightRotator {
    recorder: Arc<FlightRecorder>,
    base: PathBuf,
    every_ns: u64,
    keep: u64,
    last_ns: Option<u64>,
    dropped_mark: u64,
    seq: u64,
}

impl FlightRotator {
    /// A rotator writing `recorder` snapshots next to `base` every
    /// `every_ns` nanoseconds, keeping the `keep` most recent files
    /// (`every_ns` and `keep` are clamped to at least 1).
    pub fn new(
        recorder: Arc<FlightRecorder>,
        base: impl Into<PathBuf>,
        every_ns: u64,
        keep: u64,
    ) -> FlightRotator {
        FlightRotator {
            recorder,
            base: base.into(),
            every_ns: every_ns.max(1),
            keep: keep.max(1),
            last_ns: None,
            dropped_mark: 0,
            seq: 0,
        }
    }

    /// Snapshots written so far.
    pub fn rotations(&self) -> u64 {
        self.seq
    }

    fn path_for(&self, seq: u64) -> PathBuf {
        let mut name = self.base.clone().into_os_string();
        name.push(format!(".{seq}"));
        PathBuf::from(name)
    }

    /// Drive the rotator: called periodically with the current clock
    /// reading. Returns the path written when a rotation happened. A
    /// failed write is logged and the interval still advances, so a bad
    /// path degrades to a warning per interval, not a hot loop.
    pub fn tick(&mut self, now_ns: u64) -> Option<PathBuf> {
        let Some(last) = self.last_ns else {
            self.last_ns = Some(now_ns);
            self.dropped_mark = self.recorder.dropped();
            return None;
        };
        let due_time = now_ns.saturating_sub(last) >= self.every_ns;
        let due_size = self.recorder.dropped() > self.dropped_mark;
        if !due_time && !due_size {
            return None;
        }
        let path = self.path_for(self.seq);
        let wrote = match self.recorder.write_to(&path) {
            Ok(()) => Some(path),
            Err(e) => {
                log::warn!("flight rotation failed: {e:#}");
                None
            }
        };
        self.last_ns = Some(now_ns);
        self.dropped_mark = self.recorder.dropped();
        self.seq += 1;
        if self.seq > self.keep {
            std::fs::remove_file(self.path_for(self.seq - self.keep - 1)).ok();
        }
        wrote
    }
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("capacity", &self.capacity)
            .field("held", &self.len())
            .finish()
    }
}

impl Tracker for FlightRecorder {
    fn is_enabled(&self) -> bool {
        true
    }

    fn begin(
        &self,
        name: &'static str,
        parent: SpanId,
        remote_parent: SpanId,
        now_ns: u64,
    ) -> SpanId {
        // relaxed: monotone id counter — uniqueness is all that matters.
        let id = self.next.fetch_add(1, Ordering::Relaxed) + 1;
        let mut inner = self.guard();
        let tid = inner.open.get(&parent).map(|p| p.tid).unwrap_or(id);
        inner.open.insert(
            id,
            Open { name, parent, remote_parent, start_ns: now_ns, tid, args: Vec::new() },
        );
        id
    }

    fn end(&self, span: SpanId, now_ns: u64) {
        let mut inner = self.guard();
        let Some(s) = inner.open.remove(&span) else {
            return;
        };
        let mut args = vec![
            ("span".to_string(), Json::Num(span as f64)),
            ("parent".to_string(), Json::Num(s.parent as f64)),
        ];
        if s.remote_parent != 0 {
            args.push(("remote_parent".to_string(), Json::Num(s.remote_parent as f64)));
        }
        args.extend(s.args);
        let args_obj = Json::obj(args.iter().map(|(k, v)| (k.as_str(), v.clone())).collect());
        let event = Json::obj(vec![
            ("name", Json::Str(s.name.to_string())),
            ("cat", Json::Str("mrtuner".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("ts", Json::Num(s.start_ns as f64 / 1e3)),
            ("dur", Json::Num(now_ns.saturating_sub(s.start_ns) as f64 / 1e3)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(s.tid as f64)),
            ("args", args_obj),
        ]);
        if inner.ring.len() == self.capacity {
            inner.ring.pop_front();
            // relaxed: independent monotone counter.
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        inner.ring.push_back(event);
    }

    fn event(&self, span: SpanId, name: &'static str, value: u64, _now_ns: u64) {
        let mut inner = self.guard();
        if let Some(s) = inner.open.get_mut(&span) {
            s.args.push((name.to_string(), Json::Num(value as f64)));
        }
    }

    fn note(&self, span: SpanId, key: &'static str, text: &str, _now_ns: u64) {
        let mut inner = self.guard();
        if let Some(s) = inner.open.get_mut(&span) {
            s.args.push((key.to_string(), Json::Str(text.to_string())));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_only_the_most_recent_spans_at_bounded_memory() {
        let r = FlightRecorder::new(3);
        for i in 0..10u64 {
            let id = r.begin("request", 0, 0, i * 100);
            r.event(id, "seq", i, i * 100 + 1);
            r.end(id, i * 100 + 50);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 7);
        let doc = r.dump();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let seqs: Vec<u64> = events
            .iter()
            .map(|e| e.get("args").and_then(|a| a.get("seq")).and_then(Json::as_u64).unwrap())
            .collect();
        assert_eq!(seqs, vec![7, 8, 9], "oldest evicted first, order preserved");
        assert_eq!(r.dumps(), 1);
    }

    #[test]
    fn dump_is_chrome_shaped_and_nonconsuming() {
        let r = FlightRecorder::new(8);
        let root = r.begin("request", 0, 41, 2_000);
        let child = r.begin("handle", root, 0, 3_000);
        r.note(child, "type", "knn", 3_100);
        r.end(child, 5_000);
        r.end(root, 6_000);

        let doc = r.dump();
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        // Child finished first; inherits the root's track id.
        let handle = &events[0];
        assert_eq!(handle.get("name").and_then(Json::as_str), Some("handle"));
        assert_eq!(handle.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(handle.get("ts").and_then(Json::as_f64), Some(3.0));
        assert_eq!(handle.get("dur").and_then(Json::as_f64), Some(2.0));
        let request = &events[1];
        assert_eq!(
            handle.get("tid").and_then(Json::as_f64),
            request.get("tid").and_then(Json::as_f64)
        );
        assert_eq!(
            handle.get("args").and_then(|a| a.get("type")).and_then(Json::as_str),
            Some("knn")
        );
        assert_eq!(
            request.get("args").and_then(|a| a.get("remote_parent")).and_then(Json::as_f64),
            Some(41.0)
        );
        // A second dump sees the same spans (snapshot, not drain).
        assert_eq!(
            r.dump().get("traceEvents").and_then(Json::as_arr).map(Vec::len),
            Some(2)
        );
        assert_eq!(r.dumps(), 2);
    }

    #[test]
    fn rotator_writes_on_the_virtual_interval_and_prunes_old_files() {
        use crate::trace::{Clock, VirtualClock};
        let dir = std::env::temp_dir().join("mrtuner_flight_rotator_interval");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let base = dir.join("flight.json");
        let r = Arc::new(FlightRecorder::new(8));
        let clock = VirtualClock::new(1);
        let mut rot = FlightRotator::new(Arc::clone(&r), &base, 1_000, 2);

        assert!(rot.tick(clock.now_ns()).is_none(), "first tick only anchors");
        let id = r.begin("request", 0, 0, 0);
        r.end(id, 50);
        assert!(rot.tick(clock.now_ns()).is_none(), "interval not yet elapsed");

        clock.advance(2_000);
        let p0 = rot.tick(clock.now_ns()).expect("interval elapsed");
        assert!(p0.to_string_lossy().ends_with("flight.json.0"), "{}", p0.display());
        let doc = Json::parse(&std::fs::read_to_string(&p0).expect("read")).expect("json");
        assert_eq!(doc.get("traceEvents").and_then(Json::as_arr).map(Vec::len), Some(1));

        assert!(rot.tick(clock.now_ns()).is_none(), "fresh interval, nothing due");
        clock.advance(2_000);
        let p1 = rot.tick(clock.now_ns()).expect("second rotation");
        clock.advance(2_000);
        let p2 = rot.tick(clock.now_ns()).expect("third rotation");
        assert_eq!(rot.rotations(), 3);
        assert!(!p0.exists(), "oldest snapshot pruned past keep=2");
        assert!(p1.exists() && p2.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotator_rotates_early_when_the_ring_starts_dropping() {
        use crate::trace::{Clock, VirtualClock};
        let dir = std::env::temp_dir().join("mrtuner_flight_rotator_pressure");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let r = Arc::new(FlightRecorder::new(1));
        let clock = VirtualClock::new(1);
        let mut rot =
            FlightRotator::new(Arc::clone(&r), dir.join("flight.json"), u64::MAX, 2);
        assert!(rot.tick(clock.now_ns()).is_none());

        // Two finished spans through a 1-slot ring: one eviction.
        for i in 0..2u64 {
            let id = r.begin("request", 0, 0, i * 10);
            r.end(id, i * 10 + 5);
        }
        assert_eq!(r.dropped(), 1);
        let p = rot.tick(clock.now_ns()).expect("size trigger fires before the interval");
        assert!(p.exists());
        assert!(rot.tick(clock.now_ns()).is_none(), "no further drops, no further writes");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writes_a_parseable_file() {
        let r = FlightRecorder::new(4);
        let id = r.begin("request", 0, 0, 0);
        r.end(id, 1_000);
        let path = std::env::temp_dir().join("mrtuner_flight_recorder_test.json");
        r.write_to(&path).expect("write dump");
        let text = std::fs::read_to_string(&path).expect("read back");
        let doc = Json::parse(&text).expect("valid json");
        assert!(doc.get("traceEvents").and_then(Json::as_arr).is_some());
        std::fs::remove_file(&path).ok();
    }
}
