"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is written with plain ``jax.numpy`` / ``lax.scan`` semantics
(no Pallas) so pytest can compare the kernels against an independent
implementation. The traceback choice encoding matches
``rust/src/dtw/mod.rs``: 0 = diagonal, 1 = up, 2 = left; ties resolve
vertical-group-first, diagonal-within-group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BIG = jnp.float32(1e30)

CHOICE_DIAG = 0
CHOICE_UP = 1
CHOICE_LEFT = 2


def dtw_reference(x, y, nx, ny):
    """Naive masked DTW over padded series.

    Args:
      x: f32[L] query (only ``x[:nx]`` is meaningful).
      y: f32[L] reference (only ``y[:ny]`` is meaningful).
      nx, ny: actual lengths (python ints or traced scalars).

    Returns:
      ``(dist, choices)`` — terminal distance ``D[nx-1, ny-1]`` and the full
      s8[L, L] traceback matrix (garbage outside the valid region).
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    L = x.shape[0]
    jj = jnp.arange(L)
    nxf = jnp.float32(nx)
    nyf = jnp.float32(ny)
    drift = (jnp.maximum(nyf, 2.0) - 1.0) / (jnp.maximum(nxf, 2.0) - 1.0)
    radius = jnp.ceil(jnp.maximum(0.1 * jnp.maximum(nxf, nyf), drift + 2.0))

    def row(carry, i):
        prev = carry  # D[i-1, :]
        centre = i.astype(jnp.float32) * drift
        in_band = (jj.astype(jnp.float32) >= jnp.floor(centre - radius)) & (
            jj.astype(jnp.float32) <= jnp.ceil(centre + radius)
        )
        d = jnp.abs(x[i] - y)
        d = jnp.where((jj < ny) & in_band & (i < nx), d, BIG)
        boundary = jnp.where(i == 0, jnp.float32(0.0), BIG)
        diag = jnp.concatenate([boundary[None], prev[:-1]])
        up = prev
        vg = jnp.minimum(diag, up)
        vchoice = jnp.where(diag <= up, CHOICE_DIAG, CHOICE_UP).astype(jnp.int8)

        # Sequential in-row recurrence: D_j = d_j + min(vg_j, D_{j-1}).
        def cell(c, inputs):
            dj, vgj = inputs
            best = jnp.minimum(vgj, c)
            return dj + best, dj + best

        _, drow = jax.lax.scan(cell, BIG, (d, vg))
        dshift = jnp.concatenate([BIG[None], drow[:-1]])
        choices = jnp.where(dshift < vg, jnp.int8(CHOICE_LEFT), vchoice)
        return drow, (drow, choices)

    init = jnp.full((L,), BIG, jnp.float32)
    _, (rows, choices) = jax.lax.scan(row, init, jnp.arange(L))
    dist = rows[nx - 1, ny - 1]
    return dist, choices


def dtw_distance_numpy(x, y):
    """Classic O(N*M) float64 DTW distance on exact-length numpy arrays —
    the most independent oracle (no masking, no padding)."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    n, m = len(x), len(y)
    D = np.full((n, m), np.inf)
    D[0, 0] = abs(x[0] - y[0])
    for j in range(1, m):
        D[0, j] = D[0, j - 1] + abs(x[0] - y[j])
    for i in range(1, n):
        D[i, 0] = D[i - 1, 0] + abs(x[i] - y[0])
        for j in range(1, m):
            D[i, j] = abs(x[i] - y[j]) + min(D[i - 1, j], D[i, j - 1], D[i - 1, j - 1])
    return D[n - 1, m - 1]


def backtrack_numpy(choices, nx, ny):
    """Walk a choice matrix back from (nx-1, ny-1); mirrors the Rust
    ``dtw::full::backtrack``."""
    i, j = nx - 1, ny - 1
    path = [(i, j)]
    while (i, j) != (0, 0):
        if i == 0:
            j -= 1
        elif j == 0:
            i -= 1
        else:
            c = int(choices[i, j])
            if c == CHOICE_DIAG:
                i, j = i - 1, j - 1
            elif c == CHOICE_UP:
                i -= 1
            else:
                j -= 1
        path.append((i, j))
    path.reverse()
    return path


def sosfilt_reference(sos, x):
    """lax.scan direct-form-II-transposed cascade (f32), matching
    ``filters.sosfilt`` up to f32 rounding."""
    y = jnp.asarray(x, jnp.float32)
    for b0, b1, b2, _, a1, a2 in np.asarray(sos, dtype=np.float32):
        def step(state, xin, b0=b0, b1=b1, b2=b2, a1=a1, a2=a2):
            s1, s2 = state
            yo = b0 * xin + s1
            s1n = b1 * xin - a1 * yo + s2
            s2n = b2 * xin - a2 * yo
            return (s1n, s2n), yo

        _, y = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), y)
    return y


def preprocess_reference(sos, x, n):
    """Filter then min-max normalize the first ``n`` samples; pad -> 0."""
    y = sosfilt_reference(sos, x)
    L = y.shape[0]
    mask = jnp.arange(L) < n
    lo = jnp.min(jnp.where(mask, y, jnp.float32(np.inf)))
    hi = jnp.max(jnp.where(mask, y, jnp.float32(-np.inf)))
    span = hi - lo
    norm = jnp.where(span > 0, (y - lo) / jnp.where(span > 0, span, 1.0), 0.0)
    return jnp.where(mask, norm, 0.0).astype(jnp.float32)
